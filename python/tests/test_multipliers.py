"""Behavioral multiplier properties + LUT serialization format."""

import os
import struct
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import multipliers as MU


@pytest.mark.parametrize("name", sorted(MU.MULTIPLIERS))
def test_zero_annihilates(name):
    m = MU.get(name)
    vals = np.array([-(1 << (m.bits - 1)), -3, 0, 1, 7, (1 << (m.bits - 1)) - 1])
    zero = np.zeros_like(vals)
    assert (m.fn(zero, vals) == 0).all()
    assert (m.fn(vals, zero) == 0).all()


@pytest.mark.parametrize(
    "name", sorted(n for n in MU.MULTIPLIERS if MU.get(n).symmetric)
)
def test_sign_symmetry(name):
    m = MU.get(name)
    rng = np.random.RandomState(0)
    half = 1 << (m.bits - 1)
    a = rng.randint(1, half, 500)
    b = rng.randint(1, half, 500)
    p = m.fn(a, b)
    assert (m.fn(-a, b) == -p).all()
    assert (m.fn(a, -b) == -p).all()
    assert (m.fn(-a, -b) == p).all()


def test_mitchell_underestimates():
    a = np.arange(1, 128)
    aa, bb = np.meshgrid(a, a)
    ap = MU.mitchell(aa.ravel(), bb.ravel())
    ex = aa.ravel() * bb.ravel()
    assert (ap <= ex).all()
    rel = (ex - ap) / ex
    # Continuous-domain Mitchell bound is ~8.6%; integer fixed-point adds a
    # little at tiny operands (3*3 -> 8, 11.1%).
    assert rel.max() <= 0.12


def test_drum_exact_below_window():
    a = np.arange(-15, 16)
    aa, bb = np.meshgrid(a, a)
    assert (MU.drum(aa.ravel(), bb.ravel(), 8, 4) == aa.ravel() * bb.ravel()).all()


@given(st.integers(0, 6), st.integers(-2048, 2047), st.integers(-2048, 2047))
@settings(max_examples=200, deadline=None)
def test_trunc_out_error_bound(k, a, b):
    err = abs(
        int(MU.trunc_out(np.array([a]), np.array([b]), 12, k)[0]) - a * b
    )
    assert err < (1 << k)


def test_characterization_registry_consistency():
    """Aliases must characterize identically to their base ACU."""
    c1 = MU.characterize("floor_trunc8_6")
    c2 = MU.characterize("mul8s_1l2h_like")
    assert c1["mre_pct"] == c2["mre_pct"]
    assert c1["wce"] == c2["wce"]


def test_floor_trunc_negative_bias():
    """The asymmetric family must round toward -inf on every product."""
    vals = np.arange(-128, 128, dtype=np.int64)
    a = np.broadcast_to(vals[:, None], (256, 256)).ravel()
    b = np.broadcast_to(vals[None, :], (256, 256)).ravel()
    e = MU.floor_trunc(a, b, 8, 6) - a * b
    assert (e <= 0).all()
    assert e.min() > -64
    assert -32.0 < e.mean() < -28.0


def test_lut_format_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        MU.write_lut("drum8_4", path)
        with open(path, "rb") as f:
            magic, bits, n, _ = struct.unpack("<IIII", f.read(16))
            body = np.frombuffer(f.read(), dtype="<i4")
        assert magic == MU.LUT_MAGIC
        assert bits == 8 and n == 256
        lut = body.reshape(n, n)
        ref = MU.build_lut("drum8_4")
        assert (lut == ref).all()
        # spot-check indexing convention: lut[a+128, b+128] == approx(a, b)
        assert lut[0, 0] == MU.drum(np.array([-128]), np.array([-128]), 8, 4)[0]


def test_lut_central_row_and_column_zero():
    lut = MU.build_lut("mitchell8")
    assert (lut[128, :] == 0).all()  # a = 0
    assert (lut[:, 128] == 0).all()  # b = 0


def test_error_profiles_are_ordered_sensibly():
    """More aggressive truncation ⇒ strictly larger MRE."""
    mre = lambda nm: MU.characterize(nm)["mre_pct"]
    assert mre("exact8") == 0.0
    assert mre("trunc_out8_4") < mre("comp_trunc_out8_6")
    assert mre("perf_pp8_3") < mre("perf_pp8_5")
    assert mre("drum8_6") < mre("drum8_4")
