"""L2 model-zoo shape/consistency tests + fp32-vs-approx sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as MZ
from compile import multipliers as MU
from compile import nn, train

BATCH = 4  # small batch for tracing speed; AOT uses MZ.BATCH


def make_input(mdef, rng):
    shape = (BATCH,) + mdef.input_shape
    if mdef.input_dtype == "i32":
        return jnp.asarray(rng.randint(0, 500, size=shape).astype(np.int32))
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


@pytest.mark.parametrize("name", MZ.all_models())
def test_fp32_forward_shapes(name):
    mdef = MZ.build(name)
    params = nn.init_params(mdef.param_specs, seed=0)
    x = make_input(mdef, np.random.RandomState(0))
    out = nn.forward(mdef.graph, params, x, nn.Ctx(mode="fp32"))
    assert out.shape[0] == BATCH
    flat = int(np.prod(out.shape[1:]))
    assert flat == mdef.out_dim, (out.shape, mdef.out_dim)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("name", MZ.table2_models())
def test_acts_taps_match_scale_count(name):
    mdef = MZ.build(name)
    params = nn.init_params(mdef.param_specs, seed=0)
    x = make_input(mdef, np.random.RandomState(1))
    ctx = nn.Ctx(mode="acts", taps=[])
    nn.forward(mdef.graph, params, x, ctx)
    assert len(ctx.taps) == mdef.n_scales


@pytest.mark.parametrize("name", ["small_vgg", "vae_mnist", "lstm_imdb"])
def test_exact_lut_approx_close_to_fp32(name):
    """Quantized-with-exact-multiplier must track fp32 within quant noise."""
    mdef = MZ.build(name)
    params = nn.init_params(mdef.param_specs, seed=0)
    rng = np.random.RandomState(2)
    x = make_input(mdef, rng)
    fp = nn.forward(mdef.graph, params, x, nn.Ctx(mode="fp32"))
    # crude per-layer scales from the fp32 taps (max calibration)
    ctx = nn.Ctx(mode="acts", taps=[])
    nn.forward(mdef.graph, params, x, ctx)
    scales = jnp.asarray(
        [float(jnp.max(jnp.abs(t))) / 127.0 + 1e-9 for t in ctx.taps], jnp.float32
    )
    lut = jnp.asarray(MU.build_lut("exact8"))
    ap = nn.forward(
        mdef.graph, params, x,
        nn.Ctx(mode="approx", bits=8, acu="lut", lut=lut, act_scales=scales),
    )
    err = float(jnp.max(jnp.abs(ap - fp)))
    ref = float(jnp.max(jnp.abs(fp))) + 1e-6
    assert err / ref < 0.25, f"{name}: rel err {err / ref}"


def test_macs_match_hand_count_small_vgg():
    mdef = MZ.build("small_vgg")
    # conv1a: 32*32*32*3*3*3, conv1b: 32*32*32*9*32, ...
    expected = (
        32 * 32 * 32 * 9 * 3
        + 32 * 32 * 32 * 9 * 32
        + 16 * 16 * 64 * 9 * 32
        + 16 * 16 * 64 * 9 * 64
        + 8 * 8 * 128 * 9 * 64
        + 2048 * 128
        + 128 * 10
    )
    assert mdef.macs == expected


def test_param_count_matches_init():
    for name in MZ.all_models():
        mdef = MZ.build(name)
        params = nn.init_params(mdef.param_specs, seed=0)
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == mdef.params_count


def test_qat_step_decreases_loss_small_vgg():
    mdef = MZ.build("small_vgg")
    params = nn.init_params(mdef.param_specs, seed=0)
    rng = np.random.RandomState(3)
    x = make_input(mdef, rng)
    y = jnp.asarray(rng.randint(0, 10, size=BATCH).astype(np.int32))
    lut = jnp.asarray(MU.build_lut("exact8"))
    scales = jnp.full((mdef.n_scales,), 0.05, jnp.float32)
    step = train.make_train_step(mdef, train.lut8_ctx, True, True)
    lr = jnp.float32(0.05)
    vels = [jnp.zeros_like(p) for p in params]
    np_ = len(params)
    out = step(*params, *vels, scales, x, y, lr, lut)
    loss0 = float(out[-1])
    params2 = list(out[:np_])
    vels2 = list(out[np_ : 2 * np_])
    out2 = step(*params2, *vels2, scales, x, y, lr, lut)
    loss1 = float(out2[-1])
    assert np.isfinite(loss0) and np.isfinite(loss1)
    assert loss1 < loss0, f"QAT step did not reduce loss: {loss0} -> {loss1}"


def test_table2_flags():
    assert set(MZ.table2_models()) == {
        "small_resnet", "small_vgg", "squeezenet_mini", "lstm_imdb", "vae_mnist",
    }


def test_graph_is_ssa_and_topologically_ordered():
    for name in MZ.all_models():
        mdef = MZ.build(name)
        seen = set()
        for node in mdef.graph:
            for i in node.get("inputs", []):
                assert i in seen or i == 0, f"{name}: node {node['id']} uses future {i}"
            assert node["id"] not in seen
            seen.add(node["id"])
