"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal of the compile path: exact integer equality
between the blocked Pallas kernels and the unblocked oracles across a
hypothesis sweep of shapes, paddings and value ranges.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import multipliers as MU
from compile.kernels import approx_matmul as AK
from compile.kernels import ref as KR

LUT8 = {name: jnp.asarray(MU.build_lut(name)) for name in ["exact8", "mitchell8"]}


def rand_q(rng, shape, half):
    return jnp.asarray(rng.randint(-half, half, size=shape).astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_matmul_matches_oracle(m, k, n, seed):
    rng = np.random.RandomState(seed)
    xq = rand_q(rng, (m, k), 128)
    wq = rand_q(rng, (k, n), 128)
    got = AK.lut_matmul(xq, wq, LUT8["mitchell8"])
    want = KR.lut_matmul_ref(xq, wq, LUT8["mitchell8"])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 50),
    k=st.integers(1, 60),
    n=st.integers(1, 30),
    trunc_k=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_functional_matmul_matches_oracle(m, k, n, trunc_k, seed):
    rng = np.random.RandomState(seed)
    xq = rand_q(rng, (m, k), 2048)
    wq = rand_q(rng, (k, n), 2048)
    got = AK.functional_matmul(xq, wq, trunc_k=trunc_k)
    want = KR.functional_matmul_ref(xq, wq, trunc_k=trunc_k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bk", [(8, 8), (16, 32), (32, 32), (64, 16)])
def test_block_shape_invariance(bm, bk):
    """The result must not depend on the BlockSpec tiling."""
    rng = np.random.RandomState(0)
    xq = rand_q(rng, (37, 53), 128)
    wq = rand_q(rng, (53, 11), 128)
    base = KR.lut_matmul_ref(xq, wq, LUT8["exact8"])
    got = AK.lut_matmul(xq, wq, LUT8["exact8"], bm=bm, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_exact_lut_equals_integer_matmul():
    rng = np.random.RandomState(1)
    xq = rand_q(rng, (20, 33), 128)
    wq = rand_q(rng, (33, 9), 128)
    got = AK.lut_matmul(xq, wq, LUT8["exact8"])
    want = jnp.asarray(np.asarray(xq) @ np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_padding_contributes_nothing():
    """Padding rows/cols (the _pad_to path) must not leak into results:
    compare a shape that forces padding against the unpadded oracle."""
    rng = np.random.RandomState(2)
    xq = rand_q(rng, (33, 35), 128)  # pads to 64 x 64 at bm=bk=32
    wq = rand_q(rng, (35, 7), 128)
    got = AK.lut_matmul(xq, wq, LUT8["mitchell8"])
    want = KR.lut_matmul_ref(xq, wq, LUT8["mitchell8"])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_negative_extremes():
    """-128 (the most negative int8) must index the LUT correctly."""
    xq = jnp.full((4, 8), -128, jnp.int32)
    wq = jnp.full((8, 3), -128, jnp.int32)
    got = AK.lut_matmul(xq, wq, LUT8["exact8"])
    assert int(np.asarray(got)[0, 0]) == 8 * 128 * 128


def test_pick_blocks_respects_slab_budget(monkeypatch):
    monkeypatch.setenv("ADAPT_SLAB_BUDGET", str(8 * 2**20))  # TPU profile
    for m, k, n in [(32768, 288, 32), (256, 2048, 128), (32, 96, 256)]:
        bm, bk = AK.pick_blocks(m, k, n)
        slab = bm * bk * n * 4
        assert slab <= 8 * 2**20, (m, k, n, bm, bk, slab)
        assert bm >= 8 and bk >= 8


def test_pick_blocks_defaults_to_cpu_profile(monkeypatch):
    monkeypatch.delenv("ADAPT_SLAB_BUDGET", raising=False)
    bm, bk = AK.pick_blocks(32768, 288, 32)
    # CPU-emulation profile favours few grid steps.
    assert bm >= 1024
    assert bk >= 128


def test_lut_matmul_auto_blocks_equal_explicit():
    rng = np.random.RandomState(5)
    xq = rand_q(rng, (100, 60), 128)
    wq = rand_q(rng, (60, 10), 128)
    auto = AK.lut_matmul(xq, wq, LUT8["mitchell8"])
    explicit = AK.lut_matmul(xq, wq, LUT8["mitchell8"], bm=16, bk=16)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))
