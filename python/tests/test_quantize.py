"""Quantizer + STE properties (mirrors rust/src/quant tests)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def test_qmax():
    assert Q.qmax_for(8) == 127
    assert Q.qmax_for(12) == 2047


def test_round_half_up():
    s = jnp.float32(1.0)
    xs = jnp.array([0.5, -0.5, 1.5, -1.5, 0.49, -0.49], jnp.float32)
    got = Q.quantize(xs, s, 8)
    np.testing.assert_array_equal(np.asarray(got), [1, 0, 2, -1, 0, 0])


@given(st.floats(-100.0, 100.0), st.floats(0.01, 2.0))
@settings(max_examples=200, deadline=None)
def test_quant_dequant_bounded(x, scale):
    xs = jnp.float32(x)
    q = Q.quantize(xs, jnp.float32(scale), 8)
    r = Q.dequantize(q, jnp.float32(scale))
    if abs(x) <= scale * 126.5:
        assert abs(float(r) - x) <= scale * 0.5 + 1e-5
    else:
        assert abs(float(r)) <= scale * 127.0 + 1e-5


def test_weight_scale_per_col():
    w = jnp.array([[1.0, -5.0, 2.0], [-4.0, 3.0, 6.0]], jnp.float32)
    s = Q.weight_scale_per_col(w, 8)
    np.testing.assert_allclose(
        np.asarray(s), [4 / 127, 5 / 127, 6 / 127], rtol=1e-6
    )


def test_ste_gradient_is_clipped_identity():
    scale = jnp.float32(0.1)

    def f(x):
        return jnp.sum(Q.fake_quant_ste(x, scale, 8))

    g = jax.grad(f)(jnp.array([0.05, 5.0, -0.3, -50.0], jnp.float32))
    # inside range -> 1, outside (|x| > 12.7) -> 0
    np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0, 1.0, 0.0])


def test_fake_quant_is_idempotent():
    x = jnp.linspace(-1, 1, 101, dtype=jnp.float32)
    s = jnp.float32(0.013)
    once = Q.fake_quant(x, s, 8)
    twice = Q.fake_quant(once, s, 8)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-7)
