"""L1 Pallas kernels: approximate integer matmul (LUT-gather + functional).

This is the TPU rethink of the paper's AVX2 hot loop (DESIGN.md
§Hardware-Adaptation). The paper tiles an im2col GEMM across OpenMP threads
and vectorizes each scalar multiply as an AVX2 ``vpgatherdd`` into a
cache-aligned product LUT. Here:

* the LUT (256x256 int32 = 256 KiB at 8-bit) is pinned whole in VMEM via a
  BlockSpec that maps it to every grid step — the analogue of "populate the
  CPU cores' cache with the LUTs" (§3.4);
* the GEMM is blocked over (M, K) on the Pallas grid; each step gathers a
  (bm, bk, N) product slab from the VMEM LUT on the VPU and accumulates
  into the (bm, N) output block, giving the HBM<->VMEM schedule the paper
  expressed with threadblocks;
* at 12-bit the LUT would be 64 MiB (> VMEM), so — like the paper's
  C-functional fallback — the ACU is computed in-register as integer
  shift/mask arithmetic (``functional`` kernel).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO (while-loop +
dynamic-slice + gather) that both jax and the Rust runtime execute.
Numerics are identical either way — these are integer kernels.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# --- Block-shape selection (EXPERIMENTS.md §Perf documents the sweep) ----
#
# VMEM per grid step at 8-bit = LUT (256 KiB) + x (bm*bk*4) + w (bk*N*4)
# + out (bm*N*4) + gather slab (bm*bk*N*4, dominant). The *slab budget*
# controls the trade-off:
#
#  * TPU profile (budget ≈ 8 MiB): blocks sized so the working set fits a
#    16 MiB VMEM with double-buffer headroom, e.g. (512, 144) at N=32.
#  * CPU-emulation profile (default, 64 MiB): interpret-mode pallas lowers
#    the grid to an HLO while loop whose per-step slice/update copies
#    dominate wall-clock — fewer, larger steps are ~100x faster (measured:
#    59 s -> 0.48 s on the 32768x288x32 conv GEMM going from 32x32 to
#    2048x288 blocks). Emulation numerics are identical either way.
#
# Override with ADAPT_SLAB_BUDGET (bytes) at `make artifacts` time.
def slab_budget() -> int:
    return int(os.environ.get("ADAPT_SLAB_BUDGET", 64 * 2**20))


def pick_blocks(m: int, k: int, n: int) -> tuple:
    """Choose (bm, bk) for an (m, k) x (k, n) LUT GEMM under the budget."""
    budget = slab_budget()
    bm = 1 << max(0, (min(m, 2048) - 1)).bit_length()  # pow2 >= min(m, 2048)
    bm = max(8, min(bm, 2048))
    bk = budget // (bm * n * 4)
    while bk < 32 and bm > 8:  # shrink rows before starving the K block
        bm //= 2
        bk = budget // (bm * n * 4)
    bk = max(8, min(k, bk))
    return bm, bk


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of ``mult``.

    Zero padding is *numerically safe* for every ACU in the family: all are
    sign-magnitude behavioral models with approx(0, y) == approx(x, 0) == 0,
    so padded lanes contribute exactly 0 to the accumulator.
    """
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _lut_kernel(x_ref, w_ref, lut_ref, o_ref, *, half: int):
    """One (mi, ki) grid step: o[mi] += sum_k LUT[x[mi,ki,k], w[ki,k,:]]."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]  # (bm, bk) int32
    wb = w_ref[...]  # (bk, N) int32
    lut = lut_ref[...]  # (2h, 2h) int32, whole table resident in VMEM
    # VPU gather: (bm, bk, N) product slab from the table.
    prods = lut[xb[:, :, None] + half, wb[None, :, :] + half]
    o_ref[...] += jnp.sum(prods, axis=1, dtype=jnp.int32)


def lut_matmul(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    bm: int | None = None,
    bk: int | None = None,
) -> jnp.ndarray:
    """Blocked Pallas LUT matmul. xq (M,K) i32, wq (K,N) i32 -> (M,N) i32.

    acc[m,n] = sum_k LUT[xq[m,k] + half, wq[k,n] + half].
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    if bm is None or bk is None:
        abm, abk = pick_blocks(m, k, n)
        bm = bm or abm
        bk = bk or abk
    half = lut.shape[0] // 2

    xp = _pad_to(_pad_to(xq, 0, bm), 1, bk)
    wp = _pad_to(wq, 0, bk)
    mp, kp = xp.shape
    grid = (mp // bm, kp // bk)

    out = pl.pallas_call(
        functools.partial(_lut_kernel, half=half),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ki: (mi, ki)),
            pl.BlockSpec((bk, n), lambda mi, ki: (ki, 0)),
            # whole LUT at every step: the "keep the table hot" strategy.
            pl.BlockSpec(lut.shape, lambda mi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda mi, ki: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.int32),
        interpret=True,
    )(xp, wp, lut)
    return out[:m, :]


def _functional_kernel(x_ref, w_ref, o_ref, *, trunc_k: int):
    """Functional-ACU grid step: product = trunc_out(|a|*|b|, k) * sign."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...][:, :, None]  # (bm, bk, 1)
    wb = w_ref[...][None, :, :]  # (1, bk, N)
    sign = jnp.sign(xb) * jnp.sign(wb)
    mask = jnp.int32(~((1 << trunc_k) - 1))
    prods = sign * ((jnp.abs(xb) * jnp.abs(wb)) & mask)
    o_ref[...] += jnp.sum(prods, axis=1, dtype=jnp.int32)


def functional_matmul(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    *,
    trunc_k: int = 4,
    bm: int | None = None,
    bk: int | None = None,
) -> jnp.ndarray:
    """Blocked Pallas matmul with the 12-bit functional ACU (trunc_out k).

    Same schedule as :func:`lut_matmul` but the product op is in-register
    integer arithmetic — no table traffic at all.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    if bm is None or bk is None:
        abm, abk = pick_blocks(m, k, n)
        bm = bm or abm
        bk = bk or abk

    xp = _pad_to(_pad_to(xq, 0, bm), 1, bk)
    wp = _pad_to(wq, 0, bk)
    mp, kp = xp.shape
    grid = (mp // bm, kp // bk)

    out = pl.pallas_call(
        functools.partial(_functional_kernel, trunc_k=trunc_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ki: (mi, ki)),
            pl.BlockSpec((bk, n), lambda mi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda mi, ki: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.int32),
        interpret=True,
    )(xp, wp)
    return out[:m, :]
