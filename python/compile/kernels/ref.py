"""Pure-jnp oracles for the Pallas approximate-matmul kernels.

These are the correctness ground truth: small, obviously-right
implementations with no blocking, no padding tricks, no pallas. The pytest
suite asserts exact integer equality between kernel and oracle across a
hypothesis sweep of shapes, and the Rust emulator is cross-checked against
the same numbers through the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def lut_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Integer matmul where every scalar product is a LUT lookup.

    xq: (M, K) int32 in [-half, half-1]
    wq: (K, N) int32
    lut: (2^b, 2^b) int32, biased-unsigned indexing (value + half)

    Returns (M, N) int32 accumulators: acc[m,n] = sum_k LUT[xq[m,k], wq[k,n]].
    """
    half = lut.shape[0] // 2
    # (M, K, N) gather — fine at oracle scale, never used on the hot path.
    prods = lut[xq[:, :, None] + half, wq[None, :, :] + half]
    return jnp.sum(prods, axis=1, dtype=jnp.int32)


def _split_sign(a, b):
    sign = jnp.sign(a) * jnp.sign(b)
    return jnp.abs(a), jnp.abs(b), sign


def trunc_out_product(a: jnp.ndarray, b: jnp.ndarray, k: int) -> jnp.ndarray:
    """Functional form of multipliers.trunc_out (sign-magnitude, k LSBs
    zeroed) on int32 arrays. Mirrors python/compile/multipliers.py."""
    aa, ab, sign = _split_sign(a, b)
    mask = jnp.int32(~((1 << k) - 1))
    return sign * ((aa * ab) & mask)


def functional_matmul_ref(
    xq: jnp.ndarray, wq: jnp.ndarray, trunc_k: int = 4
) -> jnp.ndarray:
    """Oracle for the LUT-free ("functional") path used at 12-bit, where a
    4096x4096 LUT would blow VMEM/cache (paper §3.4). Product op is
    trunc_out(k) — the mul12s_2km_like ACU."""
    prods = trunc_out_product(xq[:, :, None], wq[None, :, :], trunc_k)
    return jnp.sum(prods, axis=1, dtype=jnp.int32)
