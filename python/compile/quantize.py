"""Affine quantization primitives shared by the L2 model zoo.

Numeric contract (mirrored bit-for-bit by ``rust/src/quant``):

* symmetric signed quantization, zero_point = 0 (paper eq. (1)/(2) with
  B = 0; the calibrators still learn the range exactly as §3.2.1 does);
* activations: per-tensor scale, learned offline by a histogram calibrator;
* weights: per-output-channel scale, ``max|w_c| / qmax`` (§3.2.1: "weight
  ranges are per channel while activation ranges are per tensor");
* rounding: ``floor(x/s + 0.5)`` — round-half-up, chosen over
  round-nearest-even because it is trivially bit-identical between XLA HLO
  and the Rust emulator's f32 ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmax_for(bits: int) -> int:
    """Largest representable magnitude, e.g. 127 for 8-bit."""
    return (1 << (bits - 1)) - 1


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Real -> int (int32 storage). ``scale`` broadcasts against ``x``."""
    q = jnp.floor(x / scale + 0.5)
    qm = float(qmax_for(bits))
    return jnp.clip(q, -qm, qm).astype(jnp.int32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def weight_scale_per_col(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-output-channel scale for a (K, N) weight matrix -> shape (N,).

    Computed in-graph (not calibrated): the weight range is known exactly.
    """
    amax = jnp.max(jnp.abs(w), axis=0)
    return jnp.maximum(amax, 1e-12) / float(qmax_for(bits))


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize (the paper's "fake quantization module")."""
    return dequantize(quantize(x, scale, bits), scale)


@jax.custom_vjp
def fake_quant_ste(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quant with a straight-through estimator backward.

    Forward: rounded/clipped quant-dequant. Backward: identity inside the
    representable range, zero outside (the clipped-STE of QAT practice).
    """
    return fake_quant(x, scale, bits)


def _fq_fwd(x, scale, bits):
    return fake_quant(x, scale, bits), (x, scale, bits)


def _fq_bwd(res, g):
    x, scale, bits = res
    lim = scale * float(qmax_for(bits))
    mask = (jnp.abs(x) <= lim).astype(g.dtype)
    return (g * mask, None, None)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)
