"""AdaPT-RS build-time compile package (L1+L2). Never imported at runtime."""
