"""Loss functions and AOT train-step builders (fp32 + approximation-aware).

The QAT step is the paper's §3.2.1: forward through the ACUs, backward
through straight-through fake-quant (``nn._ste_matmul_for``), plain SGD —
the paper retrains with SGD, lr 1e-4, for ~10 % of the schedule. The whole
step (grads + update) is one XLA executable; the Rust coordinator owns the
schedule (epochs, lr, subset) and just feeds batches.
"""

from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp

from . import nn
from .model import ModelDef


def loss_value(mdef: ModelDef, out: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Scalar training loss for a model family."""
    if mdef.loss == "ce":
        logp = jax.nn.log_softmax(out, axis=-1)
        n = out.shape[0]
        return -jnp.mean(logp[jnp.arange(n), y])
    if mdef.loss == "vae":
        # Deterministic-AE objective (z = mu, DESIGN.md §Substitutions):
        # mean binary cross-entropy between reconstruction and input.
        r = jnp.clip(out, 1e-6, 1.0 - 1e-6)
        t = jnp.clip(x, 0.0, 1.0)
        return -jnp.mean(t * jnp.log(r) + (1.0 - t) * jnp.log(1.0 - r))
    raise ValueError(f"model {mdef.name} has no trainable loss")


def make_infer(mdef: ModelDef, ctx_fn: Callable[..., nn.Ctx], with_scales: bool, with_lut: bool):
    """Build a flat-positional inference callable for AOT lowering.

    Signature: (*params[, act_scales], x[, lut]) -> (out,)
    """
    np_ = len(mdef.param_specs)

    def fn(*args):
        params = list(args[:np_])
        rest = list(args[np_:])
        scales = rest.pop(0) if with_scales else None
        x = rest.pop(0)
        lut = rest.pop(0) if with_lut else None
        ctx = ctx_fn(act_scales=scales, lut=lut)
        return (nn.forward(mdef.graph, params, x, ctx),)

    return fn


def make_acts(mdef: ModelDef):
    """Calibration-tap executable: (*params, x) -> tuple of L tap tensors.

    Tap i is the (flattened-to-2D) fp32 input of the quantizable matmul
    that consumes act_scales[i] — histogrammed by the Rust calibrators.
    """
    np_ = len(mdef.param_specs)

    def fn(*args):
        params = list(args[:np_])
        x = args[np_]
        ctx = nn.Ctx(mode="acts", taps=[])
        out = nn.forward(mdef.graph, params, x, ctx)
        assert len(ctx.taps) == mdef.n_scales, (len(ctx.taps), mdef.n_scales)
        # Anchor the network output into tap 0 with zero weight so XLA
        # cannot DCE the last layer's parameters (the Rust caller always
        # supplies the full positional signature).
        taps = list(ctx.taps)
        taps[0] = taps[0] + 0.0 * jnp.sum(out).astype(taps[0].dtype)
        return tuple(taps)

    return fn


#: Heavy-ball momentum baked into every train-step executable. The paper
#: retrains with SGD; momentum is the standard stabilizer and is required
#: for the small-init synthetic tasks to converge in a few hundred steps.
MOMENTUM = 0.9


def make_train_step(mdef: ModelDef, ctx_fn, with_scales: bool, with_lut: bool):
    """One SGD-with-momentum step as a single executable.

    Signature:
        (*params, *velocities[, act_scales], x, y, lr[, lut])
            -> (*new_params, *new_velocities, loss)

    The Rust coordinator owns the velocity buffers (initialized to zero)
    and round-trips them exactly like the parameters.
    """
    np_ = len(mdef.param_specs)

    def fn(*args):
        params = list(args[:np_])
        vels = list(args[np_ : 2 * np_])
        rest = list(args[2 * np_ :])
        scales = rest.pop(0) if with_scales else None
        x = rest.pop(0)
        y = rest.pop(0)
        lr = rest.pop(0)
        lut = rest.pop(0) if with_lut else None

        def loss_fn(plist: Sequence[jnp.ndarray]) -> jnp.ndarray:
            ctx = ctx_fn(act_scales=scales, lut=lut, ste=True)
            out = nn.forward(mdef.graph, list(plist), x, ctx)
            return loss_value(mdef, out, x, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Anchor every declared argument into the output: XLA would
        # otherwise DCE unused parameters (e.g. labels in the VAE loss) and
        # the Rust caller feeds the full uniform signature.
        loss = loss + 0.0 * y.astype(jnp.float32).sum()
        new_vels = [MOMENTUM * v + g for v, g in zip(vels, grads)]
        new_params = [p - lr * v for p, v in zip(params, new_vels)]
        return (*new_params, *new_vels, loss)

    return fn


def fp32_ctx(**kw) -> nn.Ctx:
    return nn.Ctx(mode="fp32")


def lut8_ctx(act_scales=None, lut=None, ste: bool = False) -> nn.Ctx:
    return nn.Ctx(mode="approx", bits=8, acu="lut", lut=lut,
                  act_scales=act_scales, ste=ste)


def func12_ctx(trunc_k: int):
    def make(act_scales=None, lut=None, ste: bool = False) -> nn.Ctx:
        return nn.Ctx(mode="approx", bits=12, acu="func", trunc_k=trunc_k,
                      act_scales=act_scales, ste=ste)

    return make
