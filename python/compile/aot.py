"""AOT driver: lower the whole model zoo to HLO text + emit artifacts.

Python runs exactly once (``make artifacts``); afterwards the Rust binary
is self-contained. Outputs under ``artifacts/``:

* ``hlo/<model>_<variant>.hlo.txt`` — one XLA executable per execution
  variant (HLO *text*, not serialized proto: jax >= 0.5 emits 64-bit
  instruction ids that xla_extension 0.5.1 rejects; the text parser
  reassigns ids — see /opt/xla-example/README.md);
* ``luts/<acu>.bin``   — product LUTs for every 8-bit ACU in the library;
* ``weights/<model>.bin`` — deterministic initial parameters (flat f32 LE);
* ``manifest.json``    — the IR graphs, param specs, artifact index and
  dataset bindings the Rust coordinator + emulators consume.

Variants per model (Table-2 models get all; timing-only models get the
first and fourth):

  fp32_infer      (*params, x)                          -> out
  fp32_train      (*params, x, y, lr)                   -> (*params', loss)
  acts            (*params, x)                          -> calibration taps
  approx_infer    (*params, scales, x, lut)             -> out   [8-bit LUT ACU]
  qat_train       (*params, scales, x, y, lr, lut)      -> (*params', loss)
  quant12_infer   (*params, scales, x)                  -> out   [12-bit exact]
  approx12_infer  (*params, scales, x)                  -> out   [12-bit func ACU]
  qat12_train     (*params, scales, x, y, lr)           -> (*params', loss)

The 8-bit *exact-quantized* column of Table 2 needs no extra executable:
it is ``approx_infer`` fed the ``exact8`` LUT.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as MZ
from . import multipliers as MU
from . import nn, train

# 8-bit ACUs whose LUTs ship as artifacts (ablation bench sweeps them all).
LUT_ACUS = [
    "exact8", "mul8s_1l2h_like", "mitchell8", "drum8_4", "drum8_6",
    "trunc_out8_4", "comp_trunc_out8_6", "trunc_in8_2", "perf_pp8_3",
    "perf_pp8_5", "floor_trunc8_5", "floor_trunc8_6", "floor_trunc8_7",
]

TRUNC12_K = 4  # the mul12s_2km_like functional ACU


def to_hlo_text(lowered) -> str:
    """HLO-text interchange (see module docstring / aot_recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    )


def model_specs(mdef: MZ.ModelDef):
    params = [spec(p["shape"]) for p in mdef.param_specs]
    x = spec((MZ.BATCH,) + mdef.input_shape, mdef.input_dtype)
    y = spec((MZ.BATCH,), "i32")
    lr = spec((), "f32")
    scales = spec((mdef.n_scales,), "f32")
    lut = spec((256, 256), "i32")
    return params, x, y, lr, scales, lut


def variants_for(mdef: MZ.ModelDef):
    """(variant name, callable, example-arg specs) triples."""
    params, x, y, lr, scales, lut = model_specs(mdef)
    v = {
        "fp32_infer": (
            train.make_infer(mdef, train.fp32_ctx, False, False),
            [*params, x],
        ),
        "approx_infer": (
            train.make_infer(mdef, train.lut8_ctx, True, True),
            [*params, scales, x, lut],
        ),
        # Every model gets calibration taps — Table-4 timing also runs the
        # approx path, which needs calibrated activation scales.
        "acts": (train.make_acts(mdef), [*params, x]),
    }
    if mdef.table2:
        v["fp32_train"] = (
            train.make_train_step(mdef, train.fp32_ctx, False, False),
            [*params, *params, x, y, lr],
        )
        v["qat_train"] = (
            train.make_train_step(mdef, train.lut8_ctx, True, True),
            [*params, *params, scales, x, y, lr, lut],
        )
        v["quant12_infer"] = (
            train.make_infer(mdef, train.func12_ctx(0), True, False),
            [*params, scales, x],
        )
        v["approx12_infer"] = (
            train.make_infer(mdef, train.func12_ctx(TRUNC12_K), True, False),
            [*params, scales, x],
        )
        v["qat12_train"] = (
            train.make_train_step(mdef, train.func12_ctx(TRUNC12_K), True, False),
            [*params, *params, scales, x, y, lr],
        )
    return v


def write_weights(mdef: MZ.ModelDef, path: str, seed: int = 0) -> None:
    params = nn.init_params(mdef.param_specs, seed=seed)
    with open(path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="", help="comma filter (default: all)")
    ap.add_argument("--variants", default="", help="comma filter (default: all)")
    args = ap.parse_args()

    out = args.out
    os.makedirs(f"{out}/hlo", exist_ok=True)
    os.makedirs(f"{out}/luts", exist_ok=True)
    os.makedirs(f"{out}/weights", exist_ok=True)

    model_filter = set(filter(None, args.models.split(",")))
    var_filter = set(filter(None, args.variants.split(",")))

    # --- LUTs + characterization ----------------------------------------
    luts_meta = {}
    for acu in LUT_ACUS:
        path = f"{out}/luts/{acu}.bin"
        MU.write_lut(acu, path)
        ch = MU.characterize(acu)
        luts_meta[acu] = {
            "file": f"luts/{acu}.bin",
            "bits": MU.get(acu).bits,
            "mae_pct": ch["mae_pct"],
            "mre_pct": ch["mre_pct"],
            "wce": ch["wce"],
            "power": ch["power"],
        }
        print(f"[lut] {acu:<20} MRE {ch['mre_pct']:.5f}%", flush=True)

    # --- models ----------------------------------------------------------
    manifest_models = {}
    for name in MZ.all_models():
        if model_filter and name not in model_filter:
            continue
        mdef = MZ.build(name)
        write_weights(mdef, f"{out}/weights/{name}.bin")
        arts = {}
        for vname, (fn, specs) in variants_for(mdef).items():
            if var_filter and vname not in var_filter:
                continue
            t0 = time.time()
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"hlo/{name}_{vname}.hlo.txt"
            with open(f"{out}/{fname}", "w") as f:
                f.write(text)
            arts[vname] = fname
            print(
                f"[hlo] {name}_{vname}: {len(text)/1e6:.2f} MB "
                f"({time.time()-t0:.1f}s)",
                flush=True,
            )
        manifest_models[name] = {
            "paper_row": mdef.paper_row,
            "kind": mdef.kind,
            "dataset": mdef.dataset,
            "input_shape": list(mdef.input_shape),
            "input_dtype": mdef.input_dtype,
            "out_dim": mdef.out_dim,
            "loss": mdef.loss,
            "metric": mdef.metric,
            "table2": mdef.table2,
            "n_scales": mdef.n_scales,
            "params": mdef.param_specs,
            "params_count": mdef.params_count,
            "macs": mdef.macs,
            "graph": mdef.graph,
            "weights_file": f"weights/{name}.bin",
            "artifacts": arts,
        }

    # Merge with any existing manifest so partial regeneration (--models /
    # --variants filters) never loses previously-lowered artifacts.
    manifest = {
        "version": 1,
        "batch": MZ.BATCH,
        "trunc12_k": TRUNC12_K,
        "luts": luts_meta,
        "models": manifest_models,
    }
    mpath = f"{out}/manifest.json"
    if os.path.exists(mpath) and (model_filter or var_filter):
        with open(mpath) as f:
            old = json.load(f)
        for name, entry in old.get("models", {}).items():
            if name not in manifest["models"]:
                manifest["models"][name] = entry
            else:
                merged = dict(entry.get("artifacts", {}))
                merged.update(manifest["models"][name]["artifacts"])
                manifest["models"][name]["artifacts"] = merged
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[manifest] {mpath} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
