"""Behavioral approximate-multiplier library (EvoApprox substitute).

The paper evaluates ACUs from the EvoApprox8b library [Mrazek et al., DATE
2017] — gate-level netlists we do not have. Per the substitution rule we
implement a *behavioral* family of classic approximate multipliers covering
the same error-profile space, characterize them (MAE/MRE/power-proxy), and
pin two aliases to the paper's Table-2 operating points:

  * ``mul8s_1l2h_like``  — 8-bit, high MRE (~4.4 %), low power
  * ``mul12s_2km_like``  — 12-bit, tiny MRE (~5e-4 %), higher power

Every multiplier here is **pure integer arithmetic** (shifts, masks, adds)
on numpy int64 arrays. The Rust crate (``rust/src/mult``) mirrors these
bit-for-bit; ``cargo test`` cross-checks the Rust models against the LUT
binaries emitted by :func:`write_lut` at ``make artifacts`` time.

Sign convention: operands are signed two's-complement ``bits``-wide values
in ``[-2^(b-1), 2^(b-1)-1]``. All approximations act on magnitudes; the
exact product sign is re-applied afterwards (standard for behavioral models
of sign-magnitude approximate array multipliers).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Callable, Dict

import numpy as np

# Fixed-point fraction bits for the Mitchell log multiplier. The Rust mirror
# uses the same constant; both sides compute in 64-bit integers only.
MITCHELL_FRAC_BITS = 16


def _split_sign(a: np.ndarray, b: np.ndarray):
    a = a.astype(np.int64)
    b = b.astype(np.int64)
    sign = np.sign(a) * np.sign(b)
    return np.abs(a), np.abs(b), sign


def _floor_log2(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) for x >= 1, elementwise; 0 maps to 0 (callers mask)."""
    out = np.zeros_like(x)
    v = x.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        ge = v >= (np.int64(1) << shift)
        out = np.where(ge, out + shift, out)
        v = np.where(ge, v >> shift, v)
    return out


def exact(a: np.ndarray, b: np.ndarray, bits: int = 8) -> np.ndarray:
    """Exact signed multiplier (the accurate ACU)."""
    return a.astype(np.int64) * b.astype(np.int64)


def trunc_in(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 2) -> np.ndarray:
    """Input-truncation multiplier: zero the k magnitude LSBs of both operands."""
    aa, ab, sign = _split_sign(a, b)
    mask = ~np.int64((1 << k) - 1)
    return sign * ((aa & mask) * (ab & mask))


def perf_pp(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 3) -> np.ndarray:
    """Partial-product perforation: drop the k lowest partial-product rows
    (equivalently, zero the k LSBs of the second operand's magnitude)."""
    aa, ab, sign = _split_sign(a, b)
    mask = ~np.int64((1 << k) - 1)
    return sign * (aa * (ab & mask))


def trunc_out(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 3) -> np.ndarray:
    """Fixed-width output truncation: exact product with k LSBs zeroed."""
    aa, ab, sign = _split_sign(a, b)
    mask = ~np.int64((1 << k) - 1)
    return sign * ((aa * ab) & mask)


def comp_trunc_out(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 3) -> np.ndarray:
    """Output truncation with midpoint error compensation (adds 2^(k-1) to
    every nonzero truncated product — halves the mean error of trunc_out)."""
    aa, ab, sign = _split_sign(a, b)
    p = aa * ab
    mask = ~np.int64((1 << k) - 1)
    comp = np.where(p > 0, np.int64(1 << (k - 1)), np.int64(0))
    return sign * ((p & mask) + comp)


def mitchell(a: np.ndarray, b: np.ndarray, bits: int = 8) -> np.ndarray:
    """Mitchell logarithmic multiplier (1962), integer fixed-point form.

    log2(x) ~= k + frac where k = floor(log2 x) and frac = x/2^k - 1.
    The product is reconstructed as 2^(ka+kb) * (1 + fa + fb), with the
    classic wrap when fa+fb >= 1. All arithmetic is int64 shifts/adds with
    MITCHELL_FRAC_BITS fraction bits — bit-exact across Python and Rust.
    """
    F = MITCHELL_FRAC_BITS
    aa, ab, sign = _split_sign(a, b)
    nz = (aa > 0) & (ab > 0)
    sa = np.where(nz, aa, 1)  # avoid log(0); masked out at the end
    sb = np.where(nz, ab, 1)
    ka = _floor_log2(sa)
    kb = _floor_log2(sb)
    # fraction in F-bit fixed point: (x << F >> k) - (1 << F)
    fa = ((sa << F) >> ka) - (np.int64(1) << F)
    fb = ((sb << F) >> kb) - (np.int64(1) << F)
    ksum = ka + kb
    fsum = fa + fb
    one = np.int64(1) << F
    wrap = fsum >= one
    # no wrap: p = (1 + fsum) << ksum ; wrap: p = (1 + (fsum - 1)/1... ) << (ksum+1)
    mant = np.where(wrap, fsum, one + fsum)
    kk = np.where(wrap, ksum + 1, ksum)
    # p = mant * 2^kk / 2^F, computed with shifts (kk <= 2*(bits-1)+1 <= 23 for 12b)
    p = np.where(kk >= F, mant << (kk - F), mant >> (F - kk))
    return sign * np.where(nz, p, 0)


def floor_trunc(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 3) -> np.ndarray:
    """Fixed-width array truncation on the two's-complement product:
    ``floor(a*b / 2^k) * 2^k`` (arithmetic shift). Unlike the
    sign-magnitude family this error is **asymmetric** — it always rounds
    toward -inf, giving every product a negative bias that accumulates
    across a dot product. This is the error mode that actually damages DNN
    accuracy (gate-level EvoApprox units behave this way), and the one QAT
    recovers by re-learning biases."""
    p = a.astype(np.int64) * b.astype(np.int64)
    return (p >> k) << k


def drum(a: np.ndarray, b: np.ndarray, bits: int = 8, k: int = 4) -> np.ndarray:
    """DRUM-k [Hashemi et al., ICCAD 2015]: keep the k leading magnitude bits
    of each operand, set the bit below the kept window (unbiasing trick),
    multiply exactly, shift back."""
    aa, ab, sign = _split_sign(a, b)

    def reduce_op(x):
        nz = x > 0
        sx = np.where(nz, x, 1)
        lx = _floor_log2(sx)
        t = np.maximum(lx - (k - 1), 0)  # bits to drop
        kept = (sx >> t) << t
        unbias = np.where(t > 0, np.int64(1) << (t - 1), np.int64(0))
        return np.where(nz, kept | unbias, 0)

    return sign * (reduce_op(aa) * reduce_op(ab))


@dataclasses.dataclass(frozen=True)
class Multiplier:
    """A named approximate compute unit (ACU)."""

    name: str
    bits: int
    fn: Callable[[np.ndarray, np.ndarray], np.ndarray]
    #: relative dynamic+static power proxy, normalized so exact8 == 1.0.
    #: Modeled as (active partial-product bits)/(full array bits); see
    #: DESIGN.md §Substitutions. Absolute mW figures in the paper are
    #: netlist-specific and not reproducible behaviorally.
    power: float
    #: sign-magnitude models satisfy approx(-a,b) == -approx(a,b); the
    #: two's-complement floor-truncation family does not.
    symmetric: bool = True

    def __call__(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return self.fn(a, b)

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def _registry() -> Dict[str, Multiplier]:
    m: Dict[str, Multiplier] = {}

    def add(name, bits, fn, power, symmetric=True):
        m[name] = Multiplier(name, bits, fn, power, symmetric)

    # --- 8-bit family ---------------------------------------------------
    add("exact8", 8, lambda a, b: exact(a, b, 8), 1.00)
    add("trunc_in8_2", 8, lambda a, b: trunc_in(a, b, 8, 2), 0.62)
    add("perf_pp8_3", 8, lambda a, b: perf_pp(a, b, 8, 3), 0.66)
    add("perf_pp8_5", 8, lambda a, b: perf_pp(a, b, 8, 5), 0.45)
    add("trunc_out8_4", 8, lambda a, b: trunc_out(a, b, 8, 4), 0.78)
    add("comp_trunc_out8_6", 8, lambda a, b: comp_trunc_out(a, b, 8, 6), 0.70)
    add("mitchell8", 8, lambda a, b: mitchell(a, b, 8), 0.40)
    add("drum8_4", 8, lambda a, b: drum(a, b, 8, 4), 0.52)
    add("drum8_6", 8, lambda a, b: drum(a, b, 8, 6), 0.74)
    add("floor_trunc8_5", 8, lambda a, b: floor_trunc(a, b, 8, 5), 0.72, False)
    add("floor_trunc8_6", 8, lambda a, b: floor_trunc(a, b, 8, 6), 0.65, False)
    add("floor_trunc8_7", 8, lambda a, b: floor_trunc(a, b, 8, 7), 0.58, False)
    # --- 12-bit family --------------------------------------------------
    add("exact12", 12, lambda a, b: exact(a, b, 12), 2.25)
    add("trunc_out12_4", 12, lambda a, b: trunc_out(a, b, 12, 4), 1.95)
    add("comp_trunc_out12_4", 12, lambda a, b: comp_trunc_out(a, b, 12, 4), 1.97)
    add("mitchell12", 12, lambda a, b: mitchell(a, b, 12), 0.90)
    add("drum12_6", 12, lambda a, b: drum(a, b, 12, 6), 1.15)
    # --- Table-2 operating-point aliases (see characterize()) -----------
    # mul8s_1L2H:  MAE 0.081 %, MRE 4.41 %, power 0.301 mW -> floor_trunc8_6
    #   (measured here: MAE 0.046 %, MRE 5.67 % — the closest family member
    #    to the paper's high-MRE/low-power corner, and like the gate-level
    #    unit its error is sign-asymmetric, which is what actually costs
    #    DNN accuracy; the sign-magnitude models are benign).
    # mul12s_2KM:  MAE 1.2e-6 %, MRE 4.7e-4 %, power 1.205 mW -> trunc_out12_4
    #   (tiny relative error, near-exact power).
    m["mul8s_1l2h_like"] = dataclasses.replace(
        m["floor_trunc8_6"], name="mul8s_1l2h_like"
    )
    m["mul12s_2km_like"] = dataclasses.replace(
        m["trunc_out12_4"], name="mul12s_2km_like"
    )
    return m


MULTIPLIERS: Dict[str, Multiplier] = _registry()


def get(name: str) -> Multiplier:
    try:
        return MULTIPLIERS[name]
    except KeyError:
        raise KeyError(
            f"unknown multiplier {name!r}; have {sorted(MULTIPLIERS)}"
        ) from None


def build_lut(name: str) -> np.ndarray:
    """Materialize the full (2^b, 2^b) int32 product LUT for an ACU.

    Row/col index ``i`` encodes operand value ``i - 2^(b-1)`` (i.e. the
    two's-complement value biased to unsigned), matching the Rust loader
    and the Pallas kernel's index arithmetic.
    """
    mul = get(name)
    n = 1 << mul.bits
    half = n // 2
    vals = np.arange(-half, half, dtype=np.int64)
    a = vals[:, None]
    b = vals[None, :]
    lut = mul.fn(np.broadcast_to(a, (n, n)), np.broadcast_to(b, (n, n)))
    return lut.astype(np.int32)


LUT_MAGIC = 0x4C55_5401  # "LUT\x01"


def write_lut(name: str, path: str) -> None:
    """Serialize a LUT to the simple binary format the Rust side reads:

    header: magic u32 | bits u32 | n u32 | reserved u32   (little-endian)
    body:   n*n int32 products, row-major, row/col biased-unsigned index.
    """
    mul = get(name)
    lut = build_lut(name)
    n = lut.shape[0]
    with open(path, "wb") as f:
        f.write(struct.pack("<IIII", LUT_MAGIC, mul.bits, n, 0))
        f.write(lut.astype("<i4").tobytes())


def characterize(name: str, sample: int | None = None, seed: int = 0) -> dict:
    """MAE%% / MRE%% / worst-case error of an ACU vs the exact product.

    MAE%% is normalized by the full output range 2^(2b) (the EvoApprox
    convention the paper quotes); MRE%% averages |err|/|exact| over nonzero
    exact products. 8-bit units are characterized exhaustively (65k pairs);
    12-bit by a deterministic 4M-pair sample unless ``sample`` overrides.
    """
    mul = get(name)
    half = 1 << (mul.bits - 1)
    if mul.bits <= 8 and sample is None:
        vals = np.arange(-half, half, dtype=np.int64)
        a = np.broadcast_to(vals[:, None], (2 * half, 2 * half)).ravel()
        b = np.broadcast_to(vals[None, :], (2 * half, 2 * half)).ravel()
    else:
        rng = np.random.RandomState(seed)
        count = sample or 4_000_000
        a = rng.randint(-half, half, size=count).astype(np.int64)
        b = rng.randint(-half, half, size=count).astype(np.int64)
    ex = a * b
    ap = mul.fn(a, b)
    err = np.abs(ap - ex).astype(np.float64)
    out_range = float(1 << (2 * mul.bits))
    nz = ex != 0
    mre = float(np.mean(err[nz] / np.abs(ex[nz]).astype(np.float64))) * 100.0
    return {
        "name": name,
        "bits": mul.bits,
        "mae_pct": float(err.mean() / out_range) * 100.0,
        "mre_pct": mre,
        "wce": int(err.max()),
        "power": mul.power,
    }


if __name__ == "__main__":  # quick characterization table
    for nm in sorted(MULTIPLIERS):
        c = characterize(nm, sample=200_000 if get(nm).bits > 8 else None)
        print(
            f"{c['name']:<20} {c['bits']:>2}b  MAE {c['mae_pct']:.5f}%  "
            f"MRE {c['mre_pct']:.5f}%  WCE {c['wce']:>8}  P {c['power']:.2f}"
        )
