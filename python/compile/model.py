"""L2 model zoo — the nine DNNs of the paper's evaluation (Tables 1/2/4).

Scaled-down same-topology stand-ins for the paper's networks (DESIGN.md
§Substitutions): each keeps the structural feature that stresses a distinct
AdaPT layer path — residual adds (ResNet), deep VGG stacks, fire modules
(SqueezeNet), dense concats (DenseNet), multi-branch concat (Inception),
grouped+depthwise conv with channel shuffle (ShuffleNet), LSTM recurrence,
VAE/GAN dense decoders.

Each builder returns a :class:`ModelDef`: the IR graph, parameter specs,
dataset binding and eval config. ``aot.py`` lowers every execution variant
of each model to HLO text and writes the graph verbatim into
``manifest.json`` for the Rust emulators.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

from . import nn
from .nn import GraphBuilder

# Eval/train batch shared by all AOT artifacts (static shapes).
BATCH = 32
IMG10 = 10  # classes for all synthetic image tasks
SEQ_LEN = 48
VOCAB = 512
EMBED = 32
LSTM_H = 64


@dataclasses.dataclass
class ModelDef:
    name: str
    kind: str  # cnn | lstm | vae | gan
    dataset: str
    input_shape: Tuple[int, ...]  # per-sample, no batch
    input_dtype: str  # f32 | i32
    graph: List[Dict[str, Any]]
    param_specs: List[Dict[str, Any]]
    n_scales: int
    out_dim: int
    loss: str  # ce | vae | none
    metric: str  # top1 | top5 | pixel | none
    table2: bool  # participates in the retraining experiment
    paper_row: str  # name of the paper model this stands in for

    @property
    def params_count(self) -> int:
        return nn.count_params(self.param_specs)

    @property
    def macs(self) -> int:
        return nn.count_macs(self.graph, self.input_shape)


def _res_block(g: GraphBuilder, x: int, cin: int, cout: int, stride: int, tag: str) -> int:
    c1 = g.conv2d(x, f"{tag}.c1", 3, 3, cin, cout, stride=stride, pad=1)
    r1 = g.relu(c1)
    c2 = g.conv2d(r1, f"{tag}.c2", 3, 3, cout, cout, stride=1, pad=1)
    if stride != 1 or cin != cout:
        sc = g.conv2d(x, f"{tag}.sc", 1, 1, cin, cout, stride=stride, pad=0)
    else:
        sc = x
    return g.relu(g.add(c2, sc))


def small_resnet() -> ModelDef:
    """ResNet50 stand-in: 3 stages of residual blocks on 32x32x3."""
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "stem", 3, 3, 3, 16, stride=1, pad=1))
    x = _res_block(g, x, 16, 16, 1, "s1b1")
    x = _res_block(g, x, 16, 32, 2, "s2b1")
    x = _res_block(g, x, 32, 32, 1, "s2b2")
    x = _res_block(g, x, 32, 64, 2, "s3b1")
    x = _res_block(g, x, 64, 64, 1, "s3b2")
    x = g.gap(x)
    g.linear(x, "fc", 64, IMG10)
    return ModelDef(
        "small_resnet", "cnn", "cifar_syn", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top1", True, "ResNet50",
    )


def small_vgg() -> ModelDef:
    """VGG19 stand-in: plain 3x3 stacks with pooling."""
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "c1a", 3, 3, 3, 32, pad=1))
    x = g.relu(g.conv2d(x, "c1b", 3, 3, 32, 32, pad=1))
    x = g.avgpool2(x)
    x = g.relu(g.conv2d(x, "c2a", 3, 3, 32, 64, pad=1))
    x = g.relu(g.conv2d(x, "c2b", 3, 3, 64, 64, pad=1))
    x = g.avgpool2(x)
    x = g.relu(g.conv2d(x, "c3a", 3, 3, 64, 128, pad=1))
    x = g.avgpool2(x)
    x = g.flatten(x)
    x = g.relu(g.linear(x, "fc1", 4 * 4 * 128, 128))
    g.linear(x, "fc2", 128, IMG10)
    return ModelDef(
        "small_vgg", "cnn", "cifar_syn", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top1", True, "VGG19",
    )


def _fire(g: GraphBuilder, x: int, cin: int, sq: int, ex: int, tag: str) -> int:
    s = g.relu(g.conv2d(x, f"{tag}.sq", 1, 1, cin, sq))
    e1 = g.relu(g.conv2d(s, f"{tag}.e1", 1, 1, sq, ex))
    e3 = g.relu(g.conv2d(s, f"{tag}.e3", 3, 3, sq, ex, pad=1))
    return g.concat([e1, e3])


def squeezenet_mini() -> ModelDef:
    """SqueezeNet stand-in: fire modules, conv classifier head."""
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "stem", 3, 3, 3, 32, stride=2, pad=1))
    x = _fire(g, x, 32, 8, 16, "f1")
    x = _fire(g, x, 32, 8, 16, "f2")
    x = g.avgpool2(x)
    x = _fire(g, x, 32, 16, 32, "f3")
    x = g.relu(g.conv2d(x, "head", 1, 1, 64, IMG10))
    g.gap(x)
    return ModelDef(
        "squeezenet_mini", "cnn", "imagenet_syn32", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top5", True, "SqueezeNet",
    )


def densenet_mini() -> ModelDef:
    """DenseNet121 stand-in: two dense blocks (growth 12) + transition."""
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "stem", 3, 3, 3, 16, pad=1))
    ch = 16
    for bi in range(2):
        for li in range(3):
            y = g.relu(g.conv2d(x, f"b{bi}l{li}", 3, 3, ch, 12, pad=1))
            x = g.concat([x, y])
            ch += 12
        if bi == 0:
            x = g.relu(g.conv2d(x, "trans", 1, 1, ch, ch // 2))
            ch //= 2
            x = g.avgpool2(x)
    x = g.gap(x)
    g.linear(x, "fc", ch, IMG10)
    return ModelDef(
        "densenet_mini", "cnn", "cifar_syn", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top1", False, "DenseNet121",
    )


def _inception_block(g: GraphBuilder, x: int, cin: int, c1: int, c3: int, c5: int, tag: str) -> int:
    b1 = g.relu(g.conv2d(x, f"{tag}.b1", 1, 1, cin, c1))
    b3 = g.relu(g.conv2d(x, f"{tag}.b3", 3, 3, cin, c3, pad=1))
    # 5x5 factored as two 3x3 (Inception-v3 style)
    b5a = g.relu(g.conv2d(x, f"{tag}.b5a", 3, 3, cin, c5, pad=1))
    b5 = g.relu(g.conv2d(b5a, f"{tag}.b5b", 3, 3, c5, c5, pad=1))
    return g.concat([b1, b3, b5])


def inception_mini() -> ModelDef:
    """Inception-v3 stand-in: factored multi-branch concat blocks."""
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "stem", 3, 3, 3, 16, stride=1, pad=1))
    x = _inception_block(g, x, 16, 8, 16, 8, "i1")  # -> 32ch
    x = g.avgpool2(x)
    x = _inception_block(g, x, 32, 16, 32, 16, "i2")  # -> 64ch
    x = g.avgpool2(x)
    x = g.gap(x)
    g.linear(x, "fc", 64, IMG10)
    return ModelDef(
        "inception_mini", "cnn", "imagenet_syn32", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top1", False, "Inceptionv3",
    )


def _shuffle_unit(g: GraphBuilder, x: int, cin: int, groups: int, tag: str) -> int:
    """ShuffleNet unit: grouped 1x1 -> shuffle -> depthwise 3x3 -> grouped 1x1,
    residual add. Exercises grouped + depthwise (separable) conv (§3.3.2)."""
    p1 = g.relu(g.conv2d(x, f"{tag}.p1", 1, 1, cin, cin, groups=groups))
    sh = g.channel_shuffle(p1, groups)
    dw = g.conv2d(sh, f"{tag}.dw", 3, 3, cin, cin, pad=1, groups=cin)
    p2 = g.conv2d(dw, f"{tag}.p2", 1, 1, cin, cin, groups=groups)
    return g.relu(g.add(p2, x))


def shufflenet_mini() -> ModelDef:
    g = GraphBuilder((32, 32, 3))
    x = g.relu(g.conv2d(0, "stem", 3, 3, 3, 32, stride=2, pad=1))
    x = _shuffle_unit(g, x, 32, 4, "u1")
    x = _shuffle_unit(g, x, 32, 4, "u2")
    x = g.avgpool2(x)
    x = _shuffle_unit(g, x, 32, 4, "u3")
    x = g.gap(x)
    g.linear(x, "fc", 32, IMG10)
    return ModelDef(
        "shufflenet_mini", "cnn", "imagenet_syn32", (32, 32, 3), "f32",
        g.nodes, g.param_specs, g.n_scales, IMG10, "ce", "top1", False, "ShuffleNet",
    )


def lstm_imdb() -> ModelDef:
    """LSTM text classifier (IMDB stand-in): embed -> LSTM -> linear, 2-way."""
    g = GraphBuilder((SEQ_LEN,))
    x = g.embedding(0, "embed", VOCAB, EMBED)
    h = g.lstm(x, "lstm", EMBED, LSTM_H)
    g.linear(h, "fc", LSTM_H, 2)
    return ModelDef(
        "lstm_imdb", "lstm", "imdb_syn", (SEQ_LEN,), "i32",
        g.nodes, g.param_specs, g.n_scales, 2, "ce", "top1", True, "LSTM-IMDB",
    )


def vae_mnist() -> ModelDef:
    """MLP VAE (MNIST stand-in). Deterministic z = mu at inference/QAT
    (DESIGN.md §Substitutions); output = sigmoid reconstruction 28x28."""
    g = GraphBuilder((28, 28, 1))
    x = g.flatten(0)
    h = g.relu(g.linear(x, "enc1", 784, 128))
    mulv = g.linear(h, "enc2", 128, 64)  # [mu | logvar]
    mu = g.slice_last(mulv, 0, 32)
    d = g.relu(g.linear(mu, "dec1", 32, 128))
    out = g.sigmoid(g.linear(d, "dec2", 128, 784))
    g.reshape(out, (28, 28, 1))
    return ModelDef(
        "vae_mnist", "vae", "mnist_syn", (28, 28, 1), "f32",
        g.nodes, g.param_specs, g.n_scales, 784, "vae", "pixel", True, "VAE-MNIST",
    )


def gan_fashion() -> ModelDef:
    """GAN generator (Fashion-MNIST stand-in): z(64) -> 28x28 image.
    Table-4 timing workload (forward-only, like the paper's GAN row)."""
    g = GraphBuilder((64,))
    h = g.relu(g.linear(0, "g1", 64, 128))
    h = g.relu(g.linear(h, "g2", 128, 256))
    out = g.tanh(g.linear(h, "g3", 256, 784))
    g.reshape(out, (28, 28, 1))
    return ModelDef(
        "gan_fashion", "gan", "noise64", (64,), "f32",
        g.nodes, g.param_specs, g.n_scales, 784, "none", "none", False, "Fashion-GAN",
    )


ZOO = {
    m().name: m
    for m in [
        small_resnet, small_vgg, squeezenet_mini, densenet_mini,
        inception_mini, shufflenet_mini, lstm_imdb, vae_mnist, gan_fashion,
    ]
}


def build(name: str) -> ModelDef:
    return ZOO[name]()


def table2_models() -> List[str]:
    return [n for n in ZOO if build(n).table2]


def all_models() -> List[str]:
    return list(ZOO)
