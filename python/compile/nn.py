"""L2 graph IR + JAX interpreter for the AdaPT-RS model zoo.

A model is a flat SSA graph of typed nodes (dicts), plus a positional
parameter list. The SAME graph is serialized into ``artifacts/manifest.json``
and re-interpreted by the Rust baseline/optimized emulators
(``rust/src/graph``, ``rust/src/emulator``) — one IR, three executors
(JAX/XLA via AOT artifacts, Rust scalar baseline, Rust blocked engine),
which is what makes the Table-4 three-way comparison apples-to-apples.

Node schema::

    {"id": int, "op": str, "inputs": [ids], "attrs": {...}, "params": [pidx]}

Node 0 is the network input; the last node is the output. ``params`` holds
indices into the positional param list (weights first, then bias).

Execution modes (``Ctx.mode``):

* ``fp32``    — plain float forward (the paper's "Native" column);
* ``approx``  — quantize + route every inner product through the ACU
  (LUT-gather at 8-bit, functional trunc at 12-bit). The paper's "8bit"
  exact-quantized column is this same path fed the ``exact8`` LUT;
* ``acts``    — fp32 forward that also collects every quantizable layer's
  input tensor (the calibration taps of Fig. 1);
* QAT: ``approx`` with ``ste=True`` wraps each ACU matmul in a
  straight-through custom_vjp so gradients flow through fake-quantized
  exact matmuls (§3.2.1) while the forward pass sees true ACU products.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import quantize as Q
from .kernels import approx_matmul as AK
from .kernels import ref as KR

# Route a matmul to the blocked Pallas kernel only when it is big enough to
# amortize the grid machinery; tiny GEMMs (depthwise groups, gate slices)
# take the plain-jnp gather, which lowers to the same HLO gather op.
PALLAS_MIN_FLOPS = 1 << 19


# --------------------------------------------------------------------------
# Execution context
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Ctx:
    """Per-forward execution configuration."""

    mode: str = "fp32"  # fp32 | approx | acts
    bits: int = 8
    acu: str = "lut"  # lut | func
    trunc_k: int = 4  # functional-ACU truncation (12-bit path)
    lut: Optional[jnp.ndarray] = None
    act_scales: Optional[jnp.ndarray] = None  # f32[L]
    ste: bool = False  # QAT straight-through backward
    taps: Optional[List[jnp.ndarray]] = None  # filled in mode=="acts"

    def scale(self, idx: int) -> jnp.ndarray:
        assert self.act_scales is not None, "approx mode needs act_scales"
        return self.act_scales[idx]


# --------------------------------------------------------------------------
# ACU matmul core (shared by conv / linear / lstm)
# --------------------------------------------------------------------------


def _acu_matmul_int(xq: jnp.ndarray, wq: jnp.ndarray, ctx: Ctx) -> jnp.ndarray:
    """Integer ACU matmul dispatch: Pallas kernel for big GEMMs, jnp-gather
    oracle path for small ones. Both produce identical integers."""
    m, k = xq.shape
    n = wq.shape[1]
    big = m * k * n >= PALLAS_MIN_FLOPS
    if ctx.acu == "lut":
        assert ctx.lut is not None, "lut ACU needs ctx.lut"
        if big:
            return AK.lut_matmul(xq, wq, ctx.lut)
        return KR.lut_matmul_ref(xq, wq, ctx.lut)
    if big:
        return AK.functional_matmul(xq, wq, trunc_k=ctx.trunc_k)
    return KR.functional_matmul_ref(xq, wq, trunc_k=ctx.trunc_k)


def _approx_matmul_fwd_val(
    x2d: jnp.ndarray, w: jnp.ndarray, a_scale: jnp.ndarray, ctx: Ctx
) -> jnp.ndarray:
    """Dequantized ACU matmul value: dq( acu(q(x), q(w)) )."""
    w_scale = Q.weight_scale_per_col(w, ctx.bits)
    xq = Q.quantize(x2d, a_scale, ctx.bits)
    wq = Q.quantize(w, w_scale[None, :], ctx.bits)
    acc = _acu_matmul_int(xq, wq, ctx)
    return acc.astype(jnp.float32) * (a_scale * w_scale)[None, :]


@functools.lru_cache(maxsize=None)
def _ste_matmul_for(bits: int, acu: str, trunc_k: int, use_lut: bool):
    """Build a custom_vjp ACU matmul for a static (bits, acu, trunc_k) cfg.

    Forward: true ACU products. Backward: gradients of the *exact* matmul
    over fake-quantized operands with clipped-STE through the quantizers —
    the paper's fake-quant training scheme.
    """

    def make_ctx(lut):
        return Ctx(mode="approx", bits=bits, acu=acu, trunc_k=trunc_k, lut=lut)

    @jax.custom_vjp
    def ste_matmul(x2d, w, a_scale, lut):
        return _approx_matmul_fwd_val(x2d, w, a_scale, make_ctx(lut))

    def fwd(x2d, w, a_scale, lut):
        out = _approx_matmul_fwd_val(x2d, w, a_scale, make_ctx(lut))
        return out, (x2d, w, a_scale, lut)

    def bwd(res, g):
        x2d, w, a_scale, lut = res
        w_scale = Q.weight_scale_per_col(w, bits)
        fx = Q.fake_quant(x2d, a_scale, bits)
        fw = Q.fake_quant(w, w_scale[None, :], bits)
        # clipped STE masks
        x_mask = (jnp.abs(x2d) <= a_scale * float(Q.qmax_for(bits))).astype(g.dtype)
        dx = (g @ fw.T) * x_mask
        dw = fx.T @ g
        return dx, dw, jnp.zeros_like(a_scale), jnp.zeros_like(lut)

    ste_matmul.defvjp(fwd, bwd)
    if use_lut:
        return ste_matmul
    # functional variant has no LUT operand; close over a dummy.
    dummy = jnp.zeros((2, 2), jnp.int32)
    return lambda x2d, w, a_scale: ste_matmul(x2d, w, a_scale, dummy)


def dense_core(
    x2d: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    scale_idx: int,
    ctx: Ctx,
) -> jnp.ndarray:
    """The one quantizable primitive: (M,K)@(K,N)+b under the active mode."""
    if ctx.mode == "acts":
        assert ctx.taps is not None
        ctx.taps.append(x2d)
    if ctx.mode in ("fp32", "acts"):
        out = x2d @ w
    else:
        a_scale = ctx.scale(scale_idx)
        if ctx.ste:
            fn = _ste_matmul_for(ctx.bits, ctx.acu, ctx.trunc_k, ctx.acu == "lut")
            out = fn(x2d, w, a_scale, ctx.lut) if ctx.acu == "lut" else fn(
                x2d, w, a_scale
            )
        else:
            out = _approx_matmul_fwd_val(x2d, w, a_scale, ctx)
    if b is not None:
        out = out + b[None, :]
    return out


# --------------------------------------------------------------------------
# Spatial helpers
# --------------------------------------------------------------------------


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """NHWC -> (N, Ho, Wo, kh*kw*C) patches; feature order (dy, dx, c).

    The Rust mirror (``tensor::im2col``) uses the identical ordering; the
    weight tensor (kh, kw, cin, cout) flattens to (kh*kw*cin, cout) in the
    same (dy, dx, c) order, so patches @ w_flat == conv2d.
    """
    n, h, w_, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w_ + 2 * pad - kw) // stride + 1
    cols = [
        xp[:, dy : dy + ho * stride : stride, dx : dx + wo * stride : stride, :]
        for dy in range(kh)
        for dx in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def conv2d_forward(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int,
    pad: int,
    groups: int,
    scale_idx: int,
    ctx: Ctx,
) -> jnp.ndarray:
    """Grouped 2-D convolution as im2col + ACU GEMM (paper §3.3.1/Fig. 3).

    x (N,H,W,Cin), w (kh,kw,Cin/groups,Cout), b (Cout) -> (N,Ho,Wo,Cout).
    All groups share the activation scale (one tensor, one scale); weight
    scales are per output channel inside each group's GEMM.
    """
    n, _, _, cin = x.shape
    kh, kw, cin_g, cout = w.shape
    assert cin_g * groups == cin, (w.shape, cin, groups)
    cout_g = cout // groups

    # Collect the calibration tap / quantize ONCE on the conv input — the
    # scale belongs to the layer input, not to each group's patch matrix.
    if ctx.mode == "acts":
        assert ctx.taps is not None
        ctx.taps.append(x.reshape(-1, cin))

    outs = []
    for g in range(groups):
        xg = x[..., g * cin_g : (g + 1) * cin_g]
        wg = w[..., g * cout_g : (g + 1) * cout_g]
        patches = im2col(xg, kh, kw, stride, pad)
        nb, ho, wo, kf = patches.shape
        p2 = patches.reshape(nb * ho * wo, kf)
        w2 = wg.reshape(kh * kw * cin_g, cout_g)
        bg = b[g * cout_g : (g + 1) * cout_g]
        # dense_core in acts mode would tap p2; we already tapped x, so run
        # the group GEMMs in plain fp32 when collecting.
        if ctx.mode == "acts":
            o2 = p2 @ w2 + bg[None, :]
        else:
            o2 = dense_core(p2, w2, bg, scale_idx, ctx)
        outs.append(o2.reshape(nb, ho, wo, cout_g))
    return outs[0] if groups == 1 else jnp.concatenate(outs, axis=-1)


def avgpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 average pool, NHWC. Odd trailing rows/cols dropped."""
    n, h, w, c = x.shape
    ho, wo = h // 2, w // 2
    x = x[:, : ho * 2, : wo * 2, :].reshape(n, ho, 2, wo, 2, c)
    return jnp.mean(x, axis=(2, 4))


def lstm_forward(
    xs: jnp.ndarray,
    wx: jnp.ndarray,
    wh: jnp.ndarray,
    b: jnp.ndarray,
    scale_x: int,
    scale_h: int,
    ctx: Ctx,
) -> jnp.ndarray:
    """LSTM over (N, T, In) -> final hidden state (N, H). Gate order i,f,g,o.

    Both the input and recurrent GEMMs route through the ACU (§3.3.4: the
    RNN layers "utilize our custom Linear layer thus making [them]
    approximation compatible"). In ``acts`` mode the taps are the
    time-flattened x and h trajectories.
    """
    n, t, _ = xs.shape
    hsz = wh.shape[0]

    if ctx.mode == "acts":
        # Tap x over all timesteps now; tap the fp32 h trajectory after the
        # scan below (h depends on the forward itself, so calibrate on the
        # fp32 trajectory, as the paper does with its fp32 histogram pass).
        assert ctx.taps is not None
        ctx.taps.append(xs.reshape(n * t, -1))
        tap_h: List[jnp.ndarray] = []

    def step(carry, x_t):
        h, c = carry
        if ctx.mode in ("fp32", "acts"):
            gates = x_t @ wx + h @ wh + b[None, :]
        else:
            gx = dense_core(x_t, wx, None, scale_x, ctx)
            gh = dense_core(h, wh, None, scale_h, ctx)
            gates = gx + gh + b[None, :]
        i = jax.nn.sigmoid(gates[:, 0 * hsz : 1 * hsz])
        f = jax.nn.sigmoid(gates[:, 1 * hsz : 2 * hsz])
        g = jnp.tanh(gates[:, 2 * hsz : 3 * hsz])
        o = jax.nn.sigmoid(gates[:, 3 * hsz : 4 * hsz])
        c2 = f * c + i * g
        h2 = o * jnp.tanh(c2)
        return (h2, c2), h2

    h0 = jnp.zeros((n, hsz), jnp.float32)
    c0 = jnp.zeros((n, hsz), jnp.float32)
    if ctx.mode in ("fp32", "acts"):
        (h, _), hs = jax.lax.scan(step, (h0, c0), jnp.swapaxes(xs, 0, 1))
        if ctx.mode == "acts":
            ctx.taps.append(jnp.swapaxes(hs, 0, 1).reshape(n * t, hsz))
        return h
    # approx path: python loop (T static) — pallas_call inside lax.scan
    # would re-trace per step anyway under interpret mode.
    h, c = h0, c0
    for ti in range(t):
        (h, c), _ = step((h, c), xs[:, ti, :])
    return h


# --------------------------------------------------------------------------
# Graph interpreter
# --------------------------------------------------------------------------


def forward(
    graph: List[Dict[str, Any]],
    params: Sequence[jnp.ndarray],
    x: jnp.ndarray,
    ctx: Ctx,
) -> jnp.ndarray:
    """Execute the IR. Returns the last node's value."""
    vals: Dict[int, jnp.ndarray] = {0: x}
    for node in graph:
        nid = node["id"]
        if nid == 0:
            continue
        op = node["op"]
        at = node.get("attrs", {})
        ins = [vals[i] for i in node.get("inputs", [])]
        ps = [params[i] for i in node.get("params", [])]
        if op == "conv2d":
            v = conv2d_forward(
                ins[0], ps[0], ps[1], at["stride"], at["pad"], at["groups"],
                at["scale_idx"], ctx,
            )
        elif op == "linear":
            v = dense_core(ins[0], ps[0], ps[1], at["scale_idx"], ctx)
        elif op == "lstm":
            v = lstm_forward(
                ins[0], ps[0], ps[1], ps[2], at["scale_idx"], at["scale_idx2"], ctx
            )
        elif op == "embedding":
            v = ps[0][ins[0].astype(jnp.int32)]
        elif op == "relu":
            v = jax.nn.relu(ins[0])
        elif op == "sigmoid":
            v = jax.nn.sigmoid(ins[0])
        elif op == "tanh":
            v = jnp.tanh(ins[0])
        elif op == "avgpool2":
            v = avgpool2(ins[0])
        elif op == "gap":
            v = jnp.mean(ins[0], axis=(1, 2))
        elif op == "flatten":
            v = ins[0].reshape(ins[0].shape[0], -1)
        elif op == "add":
            v = ins[0] + ins[1]
        elif op == "concat":
            v = jnp.concatenate(ins, axis=-1)
        elif op == "channel_shuffle":
            g = at["groups"]
            n_, h_, w_, c_ = ins[0].shape
            v = (
                ins[0]
                .reshape(n_, h_, w_, g, c_ // g)
                .swapaxes(3, 4)
                .reshape(n_, h_, w_, c_)
            )
        elif op == "slice_last":
            v = ins[0][..., at["start"] : at["end"]]
        elif op == "reshape":
            v = ins[0].reshape((ins[0].shape[0],) + tuple(at["shape"]))
        else:
            raise ValueError(f"unknown op {op!r}")
        vals[nid] = v
    return vals[graph[-1]["id"]]


# --------------------------------------------------------------------------
# Graph builder
# --------------------------------------------------------------------------


class GraphBuilder:
    """Tiny helper to author IR graphs + param specs + scale bookkeeping."""

    def __init__(self, input_shape: Tuple[int, ...]):
        self.nodes: List[Dict[str, Any]] = [
            {"id": 0, "op": "input", "inputs": [], "attrs": {"shape": list(input_shape)}}
        ]
        self.param_specs: List[Dict[str, Any]] = []
        self.n_scales = 0
        self._next = 1

    def _param(self, name: str, shape: Tuple[int, ...], init: str, fan_in: int) -> int:
        self.param_specs.append(
            {"name": name, "shape": list(shape), "init": init, "fan_in": fan_in}
        )
        return len(self.param_specs) - 1

    def _node(self, op: str, inputs: List[int], attrs=None, params=None) -> int:
        nid = self._next
        self._next += 1
        self.nodes.append(
            {
                "id": nid,
                "op": op,
                "inputs": inputs,
                "attrs": attrs or {},
                "params": params or [],
            }
        )
        return nid

    def conv2d(self, x, name, kh, kw, cin, cout, stride=1, pad=0, groups=1) -> int:
        fan_in = kh * kw * cin // groups
        wp = self._param(f"{name}.w", (kh, kw, cin // groups, cout), "he", fan_in)
        bp = self._param(f"{name}.b", (cout,), "zeros", fan_in)
        sidx = self.n_scales
        self.n_scales += 1
        return self._node(
            "conv2d",
            [x],
            {
                "kh": kh, "kw": kw, "cin": cin, "cout": cout,
                "stride": stride, "pad": pad, "groups": groups,
                "scale_idx": sidx, "name": name,
            },
            [wp, bp],
        )

    def linear(self, x, name, din, dout) -> int:
        wp = self._param(f"{name}.w", (din, dout), "he", din)
        bp = self._param(f"{name}.b", (dout,), "zeros", din)
        sidx = self.n_scales
        self.n_scales += 1
        return self._node(
            "linear", [x],
            {"din": din, "dout": dout, "scale_idx": sidx, "name": name},
            [wp, bp],
        )

    def lstm(self, x, name, din, hidden) -> int:
        wxp = self._param(f"{name}.wx", (din, 4 * hidden), "glorot", din)
        whp = self._param(f"{name}.wh", (hidden, 4 * hidden), "glorot", hidden)
        bp = self._param(f"{name}.b", (4 * hidden,), "zeros", din)
        sx = self.n_scales
        sh = self.n_scales + 1
        self.n_scales += 2
        return self._node(
            "lstm", [x],
            {"din": din, "hidden": hidden, "scale_idx": sx, "scale_idx2": sh,
             "name": name},
            [wxp, whp, bp],
        )

    def embedding(self, x, name, vocab, dim) -> int:
        tp = self._param(f"{name}.table", (vocab, dim), "embed", dim)
        return self._node("embedding", [x], {"vocab": vocab, "dim": dim}, [tp])

    def relu(self, x):
        return self._node("relu", [x])

    def sigmoid(self, x):
        return self._node("sigmoid", [x])

    def tanh(self, x):
        return self._node("tanh", [x])

    def avgpool2(self, x):
        return self._node("avgpool2", [x])

    def gap(self, x):
        return self._node("gap", [x])

    def flatten(self, x):
        return self._node("flatten", [x])

    def add(self, a, b):
        return self._node("add", [a, b])

    def concat(self, xs):
        return self._node("concat", list(xs))

    def channel_shuffle(self, x, groups):
        return self._node("channel_shuffle", [x], {"groups": groups})

    def slice_last(self, x, start, end):
        return self._node("slice_last", [x], {"start": start, "end": end})

    def reshape(self, x, shape):
        return self._node("reshape", [x], {"shape": list(shape)})


def init_params(specs: List[Dict[str, Any]], seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic param init from the spec list (he / glorot / zeros)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i, sp in enumerate(specs):
        k = jax.random.fold_in(key, i)
        shape = tuple(sp["shape"])
        fi = max(sp["fan_in"], 1)
        if sp["init"] == "zeros":
            v = jnp.zeros(shape, jnp.float32)
        elif sp["init"] == "he":
            v = jax.random.normal(k, shape, jnp.float32) * (2.0 / fi) ** 0.5
        elif sp["init"] == "glorot":
            v = jax.random.normal(k, shape, jnp.float32) * (1.0 / fi) ** 0.5
        elif sp["init"] == "embed":
            v = jax.random.normal(k, shape, jnp.float32) * 0.1
        else:
            raise ValueError(sp["init"])
        out.append(v)
    return out


# --------------------------------------------------------------------------
# Analytic specs (Table 1)
# --------------------------------------------------------------------------


def count_params(specs: List[Dict[str, Any]]) -> int:
    total = 0
    for sp in specs:
        n = 1
        for d in sp["shape"]:
            n *= d
        total += n
    return total


def count_macs(graph: List[Dict[str, Any]], input_shape: Tuple[int, ...]) -> int:
    """MAC count per sample, walking the IR with shape propagation."""
    shapes: Dict[int, Tuple[int, ...]] = {0: tuple(input_shape)}
    macs = 0
    for node in graph:
        nid, op, at = node["id"], node["op"], node.get("attrs", {})
        if nid == 0:
            continue
        ins = [shapes[i] for i in node["inputs"]]
        if op == "conv2d":
            h, w = ins[0][0], ins[0][1]
            ho = (h + 2 * at["pad"] - at["kh"]) // at["stride"] + 1
            wo = (w + 2 * at["pad"] - at["kw"]) // at["stride"] + 1
            macs += (
                ho * wo * at["cout"] * at["kh"] * at["kw"] * at["cin"] // at["groups"]
            )
            shapes[nid] = (ho, wo, at["cout"])
        elif op == "linear":
            macs += at["din"] * at["dout"]
            shapes[nid] = ins[0][:-1] + (at["dout"],)
        elif op == "lstm":
            t = ins[0][0]
            macs += t * 4 * at["hidden"] * (at["din"] + at["hidden"])
            shapes[nid] = (at["hidden"],)
        elif op == "embedding":
            shapes[nid] = ins[0] + (at["dim"],)
        elif op == "avgpool2":
            h, w, c = ins[0]
            shapes[nid] = (h // 2, w // 2, c)
        elif op == "gap":
            shapes[nid] = (ins[0][-1],)
        elif op == "flatten":
            n = 1
            for d in ins[0]:
                n *= d
            shapes[nid] = (n,)
        elif op == "concat":
            c = sum(s[-1] for s in ins)
            shapes[nid] = ins[0][:-1] + (c,)
        elif op == "slice_last":
            shapes[nid] = ins[0][:-1] + (at["end"] - at["start"],)
        elif op == "reshape":
            shapes[nid] = tuple(at["shape"])
        else:  # elementwise / add / shuffle keep shape
            shapes[nid] = ins[0]
    return macs
