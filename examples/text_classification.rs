//! Text classification with the approximate LSTM (§3.3.4).
//!
//! The RNN path is what distinguishes AdaPT from the CNN-only frameworks
//! in Table 3: both the input and recurrent GEMMs of the LSTM route
//! through the ACU. This example runs the IMDB-stand-in sentiment task
//! end to end and prints per-variant accuracy.

use adapt::coordinator::experiments::hyper_for;
use adapt::coordinator::ops::{self, InferVariant, ModelState, TrainVariant};
use adapt::data::{self, Sizes};
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::{weights, Runtime};
use adapt::util::fmt;

fn main() -> anyhow::Result<()> {
    let model = "lstm_imdb";
    let mut rt = Runtime::open(&adapt::artifacts_dir())?;
    let m = rt.manifest.model(model)?.clone();
    let sizes = Sizes::default();
    let ds = data::load(&m.dataset, &sizes);
    let hy = hyper_for(model);

    println!("== {model}: seq len {}, binary sentiment ==", m.input_shape[0]);

    let mut st = ModelState::load(&rt, model, &weights::initial_path(&rt.manifest.root, &m))?;
    let tr = ops::train(&mut rt, &mut st, TrainVariant::Fp32, &ds,
        hy.pretrain_steps, hy.pretrain_lr, None, 0)?;
    println!("pre-train: loss {:.3} -> {:.3} in {}", tr.first_loss, tr.last_loss, fmt::dur(tr.wall));

    let fp32 = ops::evaluate(&mut rt, &st, InferVariant::Fp32, &ds, None, None)?;
    ops::calibrate(&mut rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;

    let exact_lut = ops::load_lut_lit(&rt, "exact8")?;
    let q = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&exact_lut), None)?;
    let acu_lut = ops::load_lut_lit(&rt, "mul8s_1l2h_like")?;
    let ap = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&acu_lut), None)?;

    let tr2 = ops::train(&mut rt, &mut st, TrainVariant::QatLut, &ds,
        hy.qat_steps, hy.qat_lr, Some(&acu_lut), 0)?;
    let rec = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&acu_lut), None)?;

    println!("fp32:              {}", fmt::pct(fp32.accuracy));
    println!("8-bit exact:       {}", fmt::pct(q.accuracy));
    println!("8-bit mul8s-like:  {}", fmt::pct(ap.accuracy));
    println!("retrained ({}):  {}", fmt::dur(tr2.wall), fmt::pct(rec.accuracy));

    // Both LSTM GEMMs are approximate — show their distinct scales
    // (scale_idx for the x path, scale_idx2 for the recurrent path).
    let scales = st.act_scales.as_ref().unwrap();
    println!("{} activation scales calibrated (incl. separate x / h LSTM paths)",
        scales.len());
    Ok(())
}
