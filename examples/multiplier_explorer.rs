//! ACU design-space exploration (ALWANN-style): accuracy vs error profile
//! vs power proxy across the whole multiplier library, plus a mixed-
//! precision demo of the graph re-transform tool (§3.4).
//!
//! ```bash
//! cargo run --release --example multiplier_explorer -- [model]
//! ```

use adapt::coordinator::experiments::ensure_pretrained;
use adapt::coordinator::ops::{self, InferVariant};
use adapt::data::{self, Sizes};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Policy};
use adapt::lut::LutRegistry;
use adapt::metrics;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;
use adapt::util::fmt;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "small_vgg".into());
    let mut rt = Runtime::open(&adapt::artifacts_dir())?;
    let sizes = Sizes::default();
    let mut st = ensure_pretrained(&mut rt, &model, &sizes, 1.0, false)?;
    let ds = data::load(&st.model.dataset.clone(), &sizes);
    ops::calibrate(&mut rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;

    let fp32 = ops::evaluate(&mut rt, &st, InferVariant::Fp32, &ds, None, Some(4))?;
    println!("== ACU sweep on {model} (fp32 = {}) ==\n", fmt::pct(fp32.accuracy));
    let mut rows = Vec::new();
    let acus: Vec<String> = rt.manifest.luts.keys().cloned().collect();
    for acu in &acus {
        let meta = rt.manifest.luts[acu].clone();
        let lit = ops::load_lut_lit(&rt, acu)?;
        let ev = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&lit), Some(4))?;
        rows.push(vec![
            acu.clone(),
            format!("{:.3}%", meta.mre_pct),
            format!("{:.2}x", meta.power),
            fmt::pct(ev.accuracy),
            format!("{:+.2} pts", 100.0 * (ev.accuracy - fp32.accuracy)),
        ]);
    }
    println!("{}", fmt::table(&["ACU", "MRE", "power", "accuracy", "vs fp32"], &rows));

    // ---- Mixed precision via the re-transform tool ----------------------
    // Keep the most error-sensitive layers exact (stem + classifier head),
    // approximate everything else — a per-layer policy the paper's plugin
    // exposes as "enable/disable per layer".
    println!("\n== mixed-precision re-transform on {model} (Rust engine) ==");
    let m = rt.manifest.model(&model)?.clone();
    let params = st.params_tensors()?;
    let scales = st.act_scales.clone().unwrap();
    let luts = LutRegistry::from_manifest(&rt.manifest);

    let quantizable: Vec<String> = m
        .nodes
        .iter()
        .filter_map(|n| n.op.layer_name().map(|s| s.to_string()))
        .collect();
    let first = quantizable.first().cloned().unwrap_or_default();
    let last = quantizable.last().cloned().unwrap_or_default();

    let acu = "mul8s_1l2h_like";
    let policies = [
        ("all approx", Policy::all(LayerMode::lut(acu))),
        (
            "stem+head exact",
            Policy::all(LayerMode::lut(acu))
                .with_override(&first, LayerMode::Fp32)
                .with_override(&last, LayerMode::Fp32),
        ),
        (
            "stem exact8, head DRUM (heterogeneous)",
            Policy::all(LayerMode::lut(acu))
                .with_acu(&first, "exact8")
                .with_acu(&last, "drum8_6"),
        ),
        (
            "head 12-bit functional",
            Policy::all(LayerMode::lut(acu)).with_override(
                &last,
                LayerMode::ApproxFunc { bits: 12, trunc_k: 4 },
            ),
        ),
    ];
    let bs = rt.manifest.batch;
    let mut rows = Vec::new();
    for (label, policy) in &policies {
        let plan = retransform(&m, policy);
        let exec = Executor::new(
            &m,
            params.clone(),
            plan,
            adapt::coordinator::ops::rescale_for_bits(&scales, 8),
            &luts,
            Style::Optimized { threads: 2 },
        )?;
        let mut hits = 0.0;
        let nb = 2;
        for bi in 0..nb {
            let out = exec.forward(Value::F(ds.eval.batch_tensor(bi, bs)))?;
            hits += metrics::top1(&out.data, m.out_dim, &ds.eval.batch_labels(bi, bs));
        }
        rows.push(vec![label.to_string(), fmt::pct(hits / nb as f64)]);
    }
    println!("{}", fmt::table(&["policy", "accuracy"], &rows));
    Ok(())
}
