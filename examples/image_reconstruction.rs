//! Image reconstruction: approximate VAE + GAN generator (§5's third
//! application domain).
//!
//! * VAE: trains on the MNIST stand-in, then reconstructs through the
//!   approximate multiplier and prints pixel accuracy + an ASCII render
//!   of one (input, reconstruction) pair.
//! * GAN: runs the Fashion-stand-in generator forward through the exact
//!   and approximate paths (the paper's GAN row is forward-only).

use adapt::coordinator::experiments::ensure_pretrained;
use adapt::coordinator::ops::{self, InferVariant};
use adapt::data::{self, Sizes};
use adapt::metrics;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;
use adapt::util::fmt;

fn ascii28(img: &[f32]) -> String {
    let ramp = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut out = String::new();
    for y in (0..28).step_by(2) {
        for x in 0..28 {
            let v = (img[y * 28 + x] + img[(y + 1) * 28 + x]) / 2.0;
            out.push(ramp[((v.clamp(0.0, 1.0)) * 9.0) as usize]);
        }
        out.push('\n');
    }
    out
}

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::open(&adapt::artifacts_dir())?;
    let sizes = Sizes::default();

    // ---- VAE ----------------------------------------------------------
    let mut st = ensure_pretrained(&mut rt, "vae_mnist", &sizes, 1.0, false)?;
    let ds = data::load("mnist_syn", &sizes);
    ops::calibrate(&mut rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;

    let bs = rt.manifest.batch;
    let x = ops::batch_input(&st.model, &ds.eval, 0, bs)?;
    let target = ds.eval.batch_f(0, bs);

    let acu_lut = ops::load_lut_lit(&rt, "mul8s_1l2h_like")?;
    let fp = ops::infer_batch(&mut rt, &st, InferVariant::Fp32, &x, None)?;
    let ap = ops::infer_batch(&mut rt, &st, InferVariant::ApproxLut, &x, Some(&acu_lut))?;

    println!("== vae_mnist reconstruction ==");
    println!("fp32 pixel accuracy:   {}", fmt::pct(metrics::pixel_accuracy(&fp, &target)));
    println!("approx pixel accuracy: {}", fmt::pct(metrics::pixel_accuracy(&ap, &target)));
    println!("\ninput:                        approx reconstruction:");
    let inp = ascii28(&target[..784]);
    let rec = ascii28(&ap[..784]);
    for (a, b) in inp.lines().zip(rec.lines()) {
        println!("{a}  {b}");
    }

    // ---- GAN generator (timing-style forward) --------------------------
    let mut gst = ensure_pretrained(&mut rt, "gan_fashion", &sizes, 1.0, false)?;
    let gds = data::load("noise64", &sizes);
    ops::calibrate(&mut rt, &mut gst, &gds, 2, CalibratorKind::Percentile, 0.999)?;
    let z = ops::batch_input(&gst.model, &gds.eval, 0, bs)?;
    let t0 = std::time::Instant::now();
    let gen_fp = ops::infer_batch(&mut rt, &gst, InferVariant::Fp32, &z, None)?;
    let t_fp = t0.elapsed();
    let t0 = std::time::Instant::now();
    let gen_ap = ops::infer_batch(&mut rt, &gst, InferVariant::ApproxLut, &z, Some(&acu_lut))?;
    let t_ap = t0.elapsed();
    // tanh outputs in [-1, 1]; compare the two paths.
    let mut max_dev = 0f32;
    for (a, b) in gen_fp.iter().zip(&gen_ap) {
        max_dev = max_dev.max((a - b).abs());
    }
    println!("\n== gan_fashion generator ==");
    println!("fp32 forward {} / approx forward {} (batch {bs})",
        fmt::dur(t_fp), fmt::dur(t_ap));
    println!("max |fp32 - approx| over generated pixels: {max_dev:.4} (range 2.0)");
    Ok(())
}
