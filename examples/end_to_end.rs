//! End-to-end validation driver (EXPERIMENTS.md records a full run).
//!
//! Proves all three layers compose on a real small workload:
//!
//! 1. loads the AOT artifacts (L2/L1 products) through the PJRT runtime,
//! 2. pre-trains every Table-2 model from scratch on the synthetic
//!    datasets via the AOT train-step executables, logging loss curves,
//! 3. regenerates Table 1, Table 2 (both ACU operating points), the ACU
//!    ablation and Table 4 (all four engines),
//! 4. exercises the dynamic-batching inference engine,
//! 5. writes everything under artifacts/results/.
//!
//! ```bash
//! cargo run --release --example end_to_end            # full (~20 min)
//! cargo run --release --example end_to_end -- --quick # smoke (~3 min)
//! ```

use std::time::Duration;

use adapt::coordinator::engine::{EngineConfig, InferenceEngine};
use adapt::coordinator::experiments::{self, Table2Config, Table4Config};
use adapt::coordinator::features;
use adapt::coordinator::ops::InferVariant;
use adapt::data::Sizes;
use adapt::runtime::Runtime;
use adapt::util::fmt;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let t_start = std::time::Instant::now();
    let artifacts = adapt::artifacts_dir();
    let mut rt = Runtime::open(&artifacts)?;
    println!("== AdaPT-RS end-to-end validation ==");
    println!("artifacts: {} ({} models, {} LUTs)\n",
        artifacts.display(), rt.manifest.models.len(), rt.manifest.luts.len());

    // ---- Table 1 --------------------------------------------------------
    println!("--- Table 1: model specifications ---\n{}", experiments::table1(&rt));

    // ---- Table 2 (pre-trains on demand, snapshots under artifacts/trained)
    let sizes = if quick { Sizes { n_train: 512, n_eval: 128 } } else { Sizes::default() };
    let t2 = Table2Config {
        sizes,
        steps_scale: if quick { 0.25 } else { 1.0 },
        eval_batches: if quick { Some(2) } else { None },
        verbose: true,
        ..Table2Config::default()
    };
    println!("--- Table 2: quantization + retraining ---\n{}", experiments::table2(&mut rt, &t2)?);

    // ---- Table 4 --------------------------------------------------------
    let t4 = Table4Config {
        sizes,
        eval_batches: if quick { 1 } else { 2 },
        verbose: true,
        ..Table4Config::default()
    };
    println!("--- Table 4: emulation wall-clock ---\n{}", experiments::table4(&mut rt, &t4)?);

    // ---- ACU ablation ----------------------------------------------------
    println!("--- ACU ablation (small_vgg) ---\n{}",
        experiments::ablation(&mut rt, "small_vgg", &sizes, Some(2))?);

    // ---- Table 3 ---------------------------------------------------------
    println!("--- Table 3: functionality matrix ---\n{}", features::table3());

    // ---- Engine pool (dynamic batching) ---------------------------------
    println!("--- inference engine pool (dynamic batching) ---");
    let ds = adapt::data::load("cifar_syn", &Sizes::small());
    drop(rt); // every engine worker opens its own runtime
    let mut engine_cfg = EngineConfig::pjrt(
        artifacts.clone(),
        "small_vgg",
        InferVariant::ApproxLut,
        Some("mul8s_1l2h_like".into()),
    );
    engine_cfg.max_wait = Duration::from_millis(10);
    engine_cfg.workers = if quick { 2 } else { engine_cfg.workers };
    let engine = InferenceEngine::start(engine_cfg)?;
    let n = if quick { 48 } else { 96 };
    let per = 32 * 32 * 3;
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..n)
        .map(|i| engine.submit(ds.eval.x_f[(i % ds.eval.num) * per..][..per].to_vec()))
        .collect::<Result<_, _>>()?;
    let ok = pending.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    let wall = t0.elapsed();
    let workers = engine.workers();
    let stats = engine.shutdown()?;
    println!(
        "{ok}/{n} requests in {} ({:.0} req/s), {workers} workers, {} batches, \
         {} padded slots, queue wait {}\n",
        fmt::dur(wall),
        n as f64 / wall.as_secs_f64(),
        stats.total.batches,
        stats.total.padded_slots,
        fmt::dur(stats.total.queue_wait));

    println!("== end-to-end validation complete in {} ==", fmt::dur(t_start.elapsed()));
    println!("results appended under {}/results/", artifacts.display());
    Ok(())
}
