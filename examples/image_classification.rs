//! Image classification, end to end (the paper's headline use case).
//!
//! Reproduces one Table-2 row live: fp32 pre-training on the synthetic
//! CIFAR stand-in, percentile calibration, accuracy under the exact and
//! approximate 8-bit multipliers, approximation-aware retraining, and the
//! recovered accuracy — with the loss curves printed.
//!
//! ```bash
//! cargo run --release --example image_classification -- [model] [acu]
//! ```

use adapt::coordinator::experiments::hyper_for;
use adapt::coordinator::ops::{self, InferVariant, ModelState, TrainVariant};
use adapt::data::{self, Sizes};
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::{weights, Runtime};
use adapt::util::fmt;

fn sparkline(losses: &[f32]) -> String {
    let blocks = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (mn, mx) = losses
        .iter()
        .fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
    let span = (mx - mn).max(1e-9);
    losses
        .iter()
        .step_by((losses.len() / 60).max(1))
        .map(|&v| blocks[(((v - mn) / span) * 7.0) as usize])
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("small_resnet");
    let acu = args.get(1).map(|s| s.as_str()).unwrap_or("mul8s_1l2h_like");

    let mut rt = Runtime::open(&adapt::artifacts_dir())?;
    let m = rt.manifest.model(model)?.clone();
    anyhow::ensure!(m.kind == "cnn", "pick a CNN (got {})", m.kind);
    let sizes = Sizes::default();
    let ds = data::load(&m.dataset, &sizes);
    let hy = hyper_for(model);

    println!("== {model} on {} ({} params, {} MACs/sample) ==",
        m.dataset, fmt::count(m.params_count), fmt::count(m.macs));

    // 1. fp32 pre-training (fresh, to show the loss curve).
    let mut st = ModelState::load(&rt, model, &weights::initial_path(&rt.manifest.root, &m))?;
    let tr = ops::train(&mut rt, &mut st, TrainVariant::Fp32, &ds,
        hy.pretrain_steps, hy.pretrain_lr, None, 0)?;
    println!("fp32 pre-train {} steps in {}:", tr.steps, fmt::dur(tr.wall));
    println!("  loss {:.3} -> {:.3}  {}", tr.first_loss, tr.last_loss, sparkline(&tr.losses));

    let fp32 = ops::evaluate(&mut rt, &st, InferVariant::Fp32, &ds, None, None)?;
    println!("fp32 accuracy: {}", fmt::pct(fp32.accuracy));

    // 2. Post-training calibration (paper default: 99.9% percentile, 2 batches).
    ops::calibrate(&mut rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;

    // 3. Quantized + approximate accuracy.
    let exact_lut = ops::load_lut_lit(&rt, "exact8")?;
    let q = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&exact_lut), None)?;
    println!("8-bit (exact mult): {}", fmt::pct(q.accuracy));
    let acu_lut = ops::load_lut_lit(&rt, acu)?;
    let ap = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&acu_lut), None)?;
    println!("8-bit via {acu}: {}  (drop {:.2} pts)",
        fmt::pct(ap.accuracy), 100.0 * (q.accuracy - ap.accuracy));

    // 4. Approximation-aware retraining (§3.2.1).
    let tr2 = ops::train(&mut rt, &mut st, TrainVariant::QatLut, &ds,
        hy.qat_steps, hy.qat_lr, Some(&acu_lut), 0)?;
    println!("QAT retrain {} steps in {}:", tr2.steps, fmt::dur(tr2.wall));
    println!("  loss {:.3} -> {:.3}  {}", tr2.first_loss, tr2.last_loss, sparkline(&tr2.losses));

    let rec = ops::evaluate(&mut rt, &st, InferVariant::ApproxLut, &ds, Some(&acu_lut), None)?;
    println!("retrained accuracy via {acu}: {}  (recovered {:.2} of {:.2} pts)",
        fmt::pct(rec.accuracy),
        100.0 * (rec.accuracy - ap.accuracy),
        100.0 * (q.accuracy - ap.accuracy));
    Ok(())
}
