//! Quickstart: load the AOT artifacts, run approximate inference.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads `small_vgg`, calibrates its activation scales on two batches
//! (99.9 % percentile histogram — the paper's default), then evaluates one
//! batch three ways: fp32, 8-bit exact-quantized, and through the
//! `mul8s_1l2h_like` approximate multiplier.

use adapt::coordinator::ops::{self, InferVariant, ModelState};
use adapt::data::{self, Sizes};
use adapt::metrics;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::open(&adapt::artifacts_dir())?;
    let model = "small_vgg";
    println!("== AdaPT-RS quickstart: {model} ==");

    // 1. Load weights (trained snapshot if `adapt table2` ran, else init).
    let mut st = ModelState::load_best(&rt, model)?;
    let ds = data::load(&st.model.dataset.clone(), &Sizes::small());

    // 2. Calibrate activation ranges offline (Fig. 1, left box).
    let scales = ops::calibrate(&mut rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;
    println!("calibrated {} activation scales", scales.len());

    // 3. One batch through each execution mode.
    let bs = rt.manifest.batch;
    let x = ops::batch_input(&st.model, &ds.eval, 0, bs)?;
    let labels = ds.eval.batch_labels(0, bs);

    let fp32 = ops::infer_batch(&mut rt, &st, InferVariant::Fp32, &x, None)?;
    let exact_lut = ops::load_lut_lit(&rt, "exact8")?;
    let q8 = ops::infer_batch(&mut rt, &st, InferVariant::ApproxLut, &x, Some(&exact_lut))?;
    let acu_lut = ops::load_lut_lit(&rt, "mul8s_1l2h_like")?;
    let a8 = ops::infer_batch(&mut rt, &st, InferVariant::ApproxLut, &x, Some(&acu_lut))?;

    let dim = st.model.out_dim;
    println!("fp32 top-1:       {:.1}%", 100.0 * metrics::top1(&fp32, dim, &labels));
    println!("8-bit quantized:  {:.1}%", 100.0 * metrics::top1(&q8, dim, &labels));
    println!("8-bit mul8s-like: {:.1}%", 100.0 * metrics::top1(&a8, dim, &labels));
    println!("(run `adapt table2` to retrain the approximate model)");
    Ok(())
}
