//! Golden cross-check: every LUT artifact emitted by Python must match the
//! Rust behavioral multiplier entry-for-entry (all 65,536 products per
//! ACU). This is the contract that keeps the two mirrored multiplier
//! libraries from drifting.

use std::path::PathBuf;

use adapt::graph::Manifest;
use adapt::lut::Lut;
use adapt::mult;

/// PJRT-artifact gate: these tests need the Python AOT step's output.
/// Absent artifacts => skip with a message; set ADAPT_REQUIRE_ARTIFACTS=1
/// to turn the skip into a failure (CI images that ran `make artifacts`).
fn artifacts() -> Option<PathBuf> {
    let p = adapt::artifacts_dir();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    if std::env::var("ADAPT_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "artifacts/ missing but ADAPT_REQUIRE_ARTIFACTS=1 (run `make artifacts` first)"
        );
    }
    None
}

#[test]
fn every_lut_artifact_matches_rust_behavioral_model() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&root).unwrap();
    assert!(!manifest.luts.is_empty());
    for (acu, meta) in &manifest.luts {
        let lut = Lut::load(&root.join(&meta.file)).unwrap();
        let m = mult::get(acu).unwrap();
        assert_eq!(lut.bits, m.bits, "{acu} bitwidth");
        let half = (lut.n / 2) as i64;
        let mut checked = 0u64;
        for a in -half..half {
            for b in -half..half {
                let want = m.apply(a, b);
                let got = lut.mul(a as i32, b as i32) as i64;
                assert_eq!(got, want, "{acu}: approx({a},{b})");
                checked += 1;
            }
        }
        assert_eq!(checked, (lut.n * lut.n) as u64);
    }
}

#[test]
fn manifest_error_profiles_match_rust_characterization() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&root).unwrap();
    for (acu, meta) in &manifest.luts {
        let m = mult::get(acu).unwrap();
        if m.bits > 8 {
            continue; // sampled characterization differs slightly
        }
        let prof = mult::characterize(m, 0, 0);
        assert!(
            (prof.mre_pct - meta.mre_pct).abs() < 1e-3,
            "{acu}: rust MRE {} vs manifest {}",
            prof.mre_pct,
            meta.mre_pct
        );
        assert_eq!(prof.wce, meta.wce, "{acu} WCE");
    }
}
