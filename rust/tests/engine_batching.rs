//! Inference engine pool: request routing, batch forming, padding, stats,
//! error propagation, backpressure and drain-on-shutdown.
//!
//! The concurrency tests run artifact-free on the emulator backend (every
//! pool worker owns a Rust `Executor` over a shared spec); only the last
//! test exercises the PJRT backend and stays artifact-gated.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig, InferenceEngine};
use adapt::coordinator::ops::InferVariant;
use adapt::data::{self, Sizes};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::tensor::Tensor;
use adapt::util::rng::Rng;

/// conv(3x3, 1->4, pad 1) -> relu -> flatten -> linear(64 -> 3), on
/// 4x4x1 inputs — small enough that a batch is microseconds, big enough
/// to route through both GEMM kinds.
fn synth_model() -> Model {
    Model {
        name: "engine_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![64, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Conv2d {
                    kh: 3,
                    kw: 3,
                    cin: 1,
                    cout: 4,
                    stride: 1,
                    pad: 1,
                    groups: 1,
                    scale_idx: 0,
                    name: "c1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1],
            },
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            Node { id: 3, op: Op::Flatten, inputs: vec![2], params: vec![] },
            Node {
                id: 4,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 1, name: "fc".into() },
                inputs: vec![3],
                params: vec![2, 3],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn scales() -> Vec<f32> {
    vec![1.5 / 127.0, 4.0 / 127.0]
}

fn synth_plan(model: &Model) -> adapt::graph::ExecutionPlan {
    retransform(
        model,
        &Policy::all(LayerMode::lut("mul8s_1l2h_like")).with_acu("c1", "exact8"),
    )
}

/// Fresh emulator spec (deterministic — every call builds the same model,
/// weights and plan, so independently-built executors agree bit-for-bit).
fn make_spec(batch: usize) -> EmulatorSpec {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = synth_plan(&model);
    EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales(),
        luts: LutRegistry::in_memory(),
        batch,
        gemm_threads: 1,
    }
}

/// Deterministic per-(client, request) input sample.
fn sample(c: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new((c * 1000 + i) as u64 + 7);
    (0..16).map(|_| rng.next_gauss()).collect()
}

#[test]
fn pool_serves_concurrent_clients_exactly_once() {
    let mut cfg = EngineConfig::emulator(make_spec(8));
    cfg.workers = 4;
    cfg.queue_depth = 32;
    cfg.max_wait = Duration::from_millis(2);
    let engine = InferenceEngine::start(cfg).unwrap();
    assert_eq!(engine.out_dim(), 3);
    assert_eq!(engine.workers(), 4);

    // Reference outputs from a plain single-threaded executor. Batch rows
    // are independent in every GEMM, so engine results must match the
    // reference bit-for-bit no matter which batch slot / worker / padding
    // a request landed in — and a swapped response is instantly visible.
    let (n_clients, per_client) = (6, 20);
    let model = synth_model();
    let params = synth_params(&model, 42);
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        &model,
        params,
        synth_plan(&model),
        scales(),
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    let expected: Vec<Vec<Vec<f32>>> = (0..n_clients)
        .map(|c| {
            (0..per_client)
                .map(|i| {
                    let x = Tensor::from_vec(&[1, 4, 4, 1], sample(c, i)).unwrap();
                    exec.forward(Value::F(x)).unwrap().data
                })
                .collect()
        })
        .collect();

    std::thread::scope(|s| {
        for c in 0..n_clients {
            let engine = &engine;
            let expected = &expected;
            s.spawn(move || {
                for i in 0..per_client {
                    let got = engine.infer(sample(c, i)).unwrap();
                    assert_eq!(
                        got, expected[c][i],
                        "client {c} request {i}: wrong or swapped response"
                    );
                }
            });
        }
    });

    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total.requests, n_clients * per_client);
    assert!(stats.total.batches >= 1);
    assert_eq!(stats.per_worker.len(), 4);
    let per_worker_sum: usize = stats.per_worker.iter().map(|w| w.requests).sum();
    assert_eq!(per_worker_sum, stats.total.requests, "stats must aggregate");
}

#[test]
fn shutdown_drains_queued_requests() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 1;
    cfg.queue_depth = 64;
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    // Queue up a backlog, then shut down immediately: every receiver must
    // still get its answer (close() stops *submissions*, not the drain).
    let rxs: Vec<_> = (0..30)
        .map(|i| engine.submit(sample(9, i)).unwrap())
        .collect();
    let stats = engine.shutdown().unwrap();
    for rx in rxs {
        let out = rx
            .recv()
            .expect("response must be delivered before shutdown returns")
            .unwrap();
        assert_eq!(out.len(), 3);
    }
    assert_eq!(stats.total.requests, 30);
    assert!(
        stats.total.queue_wait > Duration::ZERO,
        "a 30-deep backlog behind one worker must accrue queue wait"
    );
    // Submitting after shutdown-close must fail, not hang.
    // (engine consumed by shutdown; start a fresh one to check the error.)
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 1;
    let engine = InferenceEngine::start(cfg).unwrap();
    let _ = engine.infer(sample(0, 0)).unwrap();
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total.requests, 1);
}

#[test]
fn tiny_queue_backpressure_completes_every_request() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 2;
    cfg.queue_depth = 2; // force submitters to block on the full queue
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    let (n_clients, per_client) = (3, 20);
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let engine = &engine;
            s.spawn(move || {
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| engine.submit(sample(c, i)).unwrap())
                    .collect();
                for rx in rxs {
                    assert_eq!(rx.recv().unwrap().unwrap().len(), 3);
                }
            });
        }
    });
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total.requests, n_clients * per_client);
}

#[test]
fn identical_inputs_identical_outputs_across_slots_and_workers() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 2;
    cfg.queue_depth = 16;
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    let x = sample(1, 1);
    // Interleave the probe with varying companions so it lands in varying
    // batch slots, padded and unpadded, on both workers.
    let mut probe_rxs = Vec::new();
    for i in 0..24 {
        probe_rxs.push(engine.submit(x.clone()).unwrap());
        let _ = engine.submit(sample(2, i)).unwrap();
    }
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for rx in probe_rxs {
        outs.push(rx.recv().unwrap().unwrap());
    }
    for o in &outs {
        assert_eq!(o, &outs[0], "same input must give same output everywhere");
    }
    engine.shutdown().unwrap();
}

#[test]
fn malformed_request_errors_without_killing_worker() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 1;
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    // Wrong per-sample length (16 expected): must error, not panic.
    assert!(engine.infer(vec![0.0; 5]).is_err());
    // The worker must still be alive and serving well-formed requests.
    let ok = engine.infer(sample(3, 3)).unwrap();
    assert_eq!(ok.len(), 3);
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total.requests, 1, "rejected request must not count");
}

#[test]
fn worker_setup_failure_aborts_start() {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = retransform(&model, &Policy::all(LayerMode::lut("no_such_acu")));
    let spec = EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales(),
        luts: LutRegistry::in_memory(),
        batch: 4,
        gemm_threads: 1,
    };
    let mut cfg = EngineConfig::emulator(spec);
    cfg.workers = 3;
    assert!(InferenceEngine::start(cfg).is_err(), "bad ACU must fail start");
}

#[test]
fn stats_snapshot_works_mid_run() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 2;
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    // Before any traffic: a clean zero snapshot, no shutdown required.
    let empty = engine.stats_snapshot();
    assert_eq!(empty.total.requests, 0);
    assert_eq!(empty.per_worker.len(), 2);
    assert_eq!(empty.generation, 0);

    for i in 0..10 {
        engine.infer(sample(4, i)).unwrap();
    }
    // Mid-run: everything answered so far is visible while the pool is
    // still serving, and the histograms counted every request.
    let snap = engine.stats_snapshot();
    assert_eq!(snap.total.requests, 10);
    assert!(snap.total.batches >= 1);
    assert_eq!(snap.total.queue_hist.count(), 10);
    assert_eq!(snap.total.compute_hist.count(), 10);
    let (p50, p95, p99) = snap.queue_wait_percentiles_us();
    assert!(p50 <= p95 && p95 <= p99, "percentiles must be ordered");

    // The engine still serves after snapshotting, and the final stats
    // from shutdown() agree with a last live snapshot.
    engine.infer(sample(4, 99)).unwrap();
    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.total.requests, 11);
    assert_eq!(stats.total.compute_hist.count(), 11);
}

#[test]
fn swap_plan_responses_match_fresh_engines_per_generation() {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 2;
    cfg.max_wait = Duration::from_millis(1);
    let engine = InferenceEngine::start(cfg).unwrap();
    let model = synth_model();
    let plan_b = retransform(&model, &Policy::all(LayerMode::lut("exact8")));
    let inputs: Vec<Vec<f32>> = (0..8).map(|i| sample(5, i)).collect();

    // Reference outputs from fresh engines started on each plan.
    let reference = |plan: &adapt::graph::ExecutionPlan| -> Vec<Vec<f32>> {
        let params = synth_params(&model, 42);
        let luts = LutRegistry::in_memory();
        let exec = Executor::new(
            &model,
            params,
            plan.clone(),
            scales(),
            &luts,
            Style::Optimized { threads: 1 },
        )
        .unwrap();
        inputs
            .iter()
            .map(|x| {
                let t = Tensor::from_vec(&[1, 4, 4, 1], x.clone()).unwrap();
                exec.forward(Value::F(t)).unwrap().data
            })
            .collect()
    };
    let expect_a = reference(&synth_plan(&model));
    let expect_b = reference(&plan_b);
    assert_ne!(expect_a, expect_b, "the two plans must disagree somewhere");

    for (i, x) in inputs.iter().enumerate() {
        let rx = engine.submit_raw(x.clone(), None).unwrap();
        let raw = rx.recv().unwrap().unwrap();
        assert_eq!(raw.output, expect_a[i], "generation 0 must serve plan A");
        assert_eq!(raw.generation, 0);
    }
    assert_eq!(engine.generation(), 0);
    assert_eq!(engine.swap_plan(plan_b).unwrap(), 1);
    assert_eq!(engine.generation(), 1);
    for (i, x) in inputs.iter().enumerate() {
        let rx = engine.submit_raw(x.clone(), None).unwrap();
        let raw = rx.recv().unwrap().unwrap();
        assert_eq!(
            raw.output, expect_b[i],
            "post-swap responses must be bit-identical to a fresh plan-B engine"
        );
        assert_eq!(raw.generation, 1, "no response may straddle generations");
    }

    // Swapping to a broken plan is rejected and leaves serving intact.
    let bad = retransform(&model, &Policy::all(LayerMode::lut("no_such_acu")));
    assert!(engine.swap_plan(bad).is_err());
    assert_eq!(engine.generation(), 1);
    let rx = engine.submit_raw(inputs[0].clone(), None).unwrap();
    assert_eq!(rx.recv().unwrap().unwrap().output, expect_b[0]);

    let stats = engine.shutdown().unwrap();
    assert_eq!(stats.generation, 1);
    assert_eq!(stats.total.requests, 17);
}

// ---------------------------------------------------------------------------
// PJRT backend (artifact-gated)
// ---------------------------------------------------------------------------

/// PJRT-artifact gate: these tests need the Python AOT step's output.
/// Absent artifacts => skip with a message; set ADAPT_REQUIRE_ARTIFACTS=1
/// to turn the skip into a failure (CI images that ran `make artifacts`).
fn artifacts() -> Option<PathBuf> {
    let p = adapt::artifacts_dir();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    if std::env::var("ADAPT_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "artifacts/ missing but ADAPT_REQUIRE_ARTIFACTS=1 (run `make artifacts` first)"
        );
    }
    None
}

#[test]
fn engine_serves_padded_and_full_batches() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let ds = data::load("mnist_syn", &Sizes::small());
    let per = 28 * 28;
    let mut cfg = EngineConfig::pjrt(
        root,
        "vae_mnist",
        InferVariant::ApproxLut,
        Some("mul8s_1l2h_like".into()),
    );
    cfg.max_wait = Duration::from_millis(5);
    cfg.workers = 2;
    let engine = InferenceEngine::start(cfg).unwrap();
    assert_eq!(engine.out_dim(), 784);

    // One lone request -> a padded batch must still answer.
    let out = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    assert_eq!(out.len(), per);
    assert!(out.iter().all(|v| v.is_finite()));

    // A burst of 40 requests (> one batch of 32).
    let pending: Vec<_> = (0..40)
        .map(|i| {
            engine
                .submit(ds.eval.x_f[(i % ds.eval.num) * per..][..per].to_vec())
                .unwrap()
        })
        .collect();
    let mut outs = Vec::new();
    for rx in pending {
        outs.push(rx.recv().unwrap().unwrap());
    }
    assert_eq!(outs.len(), 40);

    // Identical inputs must produce identical outputs regardless of which
    // batch slot (or worker) they landed in.
    let a = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    let b = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    assert_eq!(a, b);

    let stats = engine.shutdown().unwrap();
    assert!(stats.total.requests >= 43);
    assert!(stats.total.batches >= 2);
    assert!(stats.total.padded_slots > 0, "lone requests must have padded");
}
