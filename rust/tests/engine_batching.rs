//! Dynamic-batching inference engine: request routing, batch forming,
//! padding, stats and error propagation.

use std::path::PathBuf;
use std::time::Duration;

use adapt::coordinator::engine::{EngineConfig, InferenceEngine};
use adapt::coordinator::ops::InferVariant;
use adapt::data::{self, Sizes};

/// PJRT-artifact gate: these tests need the Python AOT step's output.
/// Absent artifacts => skip with a message; set ADAPT_REQUIRE_ARTIFACTS=1
/// to turn the skip into a failure (CI images that ran `make artifacts`).
fn artifacts() -> Option<PathBuf> {
    let p = adapt::artifacts_dir();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    if std::env::var("ADAPT_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "artifacts/ missing but ADAPT_REQUIRE_ARTIFACTS=1 (run `make artifacts` first)"
        );
    }
    None
}

#[test]
fn engine_serves_padded_and_full_batches() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let ds = data::load("mnist_syn", &Sizes::small());
    let per = 28 * 28;
    let engine = InferenceEngine::start(EngineConfig {
        artifacts: root,
        model: "vae_mnist".into(),
        variant: InferVariant::ApproxLut,
        acu: Some("mul8s_1l2h_like".into()),
        max_wait: Duration::from_millis(5),
    })
    .unwrap();
    assert_eq!(engine.out_dim(), 784);

    // One lone request -> a padded batch must still answer.
    let out = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    assert_eq!(out.len(), per);
    assert!(out.iter().all(|v| v.is_finite()));

    // A burst of 40 requests (> one batch of 32).
    let pending: Vec<_> = (0..40)
        .map(|i| {
            engine
                .submit(ds.eval.x_f[(i % ds.eval.num) * per..][..per].to_vec())
                .unwrap()
        })
        .collect();
    let mut outs = Vec::new();
    for rx in pending {
        outs.push(rx.recv().unwrap().unwrap());
    }
    assert_eq!(outs.len(), 40);

    // Identical inputs must produce identical outputs regardless of which
    // batch slot they landed in.
    let a = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    let b = engine.infer(ds.eval.x_f[..per].to_vec()).unwrap();
    assert_eq!(a, b);

    let stats = engine.shutdown().unwrap();
    assert!(stats.requests >= 43);
    assert!(stats.batches >= 2);
    assert!(stats.padded_slots > 0, "lone requests must have padded");
}
