//! End-to-end tests for the observability layer: trace propagation
//! through the engine (queue → batch → execute spans, monotone and
//! non-overlapping, version-tagged), tail-sampling semantics (off =
//! zero traces, errors always kept), per-layer profiler coverage of the
//! forward wall, and the wire surface (`GET /metrics` Prometheus text,
//! `GET /v1/trace/{id}`, `GET /v2/models/{m}/traces`) — all
//! artifact-free on the emulator backend.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::obs::LayerProfiler;
use adapt::service::client::{self, http_call};
use adapt::service::http::{HttpServer, ServeOptions};
use adapt::service::{AdaptService, InferRequest};
use adapt::tensor::Tensor;
use adapt::util::json::Json;
use adapt::util::rng::Rng;

/// conv(3x3, 1->4, pad 1) -> relu -> flatten -> linear(64 -> 3), on
/// 4x4x1 inputs (the same shape the other service tests use).
fn synth_model() -> Model {
    Model {
        name: "obs_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![64, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Conv2d {
                    kh: 3,
                    kw: 3,
                    cin: 1,
                    cout: 4,
                    stride: 1,
                    pad: 1,
                    groups: 1,
                    scale_idx: 0,
                    name: "c1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1],
            },
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            Node { id: 3, op: Op::Flatten, inputs: vec![2], params: vec![] },
            Node {
                id: 4,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 1, name: "fc".into() },
                inputs: vec![3],
                params: vec![2, 3],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn plan_a(model: &Model) -> ExecutionPlan {
    retransform(model, &Policy::all(LayerMode::lut("mul8s_1l2h_like")))
}

fn make_spec(batch: usize) -> EmulatorSpec {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = plan_a(&model);
    EmulatorSpec {
        model,
        params,
        plan,
        act_scales: vec![1.5 / 127.0, 4.0 / 127.0],
        luts: LutRegistry::in_memory(),
        batch,
        gemm_threads: 1,
    }
}

fn start_service(workers: usize) -> AdaptService {
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = workers;
    cfg.queue_depth = 64;
    cfg.max_wait = Duration::from_millis(1);
    AdaptService::start(cfg).unwrap()
}

fn sample(i: usize) -> Vec<f32> {
    let mut rng = Rng::new(i as u64 + 7);
    (0..16).map(|_| rng.next_gauss()).collect()
}

fn span<'j>(trace: &'j Json, name: &str) -> &'j Json {
    trace
        .get("spans")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .find(|s| s.get("name").unwrap().str().unwrap() == name)
        .unwrap_or_else(|| panic!("trace has no {name} span: {trace}"))
}

#[test]
fn trace_propagates_submit_to_execute() {
    let service = start_service(1);
    service.engine().tracer().set_sample(1.0);

    let mut req = InferRequest::new(sample(0));
    req.id = Some(7);
    let resp = service.infer(req).unwrap();
    assert_eq!(resp.id, 7);

    // finish() runs before the response is delivered, so the trace is
    // retrievable as soon as infer() returns.
    let trace = service.engine().tracer().get(7).expect("trace retained");
    assert_eq!(trace.get("outcome").unwrap().str().unwrap(), "ok");

    let (queue, batch, execute) =
        (span(&trace, "queue"), span(&trace, "batch"), span(&trace, "execute"));
    let iv = |s: &Json| {
        (
            s.get("start_us").unwrap().i64().unwrap(),
            s.get("end_us").unwrap().i64().unwrap(),
        )
    };
    let (q0, q1) = iv(queue);
    let (b0, b1) = iv(batch);
    let (e0, e1) = iv(execute);
    // Monotone and non-overlapping, sharing boundary instants. Each
    // offset truncates to whole microseconds independently, so adjacent
    // boundaries may disagree by 1us — allow exactly that much.
    assert!(q0 <= q1 && q1 <= b0 + 1 && b0 <= b1 && b1 <= e0 && e0 <= e1,
        "spans out of order: queue [{q0},{q1}] batch [{b0},{b1}] execute [{e0},{e1}]");
    assert!(trace.get("total_us").unwrap().i64().unwrap() + 1 >= e1);

    // The execute span carries the identity of the run that answered.
    assert_eq!(
        execute.get("version").unwrap().i64().unwrap() as u64,
        resp.version
    );
    assert_eq!(
        execute.get("generation").unwrap().i64().unwrap() as u64,
        resp.generation
    );
    assert_eq!(
        execute.get("worker").unwrap().i64().unwrap() as usize,
        resp.worker
    );
    assert!(batch.get("batch").unwrap().i64().unwrap() >= 1);
    service.shutdown().unwrap();
}

#[test]
fn sampling_off_records_no_traces() {
    let service = start_service(1);
    service.engine().tracer().set_sample(0.0);
    for i in 0..8 {
        let mut req = InferRequest::new(sample(i));
        req.id = Some(i as u64);
        service.infer(req).unwrap();
    }
    assert_eq!(service.engine().tracer().retained(), 0);
    assert!(service.engine().tracer().get(0).is_none());
    service.shutdown().unwrap();
}

#[test]
fn error_traces_kept_at_tiny_sample_rate() {
    let service = start_service(1);
    // Rate so small no success survives the tail decision — but errors
    // must be retained regardless.
    service.engine().tracer().set_sample(1.0e-9);
    let mut req = InferRequest::new(vec![0.0; 5]); // wrong length
    req.id = Some(99);
    service.infer(req).unwrap_err();
    let trace = service.engine().tracer().get(99).expect("error trace kept");
    assert_eq!(
        trace.get("outcome").unwrap().str().unwrap(),
        "wrong_input_length"
    );
    service.shutdown().unwrap();
}

#[test]
fn profiler_layer_sum_covers_forward_wall() {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = plan_a(&model);
    let luts = LutRegistry::in_memory();
    let mut exec = Executor::new(
        &model,
        params,
        plan,
        vec![1.5 / 127.0, 4.0 / 127.0],
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    let profiler = Arc::new(LayerProfiler::new(true));
    exec.set_profiler(Some(Arc::clone(&profiler)));

    // Big enough batch that kernel work dwarfs the (untimed) per-node
    // bookkeeping between layers.
    let batch = 64;
    let x = Tensor::from_vec(
        &[batch, 4, 4, 1],
        (0..batch * 16).map(|i| (i as f32 * 0.37).sin()).collect(),
    )
    .unwrap();
    // Warm up once (arena growth, LUT faulting), then measure.
    exec.forward(Value::F(x.clone())).unwrap();
    profiler.clear();
    let t0 = Instant::now();
    for _ in 0..20 {
        exec.forward(Value::F(x.clone())).unwrap();
    }
    let wall = t0.elapsed().as_nanos() as f64;
    let layer_sum = profiler.total_ns() as f64;

    // The per-layer sum excludes only per-forward bookkeeping (input
    // staging, output extraction), so it must land close below the
    // measured wall. Generous lower bound for noisy CI machines; the
    // `adapt profile` CLI reports the exact coverage.
    assert!(layer_sum <= wall * 1.05, "layer sum {layer_sum} > wall {wall}");
    assert!(
        layer_sum >= wall * 0.5,
        "layer sum {layer_sum} covers under half of wall {wall}"
    );

    // The table carries kernel identity: the GEMM nodes report MACs and
    // a resolved product backend.
    let table = profiler.to_json();
    let layers = table.get("layers").unwrap().arr().unwrap().clone();
    let gemms: Vec<&Json> = layers
        .iter()
        .filter(|l| {
            let op = l.get("op").unwrap().str().unwrap().to_string();
            op == "conv2d" || op == "linear"
        })
        .collect();
    assert_eq!(gemms.len(), 2);
    for g in gemms {
        assert!(g.get("macs").unwrap().i64().unwrap() > 0);
        assert_eq!(g.get("bits").unwrap().i64().unwrap(), 8);
        assert!(g.get("count").unwrap().i64().unwrap() >= 20);
        let backend = g.get("backend").unwrap().str().unwrap().to_string();
        assert!(
            backend == "lut" || backend == "closed-form",
            "unexpected backend {backend}"
        );
    }
}

#[test]
fn disabled_profiler_records_nothing() {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = plan_a(&model);
    let luts = LutRegistry::in_memory();
    let mut exec = Executor::new(
        &model,
        params,
        plan,
        vec![1.5 / 127.0, 4.0 / 127.0],
        &luts,
        Style::Naive,
    )
    .unwrap();
    let profiler = Arc::new(LayerProfiler::new(false));
    exec.set_profiler(Some(Arc::clone(&profiler)));
    let x = Tensor::from_vec(&[1, 4, 4, 1], vec![0.5; 16]).unwrap();
    exec.forward(Value::F(x)).unwrap();
    assert!(profiler.is_empty());
}

#[test]
fn metrics_and_trace_routes_over_the_wire() {
    let service = Arc::new(start_service(1));
    service.engine().tracer().set_sample(1.0);
    let server =
        HttpServer::start_with(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default())
            .unwrap();
    let addr = server.addr().to_string();

    // Unsampled id: typed 404 (tracing is on, but nothing ran yet).
    let (status, body) = http_call(&addr, "GET", "/v1/trace/5", None).unwrap();
    assert_eq!(status, 404);
    assert_eq!(
        Json::parse(&body).unwrap().get("error").unwrap().str().unwrap(),
        "not_found"
    );
    // Malformed id: 400, not a panic.
    let (status, _) = http_call(&addr, "GET", "/v1/trace/xyz", None).unwrap();
    assert_eq!(status, 400);

    // Drive one inference through the wire, then fetch its trace.
    let mut req = InferRequest::new(sample(1));
    req.id = Some(5);
    let (status, _) =
        http_call(&addr, "POST", "/v1/infer", Some(&req.to_json().to_string())).unwrap();
    assert_eq!(status, 200);
    let (status, body) = http_call(&addr, "GET", "/v1/trace/5", None).unwrap();
    assert_eq!(status, 200);
    let trace = Json::parse(&body).unwrap();
    assert_eq!(trace.get("id").unwrap().i64().unwrap(), 5);
    for name in ["queue", "batch", "execute"] {
        span(&trace, name);
    }

    // The model's recent-traces listing carries the same trace.
    let (status, body) =
        http_call(&addr, "GET", "/v2/models/obs_cnn/traces", None).unwrap();
    assert_eq!(status, 200);
    let listed = Json::parse(&body).unwrap();
    assert!(listed
        .arr()
        .unwrap()
        .iter()
        .any(|t| t.get("id").unwrap().i64().unwrap() == 5));

    // /metrics: Prometheus text with the engine counters, and counters
    // never decrease between scrapes.
    let before = client::scrape_metrics(&addr).unwrap();
    assert!(before.contains_key("adapt_net_accepted_total"));
    let served: f64 = before
        .iter()
        .filter(|(k, _)| k.starts_with("adapt_requests_total"))
        .map(|(_, v)| *v)
        .sum();
    assert!(served >= 1.0, "requests counter missing the driven request");
    let mut req = InferRequest::new(sample(2));
    req.id = Some(6);
    let (status, _) =
        http_call(&addr, "POST", "/v1/infer", Some(&req.to_json().to_string())).unwrap();
    assert_eq!(status, 200);
    let after = client::scrape_metrics(&addr).unwrap();
    for (k, v) in &before {
        if k.ends_with("_total") || k.contains("_bucket") || k.ends_with("_count") {
            let now = after.get(k).copied().unwrap_or(0.0);
            assert!(now >= *v, "counter {k} decreased: {v} -> {now}");
        }
    }
    // Wrong method on /metrics: 405, JSON error body.
    let (status, _) = http_call(&addr, "POST", "/metrics", Some("{}")).unwrap();
    assert_eq!(status, 405);

    server.stop();
}
