//! Integration tests for the error-compensation subsystem
//! (`adapt::compensate`):
//!
//! 1. calibration is byte-deterministic across `ADAPT_THREADS` — identical
//!    operand histograms and bit-identical fitted corrections at 1 and 4
//!    threads,
//! 2. a compensated plan (terms + provenance) survives a JSON round trip
//!    byte-for-byte,
//! 3. the executor's no-compensation path is untouched: plans without a
//!    compensation block (or with the blocks stripped) execute
//!    bit-identically to before, at any thread count and style,
//! 4. end-to-end on the pre-trained synthetic CNN, compensating an
//!    aggressive mitchell8 plan recovers accuracy at identical
//!    MAC-weighted power.

use adapt::compensate;
use adapt::data::Split;
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Policy};
use adapt::lut::LutRegistry;
use adapt::search;
use adapt::tensor::Tensor;
use adapt::trainer::{self, synth};
use adapt::util::rng::Rng;

/// Untrained [`synth::tiny_cnn`] with random weights, fixed activation
/// scales, and an in-memory calibration split — enough for the
/// determinism / round-trip / bit-equivalence properties, which do not
/// care whether the network classifies anything.
fn synth_setup(seed: u64) -> (Model, Vec<Tensor>, Vec<f32>, Split) {
    let model = synth::tiny_cnn();
    let mut rng = Rng::new(seed);
    let params: Vec<Tensor> = model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.4).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect();
    let per: usize = model.input_shape.iter().product();
    let n = 64;
    let x_f: Vec<f32> = (0..n * per).map(|_| rng.next_gauss()).collect();
    let split = Split {
        x_f,
        x_i: vec![],
        labels: (0..n).map(|i| (i % model.out_dim) as i32).collect(),
        num: n,
        sample_shape: model.input_shape.clone(),
        is_tokens: false,
    };
    let scales = vec![2.0 / 127.0; model.n_scales];
    (model, params, scales, split)
}

fn calibrate(
    model: &Model,
    params: &[Tensor],
    scales: &[f32],
    split: &Split,
    threads: usize,
) -> compensate::Calibration {
    compensate::collect(model, params, split, 16, 3, scales, &[8], threads).unwrap()
}

#[test]
fn calibration_is_deterministic_across_thread_counts() {
    // PROPERTY: `collect` histograms and the corrections fitted from them
    // are byte-identical at ADAPT_THREADS=1 and =4 — the contract that
    // lets a plan calibrated on one machine reproduce anywhere.
    let (model, params, scales, split) = synth_setup(11);
    let c1 = calibrate(&model, &params, &scales, &split, 1);
    let c4 = calibrate(&model, &params, &scales, &split, 4);

    assert_eq!(c1.hists.len(), c4.hists.len());
    for ((k1, h1), (k4, h4)) in c1.hists.iter().zip(c4.hists.iter()) {
        assert_eq!(k1, k4);
        assert_eq!(
            h1.counts, h4.counts,
            "operand histogram diverged for node {} at {} bits",
            k1.0, k1.1
        );
        assert_eq!(h1.total, h4.total);
    }

    let mode = LayerMode::lut("mitchell8");
    for (&id, _) in &search::layer_macs(&model) {
        let a = compensate::compensation_for(&model, &params, &scales, &c1, id, &mode)
            .unwrap()
            .expect("mitchell8 has systematic error; every layer should get a block");
        let b = compensate::compensation_for(&model, &params, &scales, &c4, id, &mode)
            .unwrap()
            .unwrap();
        assert_eq!(
            a.constant.to_bits(),
            b.constant.to_bits(),
            "constant term of node {id} is not bit-identical"
        );
        let bits_a: Vec<u32> = a.channels.iter().map(|c| c.to_bits()).collect();
        let bits_b: Vec<u32> = b.channels.iter().map(|c| c.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "channel terms of node {id} are not bit-identical");
    }
}

#[test]
fn compensated_plan_json_round_trips_byte_identically() {
    let (model, params, scales, split) = synth_setup(23);
    let calib = calibrate(&model, &params, &scales, &split, 1);
    let mut plan = retransform(&model, &Policy::all(LayerMode::lut("mitchell8")));
    let applied = compensate::compensate_plan(&model, &params, &scales, &calib, &mut plan).unwrap();
    assert!(applied >= 1, "at least one layer must be compensated");
    assert_eq!(applied, plan.compensation.len());

    let json1 = plan.to_json_with(&model, Some("compensate:mitchell8"));
    assert_eq!(
        ExecutionPlan::provenance_of(&json1).as_deref(),
        Some("compensate:mitchell8")
    );
    let reloaded = ExecutionPlan::from_json(&json1, &model).unwrap();
    assert_eq!(
        reloaded.compensation, plan.compensation,
        "compensation terms must survive the round trip exactly"
    );
    let json2 = reloaded.to_json_with(&model, Some("compensate:mitchell8"));
    assert_eq!(json1, json2, "plan JSON round trip must be byte-identical");
}

#[test]
fn absent_compensation_executes_bit_identically_at_any_thread_count() {
    // PROPERTY: compensation folds into the bias at prepare time, so (a) a
    // plan without a block runs the exact pre-subsystem path, (b) a
    // compensated plan is still bit-identical across thread counts and
    // styles, and (c) stripping the blocks restores (a) byte-for-byte.
    let (model, params, scales, split) = synth_setup(37);
    let calib = calibrate(&model, &params, &scales, &split, 1);
    let plain = retransform(&model, &Policy::all(LayerMode::lut("mitchell8")));
    let mut comp = plain.clone();
    let applied = compensate::compensate_plan(&model, &params, &scales, &calib, &mut comp).unwrap();
    assert!(applied >= 1);

    let luts = LutRegistry::in_memory();
    let x = split.batch_tensor(0, 8);
    let run = |p: &ExecutionPlan, style: Style| {
        let exec = Executor::new(&model, params.clone(), p.clone(), scales.clone(), &luts, style)
            .unwrap();
        exec.forward(Value::F(x.clone())).unwrap()
    };

    let plain1 = run(&plain, Style::Optimized { threads: 1 });
    let plain4 = run(&plain, Style::Optimized { threads: 4 });
    assert_eq!(plain1.data, plain4.data, "uncompensated plan must be thread-invariant");

    let comp1 = run(&comp, Style::Optimized { threads: 1 });
    let comp4 = run(&comp, Style::Optimized { threads: 4 });
    let comp_naive = run(&comp, Style::Naive);
    assert_eq!(comp1.data, comp4.data, "compensated plan must be thread-invariant");
    assert_eq!(comp1.data, comp_naive.data, "styles must agree on the compensated plan");
    assert_ne!(plain1.data, comp1.data, "compensation must actually change outputs");

    let mut stripped = comp.clone();
    stripped.compensation.clear();
    assert_eq!(
        run(&stripped, Style::Optimized { threads: 2 }).data,
        plain1.data,
        "stripping the blocks must restore the uncompensated execution"
    );
}

#[test]
fn compensation_recovers_accuracy_at_identical_mac_cost() {
    // END-TO-END: on the pre-trained synthetic CNN, an all-mitchell8 plan
    // drops accuracy vs exact8; attaching calibrated compensation claws
    // some of it back without touching a single MAC (identical
    // MAC-weighted power before and after).
    let ts = synth::tiny_pretrained(0xADA9, 2).unwrap();
    let luts = LutRegistry::in_memory();
    let plain = retransform(&ts.model, &Policy::all(LayerMode::lut("mitchell8")));
    let bits = compensate::needed_bits(plain.modes.values()).unwrap();
    let calib = compensate::collect(
        &ts.model, &ts.params, &ts.ds.train, 32, 2, &ts.scales, &bits, 2,
    )
    .unwrap();
    let mut comp = plain.clone();
    let applied =
        compensate::compensate_plan(&ts.model, &ts.params, &ts.scales, &calib, &mut comp).unwrap();
    assert!(applied >= 2, "both convs at least should be compensated, got {applied}");

    let eval = |p: &ExecutionPlan| {
        trainer::evaluate(&ts.model, ts.params.clone(), p, &ts.scales, &luts, &ts.ds.eval, 32, 8, 2)
            .unwrap()
    };
    let exact = eval(&retransform(&ts.model, &Policy::all(LayerMode::lut("exact8"))));
    let uncomp = eval(&plain);
    let with_comp = eval(&comp);
    assert!(
        exact > uncomp,
        "mitchell8 must visibly hurt the tiny CNN (exact {exact}, uncompensated {uncomp})"
    );
    assert!(
        with_comp > uncomp,
        "compensation must recover accuracy: exact {exact}, uncompensated {uncomp}, compensated {with_comp}"
    );

    let macs = search::layer_macs(&ts.model);
    assert_eq!(
        search::plan_cost_macs(&macs, &plain),
        search::plan_cost_macs(&macs, &comp),
        "compensation must not change the MAC-weighted power"
    );
}
