//! Registry `/v2` acceptance tests: multiple models served concurrently
//! with independent stats, immutable + enumerable plan versions, exact
//! canary splits, shadow disagreement stats matching an offline
//! recomputation, no version mixing across activate/rollback, and the
//! hardened HTTP front-end (idle timeout, connection cap) — all
//! artifact-free on the emulator backend.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::service::client::http_call;
use adapt::service::http::{HttpServer, ServeOptions};
use adapt::service::registry::ModelRegistry;
use adapt::service::{AdaptService, InferRequest};
use adapt::tensor::Tensor;
use adapt::util::json::Json;
use adapt::util::rng::Rng;

/// conv(3x3, 1->4, pad 1) -> relu -> flatten -> linear(64 -> 3), on
/// 4x4x1 inputs (the same shape the other serving tests exercise).
fn synth_model(name: &str) -> Model {
    Model {
        name: name.into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![64, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Conv2d {
                    kh: 3,
                    kw: 3,
                    cin: 1,
                    cout: 4,
                    stride: 1,
                    pad: 1,
                    groups: 1,
                    scale_idx: 0,
                    name: "c1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1],
            },
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            Node { id: 3, op: Op::Flatten, inputs: vec![2], params: vec![] },
            Node {
                id: 4,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 1, name: "fc".into() },
                inputs: vec![3],
                params: vec![2, 3],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn scales() -> Vec<f32> {
    vec![1.5 / 127.0, 4.0 / 127.0]
}

/// Version-1 plan: mixed (c1 on exact8, fc on mul8s_1l2h_like).
fn plan_a(model: &Model) -> ExecutionPlan {
    retransform(
        model,
        &Policy::all(LayerMode::lut("mul8s_1l2h_like")).with_acu("c1", "exact8"),
    )
}

/// Candidate plan: everything on exact8 (visibly different arithmetic).
fn plan_b(model: &Model) -> ExecutionPlan {
    retransform(model, &Policy::all(LayerMode::lut("exact8")))
}

/// One engine-pool service over the synthetic model (deterministic per
/// (name, seed): independently-built executors agree bit-for-bit).
fn make_service(name: &str, seed: u64, workers: usize, batch: usize) -> Arc<AdaptService> {
    let model = synth_model(name);
    let params = synth_params(&model, seed);
    let plan = plan_a(&model);
    let spec = EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales(),
        luts: LutRegistry::in_memory(),
        batch,
        gemm_threads: 1,
    };
    let mut cfg = EngineConfig::emulator(spec);
    cfg.workers = workers;
    cfg.queue_depth = 64;
    cfg.max_wait = Duration::from_millis(2);
    Arc::new(AdaptService::start(cfg).unwrap())
}

/// Deterministic per-(client, request) input sample.
fn sample(c: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new((c * 1000 + i) as u64 + 7);
    (0..16).map(|_| rng.next_gauss()).collect()
}

/// Reference outputs from a plain single-threaded executor on `plan`.
fn reference_outputs(
    name: &str,
    seed: u64,
    plan: &ExecutionPlan,
    inputs: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let model = synth_model(name);
    let params = synth_params(&model, seed);
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        &model,
        params,
        plan.clone(),
        scales(),
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    inputs
        .iter()
        .map(|x| {
            let t = Tensor::from_vec(&[1, 4, 4, 1], x.clone()).unwrap();
            exec.forward(Value::F(t)).unwrap().data
        })
        .collect()
}

fn post(addr: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, text) = http_call(addr, "POST", path, Some(body)).unwrap();
    (status, Json::parse(&text).expect("every response body is JSON"))
}

fn get(addr: &str, path: &str) -> (u16, Json) {
    let (status, text) = http_call(addr, "GET", path, None).unwrap();
    (status, Json::parse(&text).expect("every response body is JSON"))
}

// ---------------------------------------------------------------------------
// Two models, independent stats
// ---------------------------------------------------------------------------

#[test]
fn two_models_serve_concurrently_with_independent_stats() {
    let registry = Arc::new(
        ModelRegistry::new(vec![
            ("alpha".into(), make_service("alpha", 42, 2, 4)),
            ("beta".into(), make_service("beta", 99, 2, 4)),
        ])
        .unwrap(),
    );
    let server =
        HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", ServeOptions::default())
            .unwrap();
    let addr = server.addr().to_string();

    // The listing names both models, alpha (first registered) is default.
    let (status, j) = get(&addr, "/v2/models");
    assert_eq!(status, 200);
    assert_eq!(j.get("default").unwrap().str().unwrap(), "alpha");
    let listed = j.get("models").unwrap().arr().unwrap();
    assert_eq!(listed.len(), 2);
    assert_eq!(listed[0].get("name").unwrap().str().unwrap(), "alpha");
    assert_eq!(listed[1].get("name").unwrap().str().unwrap(), "beta");
    assert_eq!(listed[0].get("active_version").unwrap().usize().unwrap(), 1);
    assert_eq!(listed[0].get("input_len").unwrap().usize().unwrap(), 16);

    // Concurrent wire traffic to both models: every response must be the
    // *right model's* bit-exact reference output (seeds differ, so the
    // two models disagree everywhere).
    let per_model = 12;
    let inputs: Vec<Vec<f32>> = (0..per_model).map(|i| sample(3, i)).collect();
    let expect: BTreeMap<&str, Vec<Vec<f32>>> = [
        ("alpha", reference_outputs("alpha", 42, &plan_a(&synth_model("alpha")), &inputs)),
        ("beta", reference_outputs("beta", 99, &plan_a(&synth_model("beta")), &inputs)),
    ]
    .into_iter()
    .collect();
    assert_ne!(expect["alpha"], expect["beta"], "models must differ");

    std::thread::scope(|s| {
        for name in ["alpha", "beta"] {
            let addr = &addr;
            let inputs = &inputs;
            let expect = &expect;
            s.spawn(move || {
                for (i, x) in inputs.iter().enumerate() {
                    let mut req = InferRequest::new(x.clone());
                    req.id = Some(i as u64);
                    let (status, j) = post(
                        addr,
                        &format!("/v2/models/{name}/infer"),
                        &req.to_json().to_string(),
                    );
                    assert_eq!(status, 200, "{name} request {i}");
                    let resp = adapt::service::InferResponse::from_json(&j).unwrap();
                    assert_eq!(resp.id, i as u64);
                    assert_eq!(resp.version, 1);
                    assert_eq!(
                        resp.output, expect[name][i],
                        "{name} request {i}: wrong model's output"
                    );
                }
            });
        }
    });

    // Per-model stats are independent totals.
    for name in ["alpha", "beta"] {
        let (status, j) = get(&addr, &format!("/v2/models/{name}/stats"));
        assert_eq!(status, 200);
        assert_eq!(j.get("name").unwrap().str().unwrap(), name);
        assert_eq!(
            j.get("total").unwrap().get("requests").unwrap().usize().unwrap(),
            per_model,
            "{name} must count only its own traffic"
        );
        assert_eq!(j.get("active_version").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("versions").unwrap().usize().unwrap(), 1);
    }

    // Unknown model -> typed 404.
    let (status, j) = get(&addr, "/v2/models/gamma/stats");
    assert_eq!(status, 404);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "model_not_found");

    server.stop();
}

// ---------------------------------------------------------------------------
// Plan versions: immutable + enumerable
// ---------------------------------------------------------------------------

#[test]
fn plan_versions_are_immutable_and_enumerable() {
    let registry = Arc::new(
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 1, 4))]).unwrap(),
    );
    let server =
        HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", ServeOptions::default())
            .unwrap();
    let addr = server.addr().to_string();
    let model = synth_model("m");

    // Version 1 (the starting plan) is pre-seeded.
    let (status, j) = get(&addr, "/v2/models/m/plans");
    assert_eq!(status, 200);
    let list = j.arr().unwrap().to_vec();
    assert_eq!(list.len(), 1);
    assert_eq!(list[0].get("version").unwrap().usize().unwrap(), 1);
    assert_eq!(list[0].get("source").unwrap().str().unwrap(), "initial");

    // Create from a spec and from a plan JSON document.
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=exact8"}"#);
    assert_eq!(status, 200, "create from spec: {j:?}");
    assert_eq!(j.get("version").unwrap().usize().unwrap(), 2);
    assert_eq!(j.get("source").unwrap().str().unwrap(), "spec:default=exact8");
    let doc = plan_a(&model).to_json(&model);
    let (status, j) = post(&addr, "/v2/models/m/plans", &doc);
    assert_eq!(status, 200);
    assert_eq!(j.get("version").unwrap().usize().unwrap(), 3);
    assert_eq!(j.get("source").unwrap().str().unwrap(), "json");

    // Same content again -> a NEW version number, never a mutation.
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=exact8"}"#);
    assert_eq!(status, 200);
    assert_eq!(j.get("version").unwrap().usize().unwrap(), 4);

    // Broken plans never become versions.
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=no_such_acu"}"#);
    assert_eq!(status, 422);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "plan_rejected");
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "nope=exact8"}"#);
    assert_eq!(status, 422, "spec matching no layer: {j:?}");

    // Snapshot version 2's plan content, then churn the lifecycle.
    let handle = registry.get("m").unwrap();
    let before: String = handle.list_versions()[1].plan.to_json(&model);
    let (status, _) = post(&addr, "/v2/models/m/plans/2/activate", "{}");
    assert_eq!(status, 200);
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=trunc_out8_4"}"#);
    assert_eq!(status, 200);
    assert_eq!(j.get("version").unwrap().usize().unwrap(), 5);

    // The full list is enumerable, ordered, and version 2 is unchanged.
    let (_, j) = get(&addr, "/v2/models/m/plans");
    let list = j.arr().unwrap();
    assert_eq!(list.len(), 5);
    for (i, entry) in list.iter().enumerate() {
        assert_eq!(entry.get("version").unwrap().usize().unwrap(), i + 1);
        assert!(entry.get("created_unix_s").unwrap().f64().unwrap() > 0.0);
    }
    let after: String = handle.list_versions()[1].plan.to_json(&model);
    assert_eq!(before, after, "an activated version must never mutate");

    // Versions 2 and 4 were created from the same spec: same plan bytes,
    // distinct version identities.
    let versions = handle.list_versions();
    assert_eq!(
        versions[1].plan.to_json(&model),
        versions[3].plan.to_json(&model)
    );
    assert_ne!(versions[1].version, versions[3].version);

    server.stop();
}

// ---------------------------------------------------------------------------
// In-process plan swap goes through the store
// ---------------------------------------------------------------------------

#[test]
fn swap_plan_body_records_a_store_version_and_activates() {
    let registry =
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 1, 4))]).unwrap();
    let handle = registry.get("m").unwrap();
    let model = synth_model("m");
    let inputs: Vec<Vec<f32>> = (0..6).map(|i| sample(11, i)).collect();
    let expect_b = reference_outputs("m", 42, &plan_b(&model), &inputs);

    // The direct in-process swap cannot bypass the store: the body
    // becomes immutable version 2 *and* activates in one call.
    let generation = handle.swap_plan_body(r#"{"spec": "default=exact8"}"#).unwrap();
    assert!(generation > 0);
    let versions = handle.list_versions();
    assert_eq!(versions.len(), 2, "the swap must be recorded as a store version");
    assert_eq!(versions[1].version, 2);
    assert_eq!(versions[1].source, "spec:default=exact8");

    // Traffic now runs the swapped plan and self-identifies as version 2.
    for (i, x) in inputs.iter().enumerate() {
        let resp = handle.infer(InferRequest::new(x.clone())).unwrap();
        assert_eq!(resp.version, 2);
        assert_eq!(resp.output, expect_b[i], "request {i} after swap");
    }

    // A broken body is rejected without minting a version or rerouting.
    assert!(handle.swap_plan_body(r#"{"spec": "default=no_such_acu"}"#).is_err());
    assert_eq!(handle.list_versions().len(), 2);
    let resp = handle.infer(InferRequest::new(inputs[0].clone())).unwrap();
    assert_eq!(resp.version, 2);
}

// ---------------------------------------------------------------------------
// Canary split
// ---------------------------------------------------------------------------

#[test]
fn canary_fraction_is_respected_exactly() {
    let registry = Arc::new(
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 2, 4))]).unwrap(),
    );
    let server =
        HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", ServeOptions::default())
            .unwrap();
    let addr = server.addr().to_string();
    let model = synth_model("m");
    let n = 40usize;
    let inputs: Vec<Vec<f32>> = (0..n).map(|i| sample(5, i)).collect();
    let expect_a = reference_outputs("m", 42, &plan_a(&model), &inputs);
    let expect_b = reference_outputs("m", 42, &plan_b(&model), &inputs);
    assert_ne!(expect_a, expect_b, "plans must differ on these inputs");

    // Create the candidate and canary 25% of traffic to it.
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=exact8"}"#);
    assert_eq!(status, 200);
    let candidate = j.get("version").unwrap().usize().unwrap() as u64;
    let (status, j) = post(
        &addr,
        &format!("/v2/models/m/plans/{candidate}/canary"),
        r#"{"fraction": 0.25}"#,
    );
    assert_eq!(status, 200, "canary start: {j:?}");

    // Drive n requests; responses self-identify their version, and each
    // must be bit-exact under that version's plan.
    let mut on_candidate = 0usize;
    for (i, x) in inputs.iter().enumerate() {
        let req = InferRequest::new(x.clone());
        let (status, j) = post(&addr, "/v2/models/m/infer", &req.to_json().to_string());
        assert_eq!(status, 200);
        let resp = adapt::service::InferResponse::from_json(&j).unwrap();
        match resp.version {
            1 => assert_eq!(resp.output, expect_a[i], "request {i} on active plan"),
            v if v == candidate => {
                on_candidate += 1;
                assert_eq!(resp.output, expect_b[i], "request {i} on candidate plan");
            }
            v => panic!("request {i} served by unexpected version {v}"),
        }
    }
    // The counter split is deterministic: exactly ⌊n · 0.25⌋.
    assert_eq!(on_candidate, n / 4, "canary split must be exact");

    // Stats expose the live canary state and counters.
    let (_, j) = get(&addr, "/v2/models/m/stats");
    let canary = j.get("canary").unwrap();
    assert_eq!(canary.get("version").unwrap().usize().unwrap() as u64, candidate);
    assert_eq!(canary.get("fraction").unwrap().f64().unwrap(), 0.25);
    assert_eq!(canary.get("routed").unwrap().usize().unwrap(), n / 4);
    assert_eq!(canary.get("seen").unwrap().usize().unwrap(), n);

    // Promote: activation ends the canary and flips all traffic.
    let (status, j) = post(&addr, &format!("/v2/models/m/plans/{candidate}/activate"), "{}");
    assert_eq!(status, 200, "promote: {j:?}");
    let (_, j) = get(&addr, "/v2/models/m/stats");
    assert_eq!(j.get("canary").unwrap(), &Json::Null);
    assert_eq!(j.get("active_version").unwrap().usize().unwrap() as u64, candidate);
    for (i, x) in inputs.iter().take(8).enumerate() {
        let req = InferRequest::new(x.clone());
        let (_, j) = post(&addr, "/v2/models/m/infer", &req.to_json().to_string());
        let resp = adapt::service::InferResponse::from_json(&j).unwrap();
        assert_eq!(resp.version, candidate);
        assert_eq!(resp.output, expect_b[i], "post-promote request {i}");
    }

    server.stop();
}

// ---------------------------------------------------------------------------
// Shadow evaluation vs offline recomputation
// ---------------------------------------------------------------------------

#[test]
fn shadow_stats_match_offline_recomputation() {
    // In-process (no sockets): exact control over inputs and counters.
    let registry =
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 2, 4))]).unwrap();
    let handle = registry.get("m").unwrap();
    let model = synth_model("m");
    let n = 24usize;
    let inputs: Vec<Vec<f32>> = (0..n).map(|i| sample(8, i)).collect();
    let expect_a = reference_outputs("m", 42, &plan_a(&model), &inputs);
    let expect_b = reference_outputs("m", 42, &plan_b(&model), &inputs);

    // Offline recomputation of what the live shadow comparison must see.
    let mut offline_disagree = 0u64;
    let mut offline_flips = 0u64;
    let mut offline_max = 0f32;
    let argmax = |xs: &[f32]| -> usize {
        let mut best = 0;
        for (i, v) in xs.iter().enumerate().skip(1) {
            if v.total_cmp(&xs[best]) == std::cmp::Ordering::Greater {
                best = i;
            }
        }
        best
    };
    for (a, b) in expect_a.iter().zip(&expect_b) {
        if a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits()) {
            offline_disagree += 1;
        }
        if argmax(a) != argmax(b) {
            offline_flips += 1;
        }
        for (x, y) in a.iter().zip(b) {
            offline_max = offline_max.max((x - y).abs());
        }
    }
    assert!(offline_disagree > 0, "plans must disagree for a meaningful test");

    // Create + shadow the candidate, then drive the same inputs.
    let pv = handle.create_version(r#"{"spec": "default=exact8"}"#).unwrap();
    handle.start_shadow(pv.version).unwrap();
    for (i, x) in inputs.iter().enumerate() {
        let resp = handle.infer(InferRequest::new(x.clone())).unwrap();
        // The primary answer stays on the active plan.
        assert_eq!(resp.version, 1);
        assert_eq!(resp.output, expect_a[i], "shadow must not disturb the primary");
    }

    // The collector runs asynchronously; wait for it to catch up.
    let deadline = Instant::now() + Duration::from_secs(30);
    let report = loop {
        let r = handle.shadow_report(pv.version).expect("stats entry exists");
        if r.mirrored + r.errors >= n as u64 {
            break r;
        }
        assert!(Instant::now() < deadline, "shadow collector did not catch up");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(report.errors, 0, "no mirror may fail");
    assert_eq!(report.mirrored, n as u64);
    assert_eq!(report.disagree, offline_disagree, "disagreement must match offline");
    assert_eq!(report.top1_flips, offline_flips, "flips must match offline");
    assert_eq!(
        report.max_abs_delta.to_bits(),
        offline_max.to_bits(),
        "max |Δ| must match offline exactly"
    );
    let expected_rate = offline_disagree as f64 / n as f64;
    assert!((report.disagreement_rate() - expected_rate).abs() < 1e-12);

    // Shadow traffic is mirrored, so the pool served 2n requests total.
    let stats = handle.service().stats();
    assert_eq!(stats.pool.total.requests, 2 * n);
}

// ---------------------------------------------------------------------------
// Activate / rollback integrity
// ---------------------------------------------------------------------------

#[test]
fn activate_and_rollback_never_mix_versions() {
    let registry =
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 2, 4))]).unwrap();
    let handle = registry.get("m").unwrap();
    let model = synth_model("m");
    let inputs: Vec<Vec<f32>> = (0..10).map(|i| sample(2, i)).collect();
    let expect_a = reference_outputs("m", 42, &plan_a(&model), &inputs);
    let expect_b = reference_outputs("m", 42, &plan_b(&model), &inputs);
    assert_ne!(expect_a, expect_b);
    let pv = handle.create_version(r#"{"spec": "default=exact8"}"#).unwrap();
    let candidate = pv.version;

    // Concurrent traffic while the active version flips twice: every
    // response must be bit-exact under the version it *claims* — the
    // observable form of "no batch mixes versions".
    std::thread::scope(|s| {
        let traffic = s.spawn(|| {
            let mut seen = BTreeMap::<u64, usize>::new();
            for round in 0..6 {
                for (i, x) in inputs.iter().enumerate() {
                    let resp = handle.infer(InferRequest::new(x.clone())).unwrap();
                    let expect = match resp.version {
                        1 => &expect_a[i],
                        v if v == candidate => &expect_b[i],
                        v => panic!("unexpected version {v}"),
                    };
                    assert_eq!(
                        &resp.output, expect,
                        "round {round} request {i}: output from a different \
                         version than the response claims"
                    );
                    *seen.entry(resp.version).or_insert(0) += 1;
                }
            }
            seen
        });
        // Interleave: promote, then roll back, mid-traffic.
        std::thread::sleep(Duration::from_millis(5));
        handle.activate(candidate).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let (back_to, _) = handle.rollback().unwrap();
        assert_eq!(back_to, 1, "rollback must return to the initial version");
        let seen = traffic.join().unwrap();
        // The flips really exposed traffic to both versions (sleep-based,
        // so only sanity-check presence, not exact counts).
        assert!(seen.contains_key(&1), "some traffic on the initial version");
    });

    // After rollback the active version serves plan A again, and a
    // second rollback ping-pongs to the candidate.
    let resp = handle.infer(InferRequest::new(inputs[0].clone())).unwrap();
    assert_eq!(resp.version, 1);
    assert_eq!(resp.output, expect_a[0]);
    let (forward_to, _) = handle.rollback().unwrap();
    assert_eq!(forward_to, candidate);
    let resp = handle.infer(InferRequest::new(inputs[0].clone())).unwrap();
    assert_eq!(resp.version, candidate);
    assert_eq!(resp.output, expect_b[0]);

    // Rollback state survives in stats.
    let (_, previous) = {
        let j = handle.stats_json();
        (
            j.get("active_version").unwrap().usize().unwrap() as u64,
            j.get("previous_version").unwrap().clone(),
        )
    };
    assert_eq!(previous.usize().unwrap() as u64, 1);
}

// ---------------------------------------------------------------------------
// v2 error surface
// ---------------------------------------------------------------------------

#[test]
fn v2_error_paths_are_typed() {
    let registry = Arc::new(
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 1, 4))]).unwrap(),
    );
    let server =
        HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", ServeOptions::default())
            .unwrap();
    let addr = server.addr().to_string();

    // Unknown model -> 404 model_not_found (infer + plans routes).
    let (status, j) = post(&addr, "/v2/models/nope/infer", "{\"input\": []}");
    assert_eq!(status, 404);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "model_not_found");

    // Unknown version -> 404 no_such_version.
    let (status, j) = post(&addr, "/v2/models/m/plans/9/activate", "{}");
    assert_eq!(status, 404);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "no_such_version");
    let (status, _) = post(&addr, "/v2/models/m/plans/9/shadow", "{}");
    assert_eq!(status, 404);

    // Canary needs a fraction in [0, 1].
    let (status, j) = post(&addr, "/v2/models/m/plans", r#"{"spec": "default=exact8"}"#);
    assert_eq!(status, 200);
    let v = j.get("version").unwrap().usize().unwrap();
    let (status, j) = post(
        &addr,
        &format!("/v2/models/m/plans/{v}/canary"),
        r#"{"fraction": 1.5}"#,
    );
    assert_eq!(status, 400);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "bad_request");
    let (status, _) = post(&addr, &format!("/v2/models/m/plans/{v}/canary"), "{}");
    assert_eq!(status, 400, "missing fraction is a 400");

    // Canarying or shadowing the active version is rejected.
    let (status, j) = post(&addr, "/v2/models/m/plans/1/canary", r#"{"fraction": 0.5}"#);
    assert_eq!(status, 422, "{j:?}");
    let (status, _) = post(&addr, "/v2/models/m/plans/1/shadow", "{}");
    assert_eq!(status, 422);

    // Rollback without history is rejected, not a crash.
    let (status, j) = post(&addr, "/v2/models/m/rollback", "{}");
    assert_eq!(status, 422);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "plan_rejected");

    // Wrong methods and unknown actions.
    let (status, text) = http_call(&addr, "GET", "/v2/models/m/infer", None).unwrap();
    assert_eq!(status, 405, "{text}");
    let (status, text) = http_call(&addr, "POST", "/v2/models", Some("{}")).unwrap();
    assert_eq!(status, 405, "{text}");
    let (status, text) =
        http_call(&addr, "POST", "/v2/models/m/plans/2/explode", Some("{}")).unwrap();
    assert_eq!(status, 404, "{text}");
    let (status, text) = http_call(&addr, "GET", "/v2/nope", None).unwrap();
    assert_eq!(status, 404, "{text}");

    // Bad version segment -> 400.
    let (status, text) =
        http_call(&addr, "POST", "/v2/models/m/plans/xyz/activate", Some("{}")).unwrap();
    assert_eq!(status, 400, "{text}");

    server.stop();
}

// ---------------------------------------------------------------------------
// HTTP hardening: idle timeout + connection cap
// ---------------------------------------------------------------------------

#[test]
fn idle_connections_time_out() {
    let registry = Arc::new(
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 1, 4))]).unwrap(),
    );
    let opts = ServeOptions {
        idle_timeout: Duration::from_millis(200),
        ..ServeOptions::default()
    };
    let server = HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // A connection that never sends a request is closed by the server
    // (read returns EOF) shortly after the idle deadline.
    let mut idle = TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    let n = idle.read(&mut buf).unwrap();
    assert_eq!(n, 0, "server must close the idle connection");
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(100) && waited < Duration::from_secs(5),
        "close should come from the idle deadline, took {waited:?}"
    );

    // A half-sent request that stalls is dropped too (thread unpinned).
    let mut stalled = TcpStream::connect(addr).unwrap();
    stalled
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stalled.write_all(b"POST /v1/infer HTTP/1.1\r\ncontent-").unwrap();
    let n = stalled.read(&mut buf).unwrap();
    assert_eq!(n, 0, "stalled mid-request connection must be dropped");

    // The server still serves fresh connections afterwards.
    let (status, _) = get(&addr.to_string(), "/v1/healthz");
    assert_eq!(status, 200);

    server.stop();
}

#[test]
fn connection_cap_refuses_with_503() {
    let registry = Arc::new(
        ModelRegistry::new(vec![("m".into(), make_service("m", 42, 1, 4))]).unwrap(),
    );
    let opts = ServeOptions {
        max_conns: 2,
        idle_timeout: Duration::from_secs(60), // keep the held conns alive
        ..ServeOptions::default()
    };
    let server = HttpServer::start_registry(Arc::clone(&registry), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // Occupy the cap with two held-open connections.
    let hold1 = TcpStream::connect(addr).unwrap();
    let hold2 = TcpStream::connect(addr).unwrap();
    // Give the accept loop a moment to register both.
    std::thread::sleep(Duration::from_millis(100));

    // The third connection is refused with a typed 503 and closed.
    let mut third = TcpStream::connect(addr).unwrap();
    third.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut text = String::new();
    third.read_to_string(&mut text).unwrap();
    assert!(text.starts_with("HTTP/1.1 503"), "got: {text}");
    assert!(text.contains("\"error\":\"overloaded\""), "got: {text}");

    // Freeing a slot lets the next connection through.
    drop(hold1);
    std::thread::sleep(Duration::from_millis(100));
    let (status, _) = get(&addr.to_string(), "/v1/healthz");
    assert_eq!(status, 200, "a freed slot must be reusable");

    drop(hold2);
    server.stop();
}
