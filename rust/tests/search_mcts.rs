//! `search::mcts` behavioral contract, artifact-free:
//!
//! 1. UCT selection math on hand-fed statistics (exploitation, exploration,
//!    virtual-loss deflation, unvisited-node priority),
//! 2. tree selection on a hand-built toy space prefers the branch with the
//!    higher committed reward,
//! 3. virtual-loss bookkeeping: planning playouts places one virtual loss
//!    per path node, commit swaps them for real visits, revert lifts them
//!    without recording a visit,
//! 4. the full search is bit-deterministic for a fixed seed at any worker
//!    pool size and GEMM thread count (byte-identical plan JSON),
//! 5. end-to-end: MCTS warm-started with greedy's incumbent is never worse
//!    than greedy at an equal evaluation budget.

use std::collections::BTreeMap;
use std::sync::Arc;

use adapt::coordinator::experiments::{self, EvalBatch, SweepCtx};
use adapt::emulator::Value;
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::search::mcts::{uct_score, LayerChoice, Mcts, MctsConfig, SearchSpace};
use adapt::search::{layer_macs, plan_cost_macs};
use adapt::tensor::Tensor;
use adapt::util::rng::Rng;
use adapt::util::threadpool::ThreadPool;

/// conv(3x3, 1->4, pad 1) -> relu -> conv(3x3, 4->4, pad 1) -> relu ->
/// flatten -> linear(64 -> 3), on 4x4x1 inputs. Same synthetic net as
/// `tests/plan_heterogeneous.rs`.
fn synth_model() -> Model {
    let conv = |id, cin, cout, scale_idx, name: &str, input, p0| Node {
        id,
        op: Op::Conv2d {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride: 1,
            pad: 1,
            groups: 1,
            scale_idx,
            name: name.into(),
        },
        inputs: vec![input],
        params: vec![p0, p0 + 1],
    };
    Model {
        name: "synth_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 3,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![3, 3, 4, 4] },
            ParamSpec { name: "b2".into(), shape: vec![4] },
            ParamSpec { name: "w3".into(), shape: vec![64, 3] },
            ParamSpec { name: "b3".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            conv(1, 1, 4, 0, "c1", 0, 0),
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            conv(3, 4, 4, 1, "c2", 2, 2),
            Node { id: 4, op: Op::Relu, inputs: vec![3], params: vec![] },
            Node { id: 5, op: Op::Flatten, inputs: vec![4], params: vec![] },
            Node {
                id: 6,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 2, name: "fc".into() },
                inputs: vec![5],
                params: vec![4, 5],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn scales() -> Vec<f32> {
    vec![1.5 / 127.0, 4.0 / 127.0, 4.0 / 127.0]
}

fn make_ctx(gemm_threads: usize) -> Arc<SweepCtx> {
    let model = synth_model();
    let params = synth_params(&model, 21);
    let bs = 4;
    let mut rng = Rng::new(99);
    let batches: Vec<EvalBatch> = (0..3)
        .map(|bi| {
            let x: Vec<f32> = (0..bs * 16).map(|_| rng.next_gauss()).collect();
            EvalBatch {
                input: Value::F(Tensor::from_vec(&[bs, 4, 4, 1], x).unwrap()),
                labels: (0..bs).map(|i| ((i + bi) % 3) as i32).collect(),
                target: vec![],
            }
        })
        .collect();
    Arc::new(SweepCtx {
        model,
        params,
        scales: scales(),
        luts: LutRegistry::in_memory(),
        batches,
        bs,
        gemm_threads,
        comp: None,
    })
}

/// Hand-built space over a subset of the synth model's layers; bypasses
/// the sweep so tree mechanics can be tested in isolation.
fn toy_space(model: &Model, nodes: &[(usize, &str)]) -> SearchSpace {
    let reference = retransform(model, &Policy::all(LayerMode::lut("exact8")));
    let macs = layer_macs(model);
    let ref_cost = plan_cost_macs(&macs, &reference);
    SearchSpace {
        layers: nodes
            .iter()
            .map(|(id, name)| LayerChoice {
                node: *id,
                name: (*name).into(),
                candidates: vec![LayerMode::lut("exact8"), LayerMode::lut("drum8_4")],
            })
            .collect(),
        reference,
        base_acc: 0.9,
        budget: 0.02,
        macs,
        ref_cost,
    }
}

#[test]
fn uct_selection_hand_math() {
    // Unvisited nodes always win selection.
    assert_eq!(uct_score(0.0, 0, 0, 10, 0.5), f64::INFINITY);

    // Committed stats: q + c * sqrt(ln(parent) / n).
    let c = 0.5;
    let s = uct_score(3.0, 4, 0, 20, c);
    let want = 3.0 / 4.0 + c * ((20f64).ln() / 4.0).sqrt();
    assert!((s - want).abs() < 1e-12, "{s} vs {want}");

    // A virtual loss is a zero-reward visit: it deflates both terms.
    let with_vloss = uct_score(3.0, 4, 2, 20, c);
    let want_v = 3.0 / 6.0 + c * ((20f64).ln() / 6.0).sqrt();
    assert!((with_vloss - want_v).abs() < 1e-12);
    assert!(with_vloss < s, "virtual loss must lower the score");

    // Higher mean reward wins at equal visit counts.
    assert!(uct_score(3.6, 4, 0, 20, c) > uct_score(3.0, 4, 0, 20, c));
    // Exploration: fewer visits win at equal mean reward.
    assert!(uct_score(1.0, 2, 0, 20, c) > uct_score(2.0, 4, 0, 20, c));
}

#[test]
fn toy_tree_selection_prefers_high_reward_branch() {
    let model = synth_model();
    let space = toy_space(&model, &[(1, "c1")]);
    let cfg = MctsConfig { seed: 1, evals: 8, ..MctsConfig::default() };
    let mut tree = Mcts::new(space, cfg);

    // Expansion order: candidate 0 (exact8) then candidate 1 (drum8_4).
    let p0 = tree.plan_playout();
    assert_eq!(p0.plan.mode_of(1).label(), "exact8");
    let p1 = tree.plan_playout();
    assert_eq!(p1.plan.mode_of(1).label(), "drum8_4");
    tree.commit(&p0, 0.2);
    tree.commit(&p1, 0.9);

    // Both children visited once; UCT's exploration terms are equal, so
    // the higher-Q (drum8_4) branch must be selected.
    let p2 = tree.plan_playout();
    assert_eq!(
        p2.plan.mode_of(1).label(),
        "drum8_4",
        "selection must follow the higher committed reward"
    );
    tree.commit(&p2, 0.9);
    assert_eq!(tree.root_visits(), 3);
    assert_eq!(tree.playouts_planned(), 3);
}

#[test]
fn virtual_loss_bookkeeping() {
    let model = synth_model();
    let space = toy_space(&model, &[(1, "c1"), (6, "fc")]);
    let cfg = MctsConfig { seed: 2, evals: 8, ..MctsConfig::default() };
    let mut tree = Mcts::new(space, cfg);
    assert_eq!(tree.total_vloss(), 0);

    // Playouts 0 and 1 expand the root's two children (path = root +
    // fresh leaf, 2 nodes each). Playout 2 descends a fully expanded
    // root and expands a depth-2 child (path = 3 nodes).
    let p0 = tree.plan_playout();
    assert_eq!(tree.total_vloss(), 2);
    let p1 = tree.plan_playout();
    assert_eq!(tree.total_vloss(), 4);
    let p2 = tree.plan_playout();
    assert_eq!(tree.total_vloss(), 7, "third playout holds a 3-node path");

    // Commit replaces each virtual loss with a real visit.
    tree.commit(&p0, 0.5);
    assert_eq!(tree.total_vloss(), 5);
    tree.commit(&p1, 0.5);
    tree.commit(&p2, 0.5);
    assert_eq!(tree.total_vloss(), 0, "all virtual losses released");
    assert_eq!(tree.root_visits(), 3);

    // Revert lifts the loss without recording a visit.
    let p3 = tree.plan_playout();
    assert!(tree.total_vloss() > 0);
    tree.revert(&p3);
    assert_eq!(tree.total_vloss(), 0);
    assert_eq!(tree.root_visits(), 3, "reverted playout must not count as a visit");
}

/// One full-search result bundle for the determinism and e2e tests.
struct RunResult {
    out: adapt::search::mcts::SearchOutcome,
    gplan: ExecutionPlan,
    gacc: f64,
    gevals: usize,
    /// Greedy's plan scored under the MCTS reward (same space).
    greward: f64,
}

/// Full search on the real scoring path; shared by the determinism and
/// e2e tests.
fn run_search(ctx: &Arc<SweepCtx>, pool: Option<&ThreadPool>, seed: u64, evals: usize) -> RunResult {
    let layers = ctx.layers();
    let acus = vec![
        "mul8s_1l2h_like".to_string(),
        "drum8_4".to_string(),
        "trunc_out8_4".to_string(),
    ];
    let reference = retransform(&ctx.model, &Policy::all(LayerMode::lut("exact8")));
    let base_acc = ctx.eval_plan(reference.clone()).unwrap();
    let budget = 0.5;
    let pair = experiments::sweep_pairs(ctx, &reference, &layers, &acus, pool).unwrap();
    let worst = experiments::worst_drops(base_acc, &pair, layers.len(), acus.len());
    let (gplan, gacc, gevals) = experiments::greedy_mixed(
        ctx, &reference, "exact8", base_acc, &layers, &worst, &acus, budget,
    )
    .unwrap();
    let space = SearchSpace::build(
        &ctx.model, reference, "exact8", base_acc, budget, &layers, &pair, &acus,
    )
    .unwrap();
    let greward = space.reward(gacc, &gplan);
    let cfg = MctsConfig { seed, evals, ..MctsConfig::default() };
    let out =
        adapt::search::mcts::search(ctx, space, &cfg, Some((&gplan, gacc)), pool, None).unwrap();
    RunResult { out, gplan, gacc, gevals, greward }
}

#[test]
fn search_is_deterministic_across_pools_and_gemm_threads() {
    // PROPERTY: for a fixed seed the search result — plan JSON bytes,
    // accuracy, eval count, playout count — is identical sequentially,
    // on worker pools of any size, and at any GEMM thread count.
    let ctx1 = make_ctx(1);
    let base = run_search(&ctx1, None, 0x5EED, 12);
    let base_json = base.out.plan.to_json(&ctx1.model);

    for workers in [2usize, 4] {
        let pool = ThreadPool::new(workers);
        for gemm_threads in [1usize, 4] {
            let ctx = make_ctx(gemm_threads);
            for round in 0..2 {
                let run = run_search(&ctx, Some(&pool), 0x5EED, 12);
                assert_eq!(
                    run.out.plan.to_json(&ctx.model),
                    base_json,
                    "plan JSON diverged: {workers} workers, {gemm_threads} gemm threads, round {round}"
                );
                assert_eq!(run.out.accuracy, base.out.accuracy);
                assert_eq!(run.out.evals, base.out.evals);
                assert_eq!(run.out.playouts, base.out.playouts);
                assert_eq!(run.out.cache_hits, base.out.cache_hits);
            }
        }
    }

    // A different seed is allowed to explore differently — the contract
    // is per-seed determinism, not seed-independence. (No assertion on
    // inequality: small spaces can converge to the same plan.)
    let other = run_search(&ctx1, None, 0xBEEF, 12);
    assert!(other.out.evals <= 12);
}

#[test]
fn mcts_never_worse_than_greedy_at_equal_budget() {
    let ctx = make_ctx(1);
    let run = run_search(&ctx, None, 0x5EED, 12);
    assert!(run.out.evals <= 12, "budget of fresh evals is hard: {}", run.out.evals);
    assert!(run.gevals > 0, "greedy must have spent evaluations");

    // Reward is the search's own total order; MCTS saw greedy's plan as
    // its incumbent, so its pick can never score lower — a guarantee,
    // not a hope.
    assert!(
        run.out.reward >= run.greward,
        "MCTS reward {} fell below greedy's {}",
        run.out.reward,
        run.greward
    );
    assert!(run.out.reward <= 1.0);

    // The reward order implies non-domination on the raw axes too: equal
    // reward means no worse savings within the same feasibility class.
    let macs = layer_macs(&ctx.model);
    let g_cost = plan_cost_macs(&macs, &run.gplan);
    let m_cost = plan_cost_macs(&macs, &run.out.plan);
    assert!(
        run.out.accuracy > run.gacc - 1e-12 || m_cost < g_cost + 1e-12,
        "MCTS dominated by greedy: acc {} vs {}, cost {m_cost} vs {g_cost}",
        run.out.accuracy,
        run.gacc
    );

    // Round-trip: the winning plan serializes and reloads losslessly.
    let json = run.out.plan.to_json(&ctx.model);
    let reloaded = ExecutionPlan::from_json(&json, &ctx.model).unwrap();
    assert_eq!(reloaded, run.out.plan);
    let re_acc = ctx.eval_plan(reloaded).unwrap();
    assert_eq!(re_acc, run.out.accuracy, "reloaded plan must score identically");
}
