//! Wire-path tests for the `/v1` serving API: typed error responses with
//! correct status codes, exactly-once concurrent round-trips matching
//! `infer()` reference outputs bit-for-bit, live plan hot-swap with
//! generation integrity, and mid-run stats — all artifact-free on the
//! emulator backend over real TCP connections.
//!
//! The second half is adversarial transport tests against the
//! readiness-loop front-end: slowloris header trickling hits the idle
//! deadline, stalled readers are dropped mid-body while cooperating
//! clients get every byte of a response far larger than the send
//! buffer, pipelined requests beyond `PIPELINE_MAX` come back in
//! order, the connection cap refuses with a typed 503 and recovers,
//! and the portable `poll(2)` backend serves the same load.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::service::client::{self, http_call};
use adapt::service::http::{HttpServer, ServeOptions};
use adapt::service::net::conn::PIPELINE_MAX;
use adapt::service::net::{self, Backend};
use adapt::service::{AdaptService, InferRequest, ServiceError};
use adapt::tensor::Tensor;
use adapt::util::json::Json;
use adapt::util::rng::Rng;

/// conv(3x3, 1->4, pad 1) -> relu -> flatten -> linear(64 -> 3), on
/// 4x4x1 inputs (the same shape `engine_batching.rs` exercises).
fn synth_model() -> Model {
    Model {
        name: "service_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![64, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Conv2d {
                    kh: 3,
                    kw: 3,
                    cin: 1,
                    cout: 4,
                    stride: 1,
                    pad: 1,
                    groups: 1,
                    scale_idx: 0,
                    name: "c1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1],
            },
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            Node { id: 3, op: Op::Flatten, inputs: vec![2], params: vec![] },
            Node {
                id: 4,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 1, name: "fc".into() },
                inputs: vec![3],
                params: vec![2, 3],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn scales() -> Vec<f32> {
    vec![1.5 / 127.0, 4.0 / 127.0]
}

/// Generation-0 plan: mixed (c1 on exact8, fc on mul8s_1l2h_like).
fn plan_a(model: &Model) -> ExecutionPlan {
    retransform(
        model,
        &Policy::all(LayerMode::lut("mul8s_1l2h_like")).with_acu("c1", "exact8"),
    )
}

/// Swap target: everything on exact8 (visibly different arithmetic).
fn plan_b(model: &Model) -> ExecutionPlan {
    retransform(model, &Policy::all(LayerMode::lut("exact8")))
}

fn make_spec(batch: usize) -> EmulatorSpec {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let plan = plan_a(&model);
    EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales(),
        luts: LutRegistry::in_memory(),
        batch,
        gemm_threads: 1,
    }
}

/// Deterministic per-(client, request) input sample.
fn sample(c: usize, i: usize) -> Vec<f32> {
    let mut rng = Rng::new((c * 1000 + i) as u64 + 7);
    (0..16).map(|_| rng.next_gauss()).collect()
}

/// Reference outputs from a plain single-threaded executor on `plan`.
fn reference_outputs(plan: &ExecutionPlan, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        &model,
        params,
        plan.clone(),
        scales(),
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    inputs
        .iter()
        .map(|x| {
            let t = Tensor::from_vec(&[1, 4, 4, 1], x.clone()).unwrap();
            exec.forward(Value::F(t)).unwrap().data
        })
        .collect()
}

fn start_server(
    workers: usize,
    batch: usize,
    opts: ServeOptions,
) -> (Arc<AdaptService>, HttpServer) {
    let mut cfg = EngineConfig::emulator(make_spec(batch));
    cfg.workers = workers;
    cfg.queue_depth = 64;
    cfg.max_wait = Duration::from_millis(2);
    let service = Arc::new(AdaptService::start(cfg).unwrap());
    let server = HttpServer::start_with(Arc::clone(&service), "127.0.0.1:0", opts).unwrap();
    (service, server)
}

fn post_infer(addr: &str, body: &str) -> (u16, Json) {
    let (status, text) = http_call(addr, "POST", "/v1/infer", Some(body)).unwrap();
    (status, Json::parse(&text).expect("every response body is JSON"))
}

#[test]
fn error_paths_have_typed_bodies_and_status_codes() {
    let opts = ServeOptions {
        max_body: 1024,
        ..ServeOptions::default()
    };
    let (_service, server) = start_server(1, 4, opts);
    let addr = server.addr().to_string();

    // Malformed JSON body -> 400 bad_request.
    let (status, j) = post_infer(&addr, "this is not json {");
    assert_eq!(status, 400);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "bad_request");

    // Well-formed JSON, missing the input field -> 400 bad_request.
    let (status, j) = post_infer(&addr, r#"{"id": 3}"#);
    assert_eq!(status, 400);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "bad_request");

    // Wrong input length -> 400 wrong_input_length, and the message names
    // both lengths.
    let (status, j) = post_infer(&addr, r#"{"input": [1, 2, 3]}"#);
    assert_eq!(status, 400);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "wrong_input_length");
    assert!(j.get("message").unwrap().str().unwrap().contains("16"));

    // Oversized body -> 413 before the request is even parsed.
    let huge = format!(r#"{{"input": [{}]}}"#, "1.0, ".repeat(400) + "1.0");
    assert!(huge.len() > 1024);
    let (status, j) = post_infer(&addr, &huge);
    assert_eq!(status, 413);
    assert_eq!(j.get("error").unwrap().str().unwrap(), "body_too_large");

    // Unknown route -> 404 not_found.
    let (status, text) = http_call(&addr, "POST", "/v1/nope", Some("{}")).unwrap();
    assert_eq!(status, 404);
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("error").unwrap().str().unwrap(), "not_found");

    // Known route, wrong method -> 405.
    let (status, text) = http_call(&addr, "GET", "/v1/infer", None).unwrap();
    assert_eq!(status, 405);
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("error").unwrap().str().unwrap(), "method_not_allowed");

    server.stop();
}

#[test]
fn concurrent_clients_get_exactly_once_reference_outputs() {
    let (_service, server) = start_server(2, 4, ServeOptions::default());
    let addr = server.addr().to_string();
    let (n_clients, per_client) = (4, 12);
    let model = synth_model();
    let expected: Vec<Vec<Vec<f32>>> = (0..n_clients)
        .map(|c| {
            let inputs: Vec<Vec<f32>> = (0..per_client).map(|i| sample(c, i)).collect();
            reference_outputs(&plan_a(&model), &inputs)
        })
        .collect();

    std::thread::scope(|s| {
        for c in 0..n_clients {
            let addr = &addr;
            let expected = &expected;
            s.spawn(move || {
                for i in 0..per_client {
                    let mut req = InferRequest::new(sample(c, i));
                    let id = (c * 1000 + i) as u64;
                    req.id = Some(id);
                    req.top_k = Some(1);
                    let (status, j) = post_infer(addr, &req.to_json().to_string());
                    assert_eq!(status, 200, "client {c} request {i}");
                    let resp = adapt::service::InferResponse::from_json(&j).unwrap();
                    assert_eq!(resp.id, id, "swapped response");
                    // Batch rows are independent in every GEMM and f32
                    // survives JSON bit-for-bit, so the wire output must
                    // equal the local reference exactly.
                    assert_eq!(
                        resp.output, expected[c][i],
                        "client {c} request {i}: wrong output over the wire"
                    );
                    let tk = resp.top_k.unwrap();
                    assert_eq!(tk.len(), 1);
                    assert_eq!(tk[0].1, resp.output[tk[0].0]);
                    assert_eq!(resp.generation, 0);
                }
            });
        }
    });

    // Live stats report everything served, while the pool is still up.
    let (status, text) = http_call(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&text).unwrap();
    let total = j.get("total").unwrap();
    assert_eq!(
        total.get("requests").unwrap().usize().unwrap(),
        n_clients * per_client
    );
    assert!(total.get("batches").unwrap().usize().unwrap() >= 1);
    assert_eq!(
        j.get("per_worker").unwrap().arr().unwrap().len(),
        2,
        "stats must be per-worker"
    );
    // Histogram percentiles are present and ordered.
    let p50 = total.get("queue_wait_p50_us").unwrap().usize().unwrap();
    let p99 = total.get("queue_wait_p99_us").unwrap().usize().unwrap();
    assert!(p50 <= p99, "p50 {p50} must not exceed p99 {p99}");

    server.stop();
}

#[test]
fn healthz_reports_service_shape() {
    let (_service, server) = start_server(2, 4, ServeOptions::default());
    let addr = server.addr().to_string();
    let (status, text) = http_call(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("status").unwrap().str().unwrap(), "ok");
    assert_eq!(j.get("model").unwrap().str().unwrap(), "service_cnn");
    assert_eq!(j.get("input_len").unwrap().usize().unwrap(), 16);
    assert_eq!(j.get("out_dim").unwrap().usize().unwrap(), 3);
    assert_eq!(j.get("workers").unwrap().usize().unwrap(), 2);
    assert_eq!(j.get("workers_alive").unwrap().usize().unwrap(), 2);
    assert_eq!(j.get("generation").unwrap().usize().unwrap(), 0);
    assert_eq!(client::discover_input_len(&addr).unwrap(), 16);
    server.stop();
}

#[test]
fn plan_hot_swap_is_bit_identical_to_fresh_engines() {
    let (_service, server) = start_server(2, 4, ServeOptions::default());
    let addr = server.addr().to_string();
    let model = synth_model();
    let inputs: Vec<Vec<f32>> = (0..10).map(|i| sample(7, i)).collect();
    let expect_a = reference_outputs(&plan_a(&model), &inputs);
    let expect_b = reference_outputs(&plan_b(&model), &inputs);
    // The two plans must actually disagree somewhere, or the swap check
    // below is vacuous.
    assert_ne!(expect_a, expect_b, "plans must differ on these inputs");

    let run_inputs = |tag: u64| -> Vec<(Vec<f32>, u64)> {
        inputs
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let mut req = InferRequest::new(x.clone());
                req.id = Some(tag * 100 + i as u64);
                let (status, j) = post_infer(&addr, &req.to_json().to_string());
                assert_eq!(status, 200);
                let resp = adapt::service::InferResponse::from_json(&j).unwrap();
                (resp.output, resp.generation)
            })
            .collect()
    };

    // Generation 0 serves plan A.
    for (i, (out, generation)) in run_inputs(1).into_iter().enumerate() {
        assert_eq!(out, expect_a[i], "generation 0 must serve plan A");
        assert_eq!(generation, 0);
    }

    // Hot-swap to plan B via a policy-spec body.
    let (status, text) =
        http_call(&addr, "POST", "/v1/plan", Some(r#"{"spec": "default=exact8"}"#)).unwrap();
    assert_eq!(status, 200, "swap rejected: {text}");
    let generation = Json::parse(&text)
        .unwrap()
        .get("generation")
        .unwrap()
        .usize()
        .unwrap();
    assert_eq!(generation, 1);

    // Every post-swap response must be plan B, bit-identical to a fresh
    // engine started on plan B, and must carry the new generation — no
    // batch may mix generations.
    for (i, (out, generation)) in run_inputs(2).into_iter().enumerate() {
        assert_eq!(out, expect_b[i], "generation 1 must serve plan B");
        assert_eq!(generation, 1);
    }

    // A plan JSON document body (what `adapt plan --out` writes) works
    // too, and bumps the generation again — back to plan A.
    let body = plan_a(&model).to_json(&model);
    let (status, text) = http_call(&addr, "POST", "/v1/plan", Some(&body)).unwrap();
    assert_eq!(status, 200, "plan-document swap rejected: {text}");
    for (i, (out, generation)) in run_inputs(3).into_iter().enumerate() {
        assert_eq!(out, expect_a[i], "generation 2 must serve plan A again");
        assert_eq!(generation, 2);
    }

    // Bad plans are rejected with a typed error and do NOT disturb the
    // serving generation.
    let (status, text) =
        http_call(&addr, "POST", "/v1/plan", Some(r#"{"spec": "default=no_such_acu"}"#)).unwrap();
    assert_eq!(status, 422);
    assert_eq!(
        Json::parse(&text).unwrap().get("error").unwrap().str().unwrap(),
        "plan_rejected"
    );
    let (status, text) =
        http_call(&addr, "POST", "/v1/plan", Some(r#"{"spec": "nope=exact8"}"#)).unwrap();
    assert_eq!(status, 422, "spec matching no layer must be rejected: {text}");
    for (i, (out, generation)) in run_inputs(4).into_iter().enumerate() {
        assert_eq!(out, expect_a[i], "rejected swaps must not change the plan");
        assert_eq!(generation, 2);
    }

    server.stop();
}

#[test]
fn load_generator_roundtrips_and_sees_the_swap() {
    let (service, server) = start_server(2, 4, ServeOptions::default());
    let addr = server.addr().to_string();
    let cfg = client::LoadConfig {
        addr: addr.clone(),
        requests: 40,
        concurrency: 3,
        input_len: 16,
        top_k: Some(2),
        deadline_ms: None,
        seed: 11,
    };
    let phase1 = client::run_load(&cfg).unwrap();
    assert_eq!(phase1.ok, 40);
    assert_eq!(phase1.errors, 0);
    assert_eq!(phase1.by_generation.keys().copied().collect::<Vec<_>>(), vec![0]);
    assert_eq!(phase1.latencies_us.len(), 40);

    let (status, _) =
        http_call(&addr, "POST", "/v1/plan", Some(r#"{"spec": "default=exact8"}"#)).unwrap();
    assert_eq!(status, 200);
    let phase2 = client::run_load(&cfg).unwrap();
    assert_eq!(phase2.ok, 40);
    assert_eq!(
        phase2.by_generation.keys().copied().collect::<Vec<_>>(),
        vec![1],
        "all post-swap responses must carry the new generation"
    );

    // The service-level totals agree with both phases.
    let stats = service.stats();
    assert_eq!(stats.pool.total.requests, 80);
    assert_eq!(stats.generation, 1);
    server.stop();
}

#[test]
fn typed_service_layer_without_http() {
    // The control plane works in-process too (no sockets): typed
    // submit/infer, deadline rejection, mid-run stats, engine shims.
    let mut cfg = EngineConfig::emulator(make_spec(4));
    cfg.workers = 1;
    cfg.max_wait = Duration::from_millis(1);
    let service = AdaptService::start(cfg).unwrap();

    // Typed round-trip with auto-assigned id + top-k.
    let mut req = InferRequest::new(sample(0, 0));
    req.top_k = Some(3);
    let resp = service.infer(req).unwrap();
    assert_eq!(resp.output.len(), 3);
    assert_eq!(resp.top_k.as_ref().unwrap().len(), 3);
    assert_eq!(resp.worker, 0);

    // Wrong input length is rejected before it occupies a queue slot.
    match service.infer(InferRequest::new(vec![0.0; 5])) {
        Err(ServiceError::WrongInputLength { got: 5, expected: 16 }) => {}
        other => panic!("expected WrongInputLength, got {other:?}"),
    }

    // A zero deadline always expires in-queue -> typed rejection.
    let mut req = InferRequest::new(sample(0, 1));
    req.deadline = Some(Duration::ZERO);
    match service.infer(req) {
        Err(ServiceError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }

    // The legacy engine shim still works on the same pool.
    let out = service.engine().infer(sample(0, 2)).unwrap();
    assert_eq!(out.len(), 3);

    // Mid-run stats: the expired request is not counted as served.
    let stats = service.stats();
    assert_eq!(stats.pool.total.requests, 2);
    assert_eq!(stats.workers, 1);
    // Queue-wait histogram saw every popped request (incl. the expired
    // one); compute histogram only the two served.
    assert_eq!(stats.pool.total.queue_hist.count(), 3);
    assert_eq!(stats.pool.total.compute_hist.count(), 2);

    let final_stats = service.shutdown().unwrap();
    assert_eq!(final_stats.total.requests, 2);
}

// ---------------------------------------------------------------------
// Adversarial transport tests against the readiness-loop front-end.
// ---------------------------------------------------------------------

/// `synth_model` with the final linear widened to 8192 outputs, so one
/// response body is ~100 KB of JSON — enough to overflow a small
/// `SO_SNDBUF` and force the server's partial-write path.
fn wide_model() -> Model {
    let mut m = synth_model();
    m.name = "service_cnn_wide".into();
    m.out_dim = 8192;
    m.params[2] = ParamSpec { name: "w2".into(), shape: vec![64, 8192] };
    m.params[3] = ParamSpec { name: "b2".into(), shape: vec![8192] };
    m.nodes[4].op = Op::Linear { din: 64, dout: 8192, scale_idx: 1, name: "fc".into() };
    m
}

fn start_wide_server(opts: ServeOptions) -> (Arc<AdaptService>, HttpServer) {
    let model = wide_model();
    let params = synth_params(&model, 42);
    let plan = plan_a(&model);
    let spec = EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales(),
        luts: LutRegistry::in_memory(),
        batch: 4,
        gemm_threads: 1,
    };
    let mut cfg = EngineConfig::emulator(spec);
    cfg.workers = 1;
    cfg.queue_depth = 64;
    cfg.max_wait = Duration::from_millis(2);
    let service = Arc::new(AdaptService::start(cfg).unwrap());
    let server = HttpServer::start_with(Arc::clone(&service), "127.0.0.1:0", opts).unwrap();
    (service, server)
}

/// A raw keep-alive `POST /v1/infer` request with `input_len` inputs.
fn raw_infer_request(input_len: usize, id: u64) -> Vec<u8> {
    let input = vec!["0.5"; input_len].join(", ");
    let body = format!(r#"{{"id": {id}, "input": [{input}]}}"#);
    format!(
        "POST /v1/infer HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Pull one HTTP response off `stream`; `carry` holds bytes already
/// read past the previous response (pipelined responses share reads).
fn read_one_response(stream: &mut TcpStream, carry: &mut Vec<u8>) -> (u16, String) {
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = carry.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos + 4;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed before the response head");
        carry.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(carry[..head_end].to_vec()).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let content_length: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("content-length: "))
        .expect("response must carry content-length")
        .trim()
        .parse()
        .unwrap();
    while carry.len() < head_end + content_length {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-body");
        carry.extend_from_slice(&chunk[..n]);
    }
    let body = String::from_utf8(carry[head_end..head_end + content_length].to_vec()).unwrap();
    carry.drain(..head_end + content_length);
    (status, body)
}

#[test]
fn slowloris_connections_hit_the_idle_deadline() {
    let opts = ServeOptions {
        idle_timeout: Duration::from_millis(300),
        ..ServeOptions::default()
    };
    let (_service, server) = start_server(1, 4, opts);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(25))).unwrap();
    let started = Instant::now();

    // Trickle a syntactically fine request one byte at a time, far too
    // slowly to ever finish. The idle deadline covers completing a
    // request, and trickling bytes must NOT extend it.
    let head = b"POST /v1/infer HTTP/1.1\r\ncontent-length: 100000\r\n\r\n";
    let mut closed = false;
    'trickle: for byte in head.iter().cycle().take(400) {
        if stream.write_all(std::slice::from_ref(byte)).is_err() {
            closed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
        let mut probe = [0u8; 64];
        loop {
            match stream.read(&mut probe) {
                Ok(0) => {
                    closed = true;
                    break 'trickle;
                }
                Ok(_) => {} // ignore anything the server sends back
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    break
                }
                Err(_) => {
                    closed = true;
                    break 'trickle;
                }
            }
        }
    }
    let elapsed = started.elapsed();
    assert!(closed, "server never dropped the slowloris connection");
    assert!(elapsed >= Duration::from_millis(200), "dropped too early: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(5), "idle deadline never fired: {elapsed:?}");
    server.stop();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let (_service, server) = start_server(2, 4, ServeOptions::default());
    // Deliberately more than PIPELINE_MAX queued requests on one
    // connection: the server must shed read interest when the queue
    // fills, drain, and resume without losing or reordering anything.
    let n = (PIPELINE_MAX + 4) as u64;
    let mut batch = Vec::new();
    for id in 0..n {
        batch.extend_from_slice(&raw_infer_request(16, id));
    }
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(&batch).unwrap();
    let mut carry = Vec::new();
    for id in 0..n {
        let (status, body) = read_one_response(&mut stream, &mut carry);
        assert_eq!(status, 200, "pipelined request {id}: {body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(
            j.get("id").unwrap().usize().unwrap() as u64,
            id,
            "responses must come back in request order"
        );
    }
    server.stop();
}

#[test]
fn stalled_readers_are_dropped_at_the_idle_deadline() {
    // A ~100 KB response against a 4 KB server send buffer: the server
    // must park the remainder, switch to write interest, and — when
    // the client never drains — drop the connection at the idle
    // deadline instead of blocking an event loop on it.
    let opts = ServeOptions {
        idle_timeout: Duration::from_millis(400),
        sndbuf: Some(4096),
        ..ServeOptions::default()
    };
    let (_service, server) = start_wide_server(opts);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    net::set_recv_buffer(&stream, 4096).unwrap();
    stream.write_all(&raw_infer_request(16, 1)).unwrap();

    // Let the response compute, the partial write stall, and the idle
    // deadline pass without reading a byte.
    std::thread::sleep(Duration::from_millis(1500));

    // Drain what the kernel buffered: EOF (or a reset) must arrive
    // before the promised body completes.
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut got = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => got.extend_from_slice(&chunk[..n]),
            Err(_) => break, // a reset also counts as dropped
        }
    }
    let head_end = got
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .expect("at least the response head must have been delivered");
    let head = String::from_utf8_lossy(&got[..head_end]).to_string();
    let content_length: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("content-length: "))
        .unwrap()
        .trim()
        .parse()
        .unwrap();
    assert!(
        got.len() - head_end < content_length,
        "the stalled connection must be dropped mid-body, not handed all \
         {content_length} bytes"
    );
    server.stop();
}

#[test]
fn partial_writes_resume_when_the_client_drains() {
    // Same oversized response and tiny buffers, but the client comes
    // back for the rest: the write-interest path must deliver every
    // byte of the parked remainder.
    let opts = ServeOptions {
        sndbuf: Some(4096),
        ..ServeOptions::default()
    };
    let (_service, server) = start_wide_server(opts);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    net::set_recv_buffer(&stream, 4096).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(&raw_infer_request(16, 9)).unwrap();
    // Give the server time to fill the send buffer and stall.
    std::thread::sleep(Duration::from_millis(300));
    let mut carry = Vec::new();
    let (status, body) = read_one_response(&mut stream, &mut carry);
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("id").unwrap().usize().unwrap(), 9);
    assert_eq!(j.get("output").unwrap().arr().unwrap().len(), 8192);
    server.stop();
}

#[test]
fn connection_cap_returns_503_and_recovers() {
    let opts = ServeOptions {
        max_conns: 2,
        idle_timeout: Duration::from_secs(60), // keep the held conns alive
        ..ServeOptions::default()
    };
    let (_service, server) = start_server(1, 4, opts);
    let addr = server.addr().to_string();

    // Occupy the cap with two held-open connections.
    let hold1 = TcpStream::connect(&*addr).unwrap();
    let hold2 = TcpStream::connect(&*addr).unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // The third connection is refused with a typed 503 and closed.
    let mut third = TcpStream::connect(&*addr).unwrap();
    third.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut text = String::new();
    third.read_to_string(&mut text).unwrap();
    assert!(text.starts_with("HTTP/1.1 503"), "got: {text}");
    assert!(text.contains("\"error\":\"overloaded\""), "got: {text}");

    // Dropping a held connection frees its slot without any request —
    // the event loop notices the EOF, not a read timeout.
    drop(hold1);
    std::thread::sleep(Duration::from_millis(200));
    let (status, _) = http_call(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(status, 200, "a freed slot must be reusable");
    drop(hold2);
    server.stop();
}

#[test]
fn poll_backend_serves_identical_load() {
    let opts = ServeOptions {
        net: Some(Backend::Poll),
        ..ServeOptions::default()
    };
    let (service, server) = start_server(2, 4, opts);
    assert_eq!(server.backend(), Backend::Poll);
    let cfg = client::LoadConfig {
        addr: server.addr().to_string(),
        requests: 40,
        concurrency: 8,
        input_len: 16,
        top_k: Some(1),
        deadline_ms: None,
        seed: 23,
    };
    let report = client::run_load(&cfg).unwrap();
    assert_eq!(report.ok, 40);
    assert_eq!(report.errors, 0);
    assert_eq!(service.stats().pool.total.requests, 40);
    server.stop();
}
