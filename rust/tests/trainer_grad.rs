//! Trainer subsystem: gradient checks for the clipped-STE backward,
//! taped-forward equivalence, thread-count determinism of `fit`, and the
//! headline property — QAT-retraining a mixed-ACU plan measurably
//! recovers accuracy on the bundled tiny dataset. Everything here is
//! artifact-free (in-memory models, synthetic data).

use std::collections::BTreeMap;

use adapt::data::Split;
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::quant;
use adapt::trainer::{self, backward, loss_and_grad, synth, LossKind, Workspace};
use adapt::tensor::Tensor;
use adapt::util::rng::Rng;

/// conv(3x3, 1->3, pad 1) -> tanh -> avgpool2 -> flatten -> linear(12->3)
/// on 4x4x1 inputs: one of every backward kind the grad check needs.
/// (tanh, not relu: the finite-difference check needs a smooth loss — the
/// relu backward is exercised by the tiny_cnn recovery test instead.)
fn grad_model() -> Model {
    Model {
        name: "grad_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 3] },
            ParamSpec { name: "b1".into(), shape: vec![3] },
            ParamSpec { name: "w2".into(), shape: vec![12, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Conv2d {
                    kh: 3,
                    kw: 3,
                    cin: 1,
                    cout: 3,
                    stride: 1,
                    pad: 1,
                    groups: 1,
                    scale_idx: 0,
                    name: "c1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1],
            },
            Node { id: 2, op: Op::Tanh, inputs: vec![1], params: vec![] },
            Node { id: 3, op: Op::AvgPool2, inputs: vec![2], params: vec![] },
            Node { id: 4, op: Op::Flatten, inputs: vec![3], params: vec![] },
            Node {
                id: 5,
                op: Op::Linear { din: 12, dout: 3, scale_idx: 1, name: "fc".into() },
                inputs: vec![4],
                params: vec![2, 3],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn grad_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn grad_input(seed: u64, n: usize) -> Tensor {
    let mut rng = Rng::new(seed);
    let data = (0..n * 16).map(|_| rng.next_gauss() * 0.8).collect();
    Tensor::from_vec(&[n, 4, 4, 1], data).unwrap()
}

fn ce_loss_of(
    model: &Model,
    params: &[Tensor],
    plan: &ExecutionPlan,
    scales: &[f32],
    luts: &LutRegistry,
    x: &Tensor,
    labels: &[i32],
) -> f32 {
    let exec = Executor::new(
        model,
        params.to_vec(),
        plan.clone(),
        scales.to_vec(),
        luts,
        Style::Optimized { threads: 2 },
    )
    .unwrap();
    let out = exec.forward(Value::F(x.clone())).unwrap();
    loss_and_grad(LossKind::CrossEntropy, &out, labels, &[]).unwrap().0
}

#[test]
fn taped_forward_matches_inference_forward() {
    // PROPERTY: forward_taped computes node-for-node exactly what the
    // recycling forward computes — on a heterogeneous mixed-ACU plan.
    let model = grad_model();
    let params = grad_params(&model, 11);
    let plan = retransform(
        &model,
        &Policy::all(LayerMode::lut("mitchell8")).with_acu("fc", "exact8"),
    );
    let luts = LutRegistry::in_memory();
    let scales = vec![1.5 / 127.0, 3.0 / 127.0];
    let exec = Executor::new(
        &model,
        params,
        plan,
        scales,
        &luts,
        Style::Optimized { threads: 2 },
    )
    .unwrap();
    let x = grad_input(12, 3);
    let plain = exec.forward(Value::F(x.clone())).unwrap();
    let tape = exec.forward_taped(Value::F(x.clone())).unwrap();
    let last = model.nodes.last().unwrap().id;
    match tape[last].as_ref().unwrap() {
        Value::F(t) => assert_eq!(t.data, plain.data, "taped forward diverged"),
        _ => panic!("expected f32 output"),
    }
    // Running the plain forward again after a taped one must still agree
    // (the tape must not corrupt the scratch arena).
    let again = exec.forward(Value::F(x)).unwrap();
    assert_eq!(again.data, plain.data);
}

#[test]
fn fp32_backward_matches_finite_differences() {
    // Finite-difference gradient check on the all-fp32 plan (the exact,
    // smooth path): validates conv/pool/flatten/linear backward plumbing,
    // the transpose GEMM kernels and the col2im scatter.
    let model = grad_model();
    let params = grad_params(&model, 21);
    let plan = retransform(&model, &Policy::all(LayerMode::Fp32));
    let luts = LutRegistry::in_memory();
    let scales: Vec<f32> = vec![];
    let x = grad_input(22, 4);
    let labels = [0i32, 2, 1, 2];

    let exec = Executor::new(
        &model,
        params.clone(),
        plan.clone(),
        scales.clone(),
        &luts,
        Style::Optimized { threads: 2 },
    )
    .unwrap();
    let tape = exec.forward_taped(Value::F(x.clone())).unwrap();
    let last = model.nodes.last().unwrap().id;
    let out = match tape[last].as_ref().unwrap() {
        Value::F(t) => t.clone(),
        _ => panic!("expected f32 output"),
    };
    let (_, d_out) = loss_and_grad(LossKind::CrossEntropy, &out, &labels, &[]).unwrap();
    let mut ws = Workspace::default();
    let analytic = backward(&exec, &tape, d_out, 2, &mut ws).unwrap().params;

    let eps = 5e-3f32;
    let mut rng = Rng::new(23);
    for (pi, p) in params.iter().enumerate() {
        // A handful of deterministic + random indices per tensor.
        let mut idxs = vec![0, p.data.len() / 2, p.data.len() - 1];
        for _ in 0..4 {
            idxs.push(rng.below(p.data.len() as u64) as usize);
        }
        for &j in &idxs {
            let mut plus = params.clone();
            plus[pi].data[j] += eps;
            let mut minus = params.clone();
            minus[pi].data[j] -= eps;
            let lp = ce_loss_of(&model, &plus, &plan, &scales, &luts, &x, &labels);
            let lm = ce_loss_of(&model, &minus, &plan, &scales, &luts, &x, &labels);
            let fd = (lp - lm) / (2.0 * eps);
            let an = analytic[pi].data[j];
            assert!(
                (fd - an).abs() < 1.5e-3 + 0.05 * fd.abs().max(an.abs()),
                "param {pi}[{j}]: fd {fd} vs analytic {an}"
            );
        }
    }
}

#[test]
fn quant_linear_backward_matches_manual_ste() {
    // Single quantized linear layer: the analytic backward must equal the
    // STE formulas computed from first principles (fake-quant operands,
    // clip mask) — validates the scale handling and the dW/dX/db shapes.
    let model = Model {
        name: "lin".into(),
        paper_row: "-".into(),
        kind: "mlp".into(),
        dataset: "none".into(),
        input_shape: vec![4],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 1,
        params: vec![
            ParamSpec { name: "w".into(), shape: vec![4, 3] },
            ParamSpec { name: "b".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Linear { din: 4, dout: 3, scale_idx: 0, name: "fc".into() },
                inputs: vec![0],
                params: vec![0, 1],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    };
    let mut rng = Rng::new(31);
    let w: Vec<f32> = (0..12).map(|_| rng.next_gauss() * 0.6).collect();
    let b: Vec<f32> = (0..3).map(|_| rng.next_gauss() * 0.1).collect();
    let params = vec![
        Tensor::from_vec(&[4, 3], w.clone()).unwrap(),
        Tensor::from_vec(&[3], b).unwrap(),
    ];
    let sa = 2.0 / 127.0;
    // One deliberately clipped activation (|x| > sa * 127 = 2.0).
    let x = Tensor::from_vec(
        &[2, 4],
        vec![0.3, -1.2, 2.6, 0.8, -0.4, 1.9, -2.4, 0.1],
    )
    .unwrap();
    let labels = [1i32, 0];
    let plan = retransform(&model, &Policy::all(LayerMode::lut("exact8")));
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        &model,
        params.clone(),
        plan,
        vec![sa],
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    let tape = exec.forward_taped(Value::F(x.clone())).unwrap();
    let out = match tape[1].as_ref().unwrap() {
        Value::F(t) => t.clone(),
        _ => panic!("expected f32 output"),
    };
    let (_, dy) = loss_and_grad(LossKind::CrossEntropy, &out, &labels, &[]).unwrap();
    let mut ws = Workspace::default();
    let grads = backward(&exec, &tape, dy.clone(), 1, &mut ws).unwrap();

    // Manual STE reference.
    let ws_col = quant::weight_scales_per_col(&w, 4, 3, 8);
    let wq = quant::quantize_weights_per_col(&w, 4, 3, 8, &ws_col);
    let what: Vec<f32> = (0..12)
        .map(|i| wq[i] as f32 * ws_col[i % 3])
        .collect();
    let xhat: Vec<f32> = x.data.iter().map(|&v| quant::fake_quant(v, sa, 8)).collect();
    // dW = X̂ᵀ dY
    for k in 0..4 {
        for n in 0..3 {
            let want: f32 = (0..2).map(|m| xhat[m * 4 + k] * dy.data[m * 3 + n]).sum();
            let got = grads.params[0].data[k * 3 + n];
            assert!((want - got).abs() < 1e-6 + 1e-4 * want.abs(), "dW[{k}][{n}]: {want} vs {got}");
        }
    }
    // db = column sums of dY
    for n in 0..3 {
        let want: f32 = (0..2).map(|m| dy.data[m * 3 + n]).sum();
        let got = grads.params[1].data[n];
        assert!((want - got).abs() < 1e-6, "db[{n}]: {want} vs {got}");
    }
    // dX = (dY Ŵᵀ), clipped-STE-masked where |x| saturated the quantizer.
    let lim = sa * 127.0;
    // The fixture deliberately saturates x[0][2] and x[1][2].
    assert!(x.data[2].abs() > lim && x.data[6].abs() > lim);
    let dx = grads.input.expect("input grad must flow through the linear");
    for m in 0..2 {
        for k in 0..4 {
            let raw: f32 = (0..3).map(|n| dy.data[m * 3 + n] * what[k * 3 + n]).sum();
            let want = if x.data[m * 4 + k].abs() > lim { 0.0 } else { raw };
            let got = dx.data[m * 4 + k];
            assert!(
                (want - got).abs() < 1e-6 + 1e-4 * want.abs(),
                "dX[{m}][{k}]: {want} vs {got}"
            );
        }
    }
}

#[test]
fn fit_is_deterministic_at_any_thread_count() {
    let model = grad_model();
    let params = grad_params(&model, 41);
    let plan = retransform(
        &model,
        &Policy::all(LayerMode::lut("mul8s_1l2h_like")).with_acu("fc", "exact8"),
    );
    let luts = LutRegistry::in_memory();
    let scales = vec![1.5 / 127.0, 3.0 / 127.0];
    let mut rng = Rng::new(42);
    let n = 48;
    let x_f: Vec<f32> = (0..n * 16).map(|_| rng.next_gauss()).collect();
    let labels: Vec<i32> = (0..n).map(|i| (i % 3) as i32).collect();
    let split = Split {
        x_f,
        x_i: vec![],
        labels,
        num: n,
        sample_shape: vec![4, 4, 1],
        is_tokens: false,
    };
    let run = |threads: usize| {
        let cfg = trainer::TrainConfig {
            epochs: 2,
            lr: 0.005,
            momentum: 0.9,
            batch: 8,
            seed: 0xD57,
            threads,
            max_batches: None,
            log_every: 0,
            approx_backward: None,
        };
        trainer::fit(&model, params.clone(), &plan, &scales, &luts, &split, &cfg).unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.losses, b.losses, "losses must be bit-identical across thread counts");
    for (pa, pb) in a.params.iter().zip(&b.params) {
        assert_eq!(pa.data, pb.data, "updated params must be bit-identical");
    }
    // And the run must have actually learned something on-plan.
    let (l0, l1) = a.improvement();
    assert!(l1.is_finite() && l0.is_finite());
}

#[test]
fn qat_recovers_mixed_acu_accuracy_on_tiny_dataset() {
    // The headline acceptance property: retraining a mixed-ACU plan on
    // the bundled tiny dataset measurably reduces the approximate-plan
    // accuracy gap, and the QAT loss decreases.
    let demo = synth::demo_retrain(8, 0.004, 0xA11CE, 2).unwrap();
    let (l0, l1) = demo.fit.improvement();
    assert!(l1.is_finite(), "QAT loss must stay finite");
    assert!(l1 < l0, "QAT epoch-mean loss must decrease ({l0:.4} -> {l1:.4})");
    let gap = demo.fp32_acc - demo.approx_acc;
    if gap > 0.03 {
        // Significant damage: retraining must win some of it back.
        assert!(
            demo.retrained_acc > demo.approx_acc,
            "retraining must reduce the accuracy gap: fp32 {:.3}, approx {:.3}, retrained {:.3}",
            demo.fp32_acc,
            demo.approx_acc,
            demo.retrained_acc
        );
    } else {
        // The ACUs barely hurt this seed — retraining must at least not
        // destroy the model.
        assert!(
            demo.retrained_acc >= demo.approx_acc - 0.04,
            "retraining regressed accuracy: approx {:.3} -> {:.3}",
            demo.approx_acc,
            demo.retrained_acc
        );
    }
}

#[test]
fn lstm_nodes_are_rejected_with_a_clear_error() {
    let model = Model {
        name: "lstm_toy".into(),
        paper_row: "-".into(),
        kind: "lstm".into(),
        dataset: "none".into(),
        input_shape: vec![2, 3],
        input_dtype: "f32".into(),
        out_dim: 4,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 2,
        params: vec![
            ParamSpec { name: "wx".into(), shape: vec![3, 16] },
            ParamSpec { name: "wh".into(), shape: vec![4, 16] },
            ParamSpec { name: "b".into(), shape: vec![16] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            Node {
                id: 1,
                op: Op::Lstm {
                    din: 3,
                    hidden: 4,
                    scale_idx: 0,
                    scale_idx2: 1,
                    name: "l1".into(),
                },
                inputs: vec![0],
                params: vec![0, 1, 2],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    };
    let params = grad_params(&model, 51);
    let plan = retransform(&model, &Policy::all(LayerMode::Fp32));
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        &model,
        params,
        plan,
        vec![],
        &luts,
        Style::Optimized { threads: 1 },
    )
    .unwrap();
    let x = Tensor::from_vec(&[1, 2, 3], vec![0.1; 6]).unwrap();
    let tape = exec.forward_taped(Value::F(x)).unwrap();
    let d_out = Tensor::from_vec(&[1, 4], vec![0.25; 4]).unwrap();
    let mut ws = Workspace::default();
    let err = backward(&exec, &tape, d_out, 1, &mut ws).unwrap_err();
    assert!(
        format!("{err:#}").contains("PJRT"),
        "LSTM rejection must point at the PJRT path: {err:#}"
    );
}
