//! A/B kernel-equivalence suite: the determinism contract, enforced.
//!
//! Every optimized GEMM tier — scalar, the active SIMD tier (AVX2/NEON
//! when present), and the branchless closed-form kernels — must produce
//! **bit-identical** outputs to the naive reference, for every registered
//! ACU, across irregular shapes, at any thread count. These tests run the
//! same inputs through all tiers via the `*_with(..., Isa, ...)` kernel
//! entry points and compare exactly (`assert_eq!` on integer outputs,
//! `to_bits` on f32). On hardware without AVX2/NEON the active tier *is*
//! scalar and the comparisons degrade to self-consistency — still a valid
//! run, just not an interesting one; CI's `ADAPT_NO_SIMD=1` matrix entry
//! covers the forced-scalar side on SIMD hardware.

use adapt::emulator::gemm;
use adapt::emulator::simd::{self, Isa};
use adapt::lut::Lut;
use adapt::mult;
use adapt::util::rng::Rng;

const THREADS: [usize; 2] = [1, 4];

fn rand_q(rng: &mut Rng, len: usize, half: i64) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(-half, half) as i32).collect()
}

/// Irregular (m, k, n) shapes: deliberately off the 8-lane / BLOCK_K
/// grid so vector tails, 4-row tails and partial k-blocks all execute.
fn shapes(rng: &mut Rng, rounds: usize) -> Vec<(usize, usize, usize)> {
    let mut out = vec![(1, 1, 1), (3, 64, 8), (5, 65, 9), (2, 128, 17)];
    for _ in 0..rounds {
        out.push((
            1 + rng.below(13) as usize,
            1 + rng.below(90) as usize,
            1 + rng.below(45) as usize,
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// LUT gather kernels: every 8-bit ACU, all tiers, both thread counts
// ---------------------------------------------------------------------------

#[test]
fn lut_biased_all_tiers_match_naive_for_every_8bit_acu() {
    let active = simd::isa();
    for name in mult::names_with_bits(8) {
        let lut = Lut::generate(mult::get(name).unwrap());
        let mut rng = Rng::new(0xA11CE);
        for (m, k, n) in shapes(&mut rng, 6) {
            let xq = rand_q(&mut rng, m * k, 128);
            let wq = rand_q(&mut rng, k * n, 128);
            let wb: Vec<u16> = wq.iter().map(|&v| (v + 128) as u16).collect();
            let mut want = vec![0i64; m * n];
            gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut want);
            for threads in THREADS {
                for isa in [Isa::Scalar, active] {
                    let mut got = vec![0i32; m * n];
                    gemm::lut_opt_biased_with(&xq, m, k, &wb, n, &lut, threads, isa, &mut got);
                    assert_eq!(
                        want,
                        got.iter().map(|&v| v as i64).collect::<Vec<_>>(),
                        "{name} {m}x{k}x{n} threads={threads} isa={isa:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn lut_i64_all_tiers_match_naive() {
    // The unbiased i64-accumulator gather kernel (the 12-bit executor
    // path). Two 8-bit models cover the same code cheaply; one 12-bit
    // model (a 4096² generated table) pins the wide-index case.
    let active = simd::isa();
    for name in ["mitchell8", "drum8_6", "mul12s_2km_like"] {
        let m_ = mult::get(name).unwrap();
        let half = 1i64 << (m_.bits - 1);
        let lut = Lut::generate(m_);
        let mut rng = Rng::new(0xB0B);
        for (m, k, n) in shapes(&mut rng, 3) {
            let xq = rand_q(&mut rng, m * k, half);
            let wq = rand_q(&mut rng, k * n, half);
            let mut want = vec![0i64; m * n];
            gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut want);
            for threads in THREADS {
                for isa in [Isa::Scalar, active] {
                    let mut got = vec![0i64; m * n];
                    gemm::lut_opt_with(&xq, m, k, &wq, n, &lut, threads, isa, &mut got);
                    assert_eq!(want, got, "{name} {m}x{k}x{n} threads={threads} isa={isa:?}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Closed-form kernels: every family pins to the LUT of the same model
// ---------------------------------------------------------------------------

#[test]
fn closed_form_all_tiers_match_lut_for_every_8bit_family() {
    let active = simd::isa();
    let mut covered = 0usize;
    for name in mult::names_with_bits(8) {
        let m8 = mult::get(name).unwrap();
        if !m8.form.is_closed() {
            continue; // mitchell8 and friends stay on the gather path
        }
        covered += 1;
        let lut = Lut::generate(m8);
        let mut rng = Rng::new(0xC0FFEE);
        for (m, k, n) in shapes(&mut rng, 6) {
            let xq = rand_q(&mut rng, m * k, 128);
            let wq = rand_q(&mut rng, k * n, 128);
            let mut want = vec![0i64; m * n];
            gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut want);
            for threads in THREADS {
                for isa in [Isa::Scalar, active] {
                    let mut got = vec![0i32; m * n];
                    gemm::cf_opt_i32_with(&xq, m, k, &wq, n, m8.form, threads, isa, &mut got);
                    assert_eq!(
                        want,
                        got.iter().map(|&v| v as i64).collect::<Vec<_>>(),
                        "{name} {m}x{k}x{n} threads={threads} isa={isa:?}"
                    );
                }
            }
        }
    }
    assert!(covered >= 8, "expected most 8-bit ACUs to have closed forms, got {covered}");
}

#[test]
fn closed_form_i64_matches_func_naive_at_12bit() {
    for name in mult::names_with_bits(12) {
        let m12 = mult::get(name).unwrap();
        if !m12.form.is_closed() {
            continue;
        }
        let mut rng = Rng::new(0xD00D);
        for (m, k, n) in shapes(&mut rng, 3) {
            let xq = rand_q(&mut rng, m * k, 2048);
            let wq = rand_q(&mut rng, k * n, 2048);
            let mut want = vec![0i64; m * n];
            gemm::func_naive(&xq, m, k, &wq, n, m12.fun, &mut want);
            for threads in THREADS {
                let mut got = vec![0i64; m * n];
                gemm::cf_opt_i64(&xq, m, k, &wq, n, m12.form, threads, &mut got);
                assert_eq!(want, got, "{name} {m}x{k}x{n} threads={threads}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// f32 kernels: SIMD vs scalar must agree to the bit (pinned reduction
// order, no FMA), at both thread counts
// ---------------------------------------------------------------------------

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn fp32_kernels_bit_identical_across_tiers_and_threads() {
    let active = simd::isa();
    let mut rng = Rng::new(0xF32);
    for (m, k, n) in shapes(&mut rng, 5) {
        let x: Vec<f32> = (0..m * k).map(|_| rng.next_gauss()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_gauss()).collect();
        let mut want = vec![0f32; m * n];
        gemm::fp32_opt_with(&x, m, k, &w, n, 1, Isa::Scalar, &mut want);
        for threads in THREADS {
            for isa in [Isa::Scalar, active] {
                let mut got = vec![0f32; m * n];
                gemm::fp32_opt_with(&x, m, k, &w, n, threads, isa, &mut got);
                assert_bits_eq(&want, &got, "fp32_opt");
            }
        }

        // The trainer's transpose GEMMs: a·bᵀ (striped dot) and aᵀ·b (axpy).
        let g: Vec<f32> = (0..m * n).map(|_| rng.next_gauss()).collect();
        let mut want = vec![0f32; m * k];
        gemm::fp32_a_bt_with(&g, m, n, &w, k, 1, Isa::Scalar, &mut want);
        for threads in THREADS {
            for isa in [Isa::Scalar, active] {
                let mut got = vec![0f32; m * k];
                gemm::fp32_a_bt_with(&g, m, n, &w, k, threads, isa, &mut got);
                assert_bits_eq(&want, &got, "fp32_a_bt");
            }
        }
        let mut want = vec![0f32; k * n];
        gemm::fp32_at_b_with(&x, m, k, &g, n, 1, Isa::Scalar, &mut want);
        for threads in THREADS {
            for isa in [Isa::Scalar, active] {
                let mut got = vec![0f32; k * n];
                gemm::fp32_at_b_with(&x, m, k, &g, n, threads, isa, &mut got);
                assert_bits_eq(&want, &got, "fp32_at_b");
            }
        }
    }
}
