//! Manifest/IR integrity: the graphs Python wrote must be well-formed and
//! self-consistent with the param specs, scale counts and artifact lists.

use std::collections::BTreeSet;
use std::path::PathBuf;

use adapt::graph::{retransform, LayerMode, Manifest, Op, Policy};

/// PJRT-artifact gate: these tests need the Python AOT step's output.
/// Absent artifacts => skip with a message; set ADAPT_REQUIRE_ARTIFACTS=1
/// to turn the skip into a failure (CI images that ran `make artifacts`).
fn artifacts() -> Option<PathBuf> {
    let p = adapt::artifacts_dir();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    if std::env::var("ADAPT_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "artifacts/ missing but ADAPT_REQUIRE_ARTIFACTS=1 (run `make artifacts` first)"
        );
    }
    None
}

#[test]
fn graphs_are_ssa_and_topologically_ordered() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    assert_eq!(m.models.len(), 9, "the paper's nine DNNs");
    for (name, model) in &m.models {
        let mut seen = BTreeSet::new();
        for node in &model.nodes {
            for inp in &node.inputs {
                assert!(
                    seen.contains(inp) || *inp == 0,
                    "{name}: node {} consumes undefined {inp}",
                    node.id
                );
            }
            assert!(seen.insert(node.id), "{name}: duplicate node id {}", node.id);
            for p in &node.params {
                assert!(*p < model.params.len(), "{name}: bad param index {p}");
            }
        }
    }
}

#[test]
fn scale_indices_are_dense_and_complete() {
    let Some(root) = artifacts() else {
        eprintln!("skipped");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    for (name, model) in &m.models {
        let mut seen = BTreeSet::new();
        for node in &model.nodes {
            match &node.op {
                Op::Conv2d { scale_idx, .. } | Op::Linear { scale_idx, .. } => {
                    seen.insert(*scale_idx);
                }
                Op::Lstm {
                    scale_idx,
                    scale_idx2,
                    ..
                } => {
                    seen.insert(*scale_idx);
                    seen.insert(*scale_idx2);
                }
                _ => {}
            }
        }
        assert_eq!(
            seen,
            (0..model.n_scales).collect(),
            "{name}: scale indices must be exactly 0..n_scales"
        );
    }
}

#[test]
fn param_shapes_match_layer_attrs() {
    let Some(root) = artifacts() else {
        eprintln!("skipped");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    for (name, model) in &m.models {
        for node in &model.nodes {
            match &node.op {
                Op::Conv2d {
                    kh, kw, cin, cout, groups, ..
                } => {
                    let w = &model.params[node.params[0]];
                    assert_eq!(
                        w.shape,
                        vec![*kh, *kw, cin / groups, *cout],
                        "{name}: conv weight shape"
                    );
                    assert_eq!(model.params[node.params[1]].shape, vec![*cout]);
                }
                Op::Linear { din, dout, .. } => {
                    assert_eq!(model.params[node.params[0]].shape, vec![*din, *dout]);
                }
                Op::Lstm { din, hidden, .. } => {
                    assert_eq!(model.params[node.params[0]].shape, vec![*din, 4 * hidden]);
                    assert_eq!(
                        model.params[node.params[1]].shape,
                        vec![*hidden, 4 * hidden]
                    );
                }
                _ => {}
            }
        }
    }
}

#[test]
fn artifacts_exist_on_disk_and_weights_match_specs() {
    let Some(root) = artifacts() else {
        eprintln!("skipped");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    for (name, model) in &m.models {
        for (variant, rel) in &model.artifacts {
            assert!(
                root.join(rel).exists(),
                "{name}/{variant}: missing {rel}"
            );
        }
        let wpath = root.join(&model.weights_file);
        let total: usize = model.params.iter().map(|p| p.numel()).sum();
        let len = std::fs::metadata(&wpath).unwrap().len() as usize;
        assert_eq!(len, total * 4, "{name}: weights blob size");
        assert_eq!(total as u64, model.params_count, "{name}: params_count");
    }
}

#[test]
fn table1_macs_are_plausible() {
    let Some(root) = artifacts() else {
        eprintln!("skipped");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    // CNNs must dominate the dense models by OPs (the Table-1/Table-4
    // correlation that gives the big speedup rows).
    let macs = |n: &str| m.models[n].macs;
    assert!(macs("small_vgg") > 20 * macs("vae_mnist"));
    assert!(macs("small_resnet") > 10 * macs("lstm_imdb"));
    assert!(macs("gan_fashion") < 1_000_000);
}

#[test]
fn retransform_covers_every_quantizable_node() {
    let Some(root) = artifacts() else {
        eprintln!("skipped");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    for model in m.models.values() {
        let plan = retransform(model, &Policy::all(LayerMode::lut("exact8")));
        let quantizable = model
            .nodes
            .iter()
            .filter(|n| n.op.is_quantizable())
            .count();
        assert_eq!(plan.modes.len(), quantizable);
    }
}
