//! End-to-end cross-validation: the Rust emulators (both styles) against
//! the XLA approx artifacts for representative models, plus calibration +
//! train-step integration through the PJRT runtime.
//!
//! Requires artifacts/ — tests self-skip otherwise (CI without `make
//! artifacts`). PJRT CPU client creation is process-global, so all
//! checks run inside one #[test] to avoid client churn.

use std::path::PathBuf;

use adapt::coordinator::ops::{self, InferVariant, ModelState, TrainVariant};
use adapt::data::{self, Sizes};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Policy};
use adapt::lut::LutRegistry;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::{weights, Runtime};

/// PJRT-artifact gate: these tests need the Python AOT step's output.
/// Absent artifacts => skip with a message; set ADAPT_REQUIRE_ARTIFACTS=1
/// to turn the skip into a failure (CI images that ran `make artifacts`).
fn artifacts() -> Option<PathBuf> {
    let p = adapt::artifacts_dir();
    if p.join("manifest.json").exists() {
        return Some(p);
    }
    if std::env::var("ADAPT_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "artifacts/ missing but ADAPT_REQUIRE_ARTIFACTS=1 (run `make artifacts` first)"
        );
    }
    None
}

#[test]
fn emulators_match_xla_and_training_converges() {
    let Some(root) = artifacts() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let mut rt = Runtime::open(&root).unwrap();
    let sizes = Sizes::small();
    let bs = rt.manifest.batch;

    // --- emulator vs XLA on three structurally distinct models ----------
    for name in ["vae_mnist", "squeezenet_mini", "lstm_imdb"] {
        let model = rt.manifest.model(name).unwrap().clone();
        let ds = data::load(&model.dataset, &sizes);
        let mut st =
            ModelState::load(&rt, name, &weights::initial_path(&root, &model)).unwrap();
        ops::calibrate(&mut rt, &mut st, &ds, 1, CalibratorKind::Percentile, 0.999)
            .unwrap();
        let lut_lit = ops::load_lut_lit(&rt, "mul8s_1l2h_like").unwrap();
        let x = ops::batch_input(&model, &ds.eval, 0, bs).unwrap();
        let xla = ops::infer_batch(&mut rt, &st, InferVariant::ApproxLut, &x, Some(&lut_lit))
            .unwrap();

        let plan = retransform(&model, &Policy::all(LayerMode::lut("mul8s_1l2h_like")));
        let luts = LutRegistry::from_manifest(&rt.manifest);
        let params = st.params_tensors().unwrap();
        let scales = st.act_scales.clone().unwrap();
        let input = if model.input_dtype == "i32" {
            Value::I(ds.eval.batch_tensor_i(0, bs))
        } else {
            Value::F(ds.eval.batch_tensor(0, bs))
        };
        for style in [Style::Naive, Style::Optimized { threads: 2 }] {
            let exec = Executor::new(
                &model,
                params.clone(),
                plan.clone(),
                scales.clone(),
                &luts,
                style,
            )
            .unwrap();
            let out = exec.forward(input.clone()).unwrap();
            assert_eq!(out.data.len(), xla.len(), "{name} output size");
            // behavioral agreement: per-sample argmax
            let rows = model.out_dim;
            let mut agree = 0;
            for s in 0..bs {
                let a = &out.data[s * rows..(s + 1) * rows];
                let b = &xla[s * rows..(s + 1) * rows];
                let am = (0..rows).max_by(|&i, &j| a[i].total_cmp(&a[j])).unwrap();
                let bm = (0..rows).max_by(|&i, &j| b[i].total_cmp(&b[j])).unwrap();
                agree += (am == bm) as usize;
            }
            assert!(
                agree * 100 >= bs * 95,
                "{name} {style:?}: argmax agreement {agree}/{bs}"
            );
        }
    }

    // --- training integration: a few fp32 + QAT steps reduce the loss ---
    let model = rt.manifest.model("vae_mnist").unwrap().clone();
    let ds = data::load(&model.dataset, &sizes);
    let mut st =
        ModelState::load(&rt, "vae_mnist", &weights::initial_path(&root, &model)).unwrap();
    let tr = ops::train(&mut rt, &mut st, TrainVariant::Fp32, &ds, 30, 0.9, None, 0).unwrap();
    assert!(
        tr.last_loss < tr.first_loss,
        "fp32 training must descend: {} -> {}",
        tr.first_loss,
        tr.last_loss
    );
    ops::calibrate(&mut rt, &mut st, &ds, 1, CalibratorKind::Percentile, 0.999).unwrap();
    let lut_lit = ops::load_lut_lit(&rt, "mul8s_1l2h_like").unwrap();
    let tr2 = ops::train(
        &mut rt,
        &mut st,
        TrainVariant::QatLut,
        &ds,
        10,
        0.1,
        Some(&lut_lit),
        0,
    )
    .unwrap();
    assert!(tr2.last_loss.is_finite());
    assert!(
        tr2.last_loss <= tr2.first_loss * 1.05,
        "QAT must not diverge: {} -> {}",
        tr2.first_loss,
        tr2.last_loss
    );

    // --- 12-bit functional variants execute and track the 8-bit path ----
    let q12 = ops::evaluate(&mut rt, &st, InferVariant::Quant12, &ds, None, Some(1)).unwrap();
    let a12 = ops::evaluate(&mut rt, &st, InferVariant::Approx12, &ds, None, Some(1)).unwrap();
    assert!((q12.accuracy - a12.accuracy).abs() < 0.05, "12-bit trunc is near-exact");
}
