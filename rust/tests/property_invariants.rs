//! Property-based invariants (proptest substitute: seeded random sweeps
//! over shapes/values with shrink-free assertions).
//!
//! Coverage: GEMM engine equivalence across styles, quantizer contracts,
//! im2col == direct convolution, LUT == functional ACU equality, emulator
//! fp32 == hand conv, channel-shuffle involution.

use adapt::emulator::gemm;
use adapt::lut::Lut;
use adapt::mult;
use adapt::quant;
use adapt::tensor::{im2col_i32, Tensor, TensorI32};
use adapt::util::rng::Rng;

fn rand_q(rng: &mut Rng, len: usize, half: i64) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(-half, half) as i32).collect()
}

#[test]
fn gemm_styles_agree_over_random_shapes() {
    let lut = Lut::generate(mult::get("drum8_4").unwrap());
    let mut rng = Rng::new(100);
    for case in 0..25 {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(80) as usize;
        let n = 1 + rng.below(48) as usize;
        let threads = 1 + rng.below(4) as usize;
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
        gemm::lut_opt(&xq, m, k, &wq, n, &lut, threads, &mut b);
        assert_eq!(a, b, "case {case}: {m}x{k}x{n} t{threads}");
    }
}

#[test]
fn lut_and_functional_paths_agree_for_same_acu() {
    // trunc_out8_4 exists as both a LUT and a functional form.
    let lut = Lut::generate(mult::get("trunc_out8_4").unwrap());
    let f = |a: i64, b: i64| mult::trunc_out(a, b, 4);
    let mut rng = Rng::new(200);
    for _ in 0..20 {
        let m = 1 + rng.below(20) as usize;
        let k = 1 + rng.below(50) as usize;
        let n = 1 + rng.below(30) as usize;
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
        gemm::func_naive(&xq, m, k, &wq, n, f, &mut b);
        assert_eq!(a, b);
    }
}

#[test]
fn quantize_is_monotone_and_odd() {
    let mut rng = Rng::new(300);
    for _ in 0..200 {
        let scale = 0.001 + rng.next_f32();
        let a = rng.next_gauss() * 3.0;
        let b = rng.next_gauss() * 3.0;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let qa = quant::quantize_one(lo, scale, 127);
        let qb = quant::quantize_one(hi, scale, 127);
        assert!(qa <= qb, "monotone: {lo} {hi} -> {qa} {qb}");
        // odd symmetry up to the round-half-up tie direction
        let q = quant::quantize_one(a, scale, 127);
        let qn = quant::quantize_one(-a, scale, 127);
        assert!(
            (q + qn).abs() <= 1,
            "near-odd: q({a})={q}, q({}) = {qn}",
            -a
        );
    }
}

#[test]
fn im2col_gemm_equals_direct_convolution() {
    // Direct NHWC convolution (integer, exact products) vs im2col + GEMM.
    let mut rng = Rng::new(400);
    for _ in 0..10 {
        let (n, h, w, c) = (
            1 + rng.below(2) as usize,
            3 + rng.below(6) as usize,
            3 + rng.below(6) as usize,
            1 + rng.below(3) as usize,
        );
        let (kh, kw) = (1 + 2 * rng.below(2) as usize, 1 + 2 * rng.below(2) as usize);
        let stride = 1 + rng.below(2) as usize;
        let pad = rng.below(2) as usize;
        let cout = 1 + rng.below(4) as usize;
        if h + 2 * pad < kh || w + 2 * pad < kw {
            continue;
        }
        let x = TensorI32::from_vec(
            &[n, h, w, c],
            rand_q(&mut rng, n * h * w * c, 8),
        )
        .unwrap();
        let wt = rand_q(&mut rng, kh * kw * c * cout, 8); // (kh,kw,c,cout)

        // direct conv
        let ho = (h + 2 * pad - kh) / stride + 1;
        let wo = (w + 2 * pad - kw) / stride + 1;
        let mut direct = vec![0i64; n * ho * wo * cout];
        for ni in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    for co in 0..cout {
                        let mut acc = 0i64;
                        for dy in 0..kh {
                            for dx in 0..kw {
                                let iy = (oy * stride + dy) as isize - pad as isize;
                                let ix = (ox * stride + dx) as isize - pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize
                                {
                                    continue;
                                }
                                for ci in 0..c {
                                    let xv = x.data
                                        [((ni * h + iy as usize) * w + ix as usize) * c + ci];
                                    let wv = wt[((dy * kw + dx) * c + ci) * cout + co];
                                    acc += xv as i64 * wv as i64;
                                }
                            }
                        }
                        direct[((ni * ho + oy) * wo + ox) * cout + co] = acc;
                    }
                }
            }
        }

        // im2col + exact-LUT GEMM
        let patches = im2col_i32(&x, kh, kw, stride, pad);
        let m = patches.shape[0];
        let kf = patches.shape[1];
        let lut = Lut::generate(mult::get("exact8").unwrap());
        let mut out = vec![0i64; m * cout];
        gemm::lut_opt(&patches.data, m, kf, &wt, cout, &lut, 2, &mut out);
        assert_eq!(out, direct, "conv {n}x{h}x{w}x{c} k{kh}x{kw} s{stride} p{pad}");
    }
}

#[test]
fn weight_quantization_never_exceeds_qmax() {
    let mut rng = Rng::new(500);
    for _ in 0..20 {
        let k = 1 + rng.below(64) as usize;
        let n = 1 + rng.below(64) as usize;
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_gauss() * 10.0).collect();
        let scales = quant::weight_scales_per_col(&w, k, n, 8);
        let q = quant::quantize_weights_per_col(&w, k, n, 8, &scales);
        assert!(q.iter().all(|&v| (-127..=127).contains(&v)));
        // the per-column max weight must quantize to ±127 exactly
        for ni in 0..n {
            let col_max = (0..k)
                .map(|ki| w[ki * n + ni].abs())
                .fold(0f32, f32::max);
            if col_max > 1e-9 {
                let hit = (0..k).any(|ki| q[ki * n + ni].abs() == 127);
                assert!(hit, "column {ni} max {col_max} never hits qmax");
            }
        }
    }
}

#[test]
fn tensor_concat_slice_roundtrip_random() {
    let mut rng = Rng::new(600);
    for _ in 0..20 {
        let rows = 1 + rng.below(6) as usize;
        let c1 = 1 + rng.below(5) as usize;
        let c2 = 1 + rng.below(5) as usize;
        let a = Tensor::from_vec(
            &[rows, c1],
            (0..rows * c1).map(|_| rng.next_gauss()).collect(),
        )
        .unwrap();
        let b = Tensor::from_vec(
            &[rows, c2],
            (0..rows * c2).map(|_| rng.next_gauss()).collect(),
        )
        .unwrap();
        let cat = Tensor::concat_last(&[&a, &b]).unwrap();
        assert_eq!(cat.slice_last(0, c1).data, a.data);
        assert_eq!(cat.slice_last(c1, c1 + c2).data, b.data);
    }
}
