//! Heterogeneous per-layer ACU plans, artifact-free: a synthetic in-memory
//! CNN proves
//!
//! 1. a heterogeneous plan where every layer is assigned the *same* ACU is
//!    bit-identical to the seed's single-global-LUT execution semantics
//!    (reproduced here as a hand-rolled reference),
//! 2. three distinct ACUs can serve different layers in one `Executor`
//!    pass, with the naive and optimized engines agreeing bit-for-bit,
//! 3. the scratch arena is behavior-neutral: reuse on/off and repeated
//!    forwards produce identical outputs.

use std::collections::BTreeMap;
use std::sync::Arc;

use adapt::coordinator::experiments::{self, EvalBatch, SweepCtx};
use adapt::emulator::{gemm, Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::{Lut, LutRegistry};
use adapt::mult;
use adapt::quant;
use adapt::tensor::{im2col_i32, Tensor, TensorI32};
use adapt::util::rng::Rng;
use adapt::util::threadpool::ThreadPool;

/// conv(3x3, 1->4, pad 1) -> relu -> conv(3x3, 4->4, pad 1) -> relu ->
/// flatten -> linear(64 -> 3), on 4x4x1 inputs.
fn synth_model() -> Model {
    let conv = |id, cin, cout, scale_idx, name: &str, input, p0| Node {
        id,
        op: Op::Conv2d {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride: 1,
            pad: 1,
            groups: 1,
            scale_idx,
            name: name.into(),
        },
        inputs: vec![input],
        params: vec![p0, p0 + 1],
    };
    Model {
        name: "synth_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![4, 4, 1],
        input_dtype: "f32".into(),
        out_dim: 3,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 3,
        params: vec![
            ParamSpec { name: "w1".into(), shape: vec![3, 3, 1, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
            ParamSpec { name: "w2".into(), shape: vec![3, 3, 4, 4] },
            ParamSpec { name: "b2".into(), shape: vec![4] },
            ParamSpec { name: "w3".into(), shape: vec![64, 3] },
            ParamSpec { name: "b3".into(), shape: vec![3] },
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            conv(1, 1, 4, 0, "c1", 0, 0),
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            conv(3, 4, 4, 1, "c2", 2, 2),
            Node { id: 4, op: Op::Relu, inputs: vec![3], params: vec![] },
            Node { id: 5, op: Op::Flatten, inputs: vec![4], params: vec![] },
            Node {
                id: 6,
                op: Op::Linear { din: 64, dout: 3, scale_idx: 2, name: "fc".into() },
                inputs: vec![5],
                params: vec![4, 5],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn synth_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.5).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect()
}

fn synth_input(seed: u64, n: usize) -> Tensor {
    let mut rng = Rng::new(seed);
    let data = (0..n * 16).map(|_| rng.next_gauss()).collect();
    Tensor::from_vec(&[n, 4, 4, 1], data).unwrap()
}

fn scales() -> Vec<f32> {
    vec![1.5 / 127.0, 4.0 / 127.0, 4.0 / 127.0]
}

// --- hand-rolled single-LUT reference (the seed executor's semantics) ----

fn ref_conv(x: &Tensor, w: &Tensor, b: &Tensor, cout: usize, sa: f32, lut: &Lut) -> Tensor {
    let (n, h, wd) = (x.shape[0], x.shape[1], x.shape[2]);
    let mut xq = TensorI32::zeros(&x.shape);
    quant::quantize_slice(&x.data, sa, 8, &mut xq.data);
    let patches = im2col_i32(&xq, 3, 3, 1, 1);
    let (m, kf) = (patches.shape[0], patches.shape[1]);
    let ws = quant::weight_scales_per_col(&w.data, kf, cout, 8);
    let wq = quant::quantize_weights_per_col(&w.data, kf, cout, 8, &ws);
    let mut acc = vec![0i64; m * cout];
    gemm::lut_naive(&patches.data, m, kf, &wq, cout, lut, &mut acc);
    let mut out = Tensor::zeros(&[n, h, wd, cout]);
    for mi in 0..m {
        for co in 0..cout {
            out.data[mi * cout + co] = acc[mi * cout + co] as f32 * (sa * ws[co]) + b.data[co];
        }
    }
    out
}

fn ref_linear(x: &Tensor, w: &Tensor, b: &Tensor, dout: usize, sa: f32, lut: &Lut) -> Tensor {
    let (m, din) = (x.shape[0], x.shape[1]);
    let mut xq = vec![0i32; x.data.len()];
    quant::quantize_slice(&x.data, sa, 8, &mut xq);
    let ws = quant::weight_scales_per_col(&w.data, din, dout, 8);
    let wq = quant::quantize_weights_per_col(&w.data, din, dout, 8, &ws);
    let mut acc = vec![0i64; m * dout];
    gemm::lut_naive(&xq, m, din, &wq, dout, lut, &mut acc);
    let mut out = Tensor::zeros(&[m, dout]);
    for mi in 0..m {
        for co in 0..dout {
            out.data[mi * dout + co] = acc[mi * dout + co] as f32 * (sa * ws[co]) + b.data[co];
        }
    }
    out
}

/// Full reference forward with one LUT per quantizable layer.
fn ref_forward(params: &[Tensor], x: &Tensor, luts: [&Lut; 3], s: &[f32]) -> Tensor {
    let n = x.shape[0];
    let relu = |t: Tensor| t.map(|v| v.max(0.0));
    let h1 = relu(ref_conv(x, &params[0], &params[1], 4, s[0], luts[0]));
    let h2 = relu(ref_conv(&h1, &params[2], &params[3], 4, s[1], luts[1]));
    let flat = h2.reshape(&[n, 64]).unwrap();
    ref_linear(&flat, &params[4], &params[5], 3, s[2], luts[2])
}

fn run_plan(model: &Model, params: &[Tensor], plan: &adapt::graph::ExecutionPlan, style: Style, x: &Tensor) -> Tensor {
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        model,
        params.to_vec(),
        plan.clone(),
        scales(),
        &luts,
        style,
    )
    .unwrap();
    exec.forward(Value::F(x.clone())).unwrap()
}

#[test]
fn homogeneous_plan_is_bit_identical_to_single_lut_path() {
    // PROPERTY: assigning every layer the same ACU in a heterogeneous plan
    // reproduces the seed's single-global-LUT executor bit-for-bit.
    let model = synth_model();
    for (seed, acu) in [(7u64, "drum8_4"), (8, "mul8s_1l2h_like"), (9, "mitchell8")] {
        let params = synth_params(&model, seed);
        let x = synth_input(seed + 100, 2);
        let lut = Lut::generate(mult::get(acu).unwrap());
        let want = ref_forward(&params, &x, [&lut, &lut, &lut], &scales());
        let plan = retransform(&model, &Policy::all(LayerMode::lut(acu)));
        for style in [Style::Naive, Style::Optimized { threads: 2 }] {
            let got = run_plan(&model, &params, &plan, style, &x);
            assert_eq!(got.shape, want.shape);
            assert_eq!(got.data, want.data, "{acu} {style:?} diverged from reference");
        }
    }
}

#[test]
fn three_distinct_acus_execute_in_one_pass() {
    let model = synth_model();
    let params = synth_params(&model, 42);
    let x = synth_input(43, 2);

    let plan = retransform(
        &model,
        &Policy::all(LayerMode::lut("mitchell8"))
            .with_acu("c2", "drum8_4")
            .with_acu("fc", "trunc_out8_4"),
    );
    assert_eq!(plan.acus().len(), 3, "three distinct ACUs in the plan");

    let l1 = Lut::generate(mult::get("mitchell8").unwrap());
    let l2 = Lut::generate(mult::get("drum8_4").unwrap());
    let l3 = Lut::generate(mult::get("trunc_out8_4").unwrap());
    let want = ref_forward(&params, &x, [&l1, &l2, &l3], &scales());

    let naive = run_plan(&model, &params, &plan, Style::Naive, &x);
    let opt = run_plan(&model, &params, &plan, Style::Optimized { threads: 3 }, &x);
    assert_eq!(naive.data, want.data, "naive vs per-layer reference");
    assert_eq!(opt.data, want.data, "optimized vs per-layer reference");

    // Sanity: the heterogeneous plan is actually different from exact8.
    let exact = retransform(&model, &Policy::all(LayerMode::lut("exact8")));
    let exact_out = run_plan(&model, &params, &exact, Style::Naive, &x);
    assert_ne!(exact_out.data, want.data, "approximation must be visible");
}

#[test]
fn mixed_fp32_func_lut_modes_agree_across_styles() {
    let model = synth_model();
    let params = synth_params(&model, 77);
    let x = synth_input(78, 2);
    let plan = retransform(
        &model,
        &Policy::all(LayerMode::lut("exact8"))
            .with_override("c1", LayerMode::Fp32)
            .with_override("c2", LayerMode::ApproxFunc { bits: 8, trunc_k: 4 }),
    );
    let naive = run_plan(&model, &params, &plan, Style::Naive, &x);
    let opt = run_plan(&model, &params, &plan, Style::Optimized { threads: 2 }, &x);
    assert_eq!(naive.shape, opt.shape);
    for (a, b) in naive.data.iter().zip(&opt.data) {
        assert!((a - b).abs() < 1e-5, "styles diverged: {a} vs {b}");
    }
}

#[test]
fn parallel_sweep_matches_sequential_bit_for_bit() {
    // PROPERTY: the (layer, ACU) sensitivity sweep returns the same
    // accuracies in the same order — and the greedy mixed-ACU search
    // built on them emits byte-identical plan JSON — whether the pairs
    // run sequentially or on a persistent worker pool of any size.
    let model = synth_model();
    let params = synth_params(&model, 21);
    let bs = 4;
    let mut rng = Rng::new(99);
    let batches: Vec<EvalBatch> = (0..3)
        .map(|bi| {
            let x: Vec<f32> = (0..bs * 16).map(|_| rng.next_gauss()).collect();
            EvalBatch {
                input: Value::F(Tensor::from_vec(&[bs, 4, 4, 1], x).unwrap()),
                labels: (0..bs).map(|i| ((i + bi) % 3) as i32).collect(),
                target: vec![],
            }
        })
        .collect();
    let ctx = Arc::new(SweepCtx {
        model,
        params,
        scales: scales(),
        luts: LutRegistry::in_memory(),
        batches,
        bs,
        gemm_threads: 1,
        comp: None,
    });
    let layers = ctx.layers();
    assert_eq!(layers.len(), 3, "c1, c2, fc");
    let acus = vec![
        "mul8s_1l2h_like".to_string(),
        "drum8_4".to_string(),
        "trunc_out8_4".to_string(),
    ];
    let reference = retransform(&ctx.model, &Policy::all(LayerMode::lut("exact8")));
    let base_acc = ctx.eval_plan(reference.clone()).unwrap();
    let budget = 0.5; // generous: the greedy search must actually assign

    let worst_drop =
        |accs: &[f64]| experiments::worst_drops(base_acc, accs, layers.len(), acus.len());

    let seq = experiments::sweep_pairs(&ctx, &reference, &layers, &acus, None).unwrap();
    assert_eq!(seq.len(), layers.len() * acus.len());
    let (seq_plan, seq_acc, _) = experiments::greedy_mixed(
        &ctx,
        &reference,
        "exact8",
        base_acc,
        &layers,
        &worst_drop(&seq),
        &acus,
        budget,
    )
    .unwrap();
    let seq_json = seq_plan.to_json(&ctx.model);
    assert_ne!(
        seq_json,
        reference.to_json(&ctx.model),
        "greedy search must have assigned cheaper ACUs"
    );

    for workers in [2usize, 3] {
        let pool = ThreadPool::new(workers);
        // Two rounds on the same pool: persistent workers reuse their warm
        // scratch arenas, which must stay behavior-neutral.
        for round in 0..2 {
            let par =
                experiments::sweep_pairs(&ctx, &reference, &layers, &acus, Some(&pool)).unwrap();
            assert_eq!(
                par, seq,
                "{workers}-worker sweep round {round} diverged from sequential"
            );
            let (par_plan, par_acc, _) = experiments::greedy_mixed(
                &ctx,
                &reference,
                "exact8",
                base_acc,
                &layers,
                &worst_drop(&par),
                &acus,
                budget,
            )
            .unwrap();
            assert_eq!(
                par_plan.to_json(&ctx.model),
                seq_json,
                "plan JSON must be byte-identical at {workers} workers"
            );
            assert_eq!(par_acc, seq_acc);
        }
    }
}

#[test]
fn greedy_plan_is_byte_identical_across_gemm_threads_and_reruns() {
    // PROPERTY: greedy_mixed emits byte-identical plan JSON (and the same
    // eval count) regardless of the GEMM thread count (`ADAPT_THREADS`)
    // and across repeated runs with the same inputs — the determinism
    // regression the MCTS planner's contract is built on.
    let run = |gemm_threads: usize| {
        let model = synth_model();
        let params = synth_params(&model, 21);
        let bs = 4;
        let mut rng = Rng::new(99);
        let batches: Vec<EvalBatch> = (0..3)
            .map(|bi| {
                let x: Vec<f32> = (0..bs * 16).map(|_| rng.next_gauss()).collect();
                EvalBatch {
                    input: Value::F(Tensor::from_vec(&[bs, 4, 4, 1], x).unwrap()),
                    labels: (0..bs).map(|i| ((i + bi) % 3) as i32).collect(),
                    target: vec![],
                }
            })
            .collect();
        let ctx = Arc::new(SweepCtx {
            model,
            params,
            scales: scales(),
            luts: LutRegistry::in_memory(),
            batches,
            bs,
            gemm_threads,
            comp: None,
        });
        let layers = ctx.layers();
        let acus = vec![
            "mul8s_1l2h_like".to_string(),
            "drum8_4".to_string(),
            "trunc_out8_4".to_string(),
        ];
        let reference = retransform(&ctx.model, &Policy::all(LayerMode::lut("exact8")));
        let base_acc = ctx.eval_plan(reference.clone()).unwrap();
        let accs = experiments::sweep_pairs(&ctx, &reference, &layers, &acus, None).unwrap();
        let worst = experiments::worst_drops(base_acc, &accs, layers.len(), acus.len());
        let (plan, acc, evals) = experiments::greedy_mixed(
            &ctx, &reference, "exact8", base_acc, &layers, &worst, &acus, 0.5,
        )
        .unwrap();
        (plan.to_json(&ctx.model), acc, evals)
    };

    let (json1, acc1, evals1) = run(1);
    for gemm_threads in [1usize, 4] {
        for round in 0..2 {
            let (json, acc, evals) = run(gemm_threads);
            assert_eq!(
                json, json1,
                "greedy plan JSON diverged at {gemm_threads} GEMM threads, round {round}"
            );
            assert_eq!(acc, acc1);
            assert_eq!(evals, evals1, "eval count is part of the determinism contract");
        }
    }
    assert!(evals1 > 0, "greedy must consume evaluations");
}

#[test]
fn scratch_arena_is_behavior_neutral() {
    let model = synth_model();
    let params = synth_params(&model, 5);
    let plan = retransform(
        &model,
        &Policy::all(LayerMode::lut("mul8s_1l2h_like")).with_acu("c1", "exact8"),
    );
    let luts = LutRegistry::in_memory();
    let mut per_call = Executor::new(
        &model,
        params.clone(),
        plan.clone(),
        scales(),
        &luts,
        Style::Optimized { threads: 2 },
    )
    .unwrap();
    per_call.set_scratch_reuse(false);
    let reuse = Executor::new(
        &model,
        params.clone(),
        plan.clone(),
        scales(),
        &luts,
        Style::Optimized { threads: 2 },
    )
    .unwrap();

    let xa = synth_input(500, 2);
    let xb = synth_input(501, 3); // different batch size exercises regrow
    let a1 = reuse.forward(Value::F(xa.clone())).unwrap();
    let b1 = reuse.forward(Value::F(xb.clone())).unwrap();
    let a2 = reuse.forward(Value::F(xa.clone())).unwrap();
    assert_eq!(a1.data, a2.data, "scratch reuse must not leak state across batches");

    let a_ref = per_call.forward(Value::F(xa)).unwrap();
    let b_ref = per_call.forward(Value::F(xb)).unwrap();
    assert_eq!(a1.data, a_ref.data, "reuse vs alloc-per-call (batch A)");
    assert_eq!(b1.data, b_ref.data, "reuse vs alloc-per-call (batch B)");
}
