//! Quantization-path microbench: per-tensor activation quantization,
//! per-channel weight quantization, dequantization — the §5.2 "10%
//! overhead" claim is the end-to-end consequence of these loops.

use adapt::quant;
use adapt::util::bench::{self, Config};
use adapt::util::rng::Rng;

fn main() {
    let cfg = Config::default().from_env();
    let mut rng = Rng::new(7);
    println!("Quantization microbench\n");

    for n in [64 * 1024, 1024 * 1024] {
        let xs: Vec<f32> = (0..n).map(|_| rng.next_gauss()).collect();
        let mut q = vec![0i32; n];
        let mut back = vec![0f32; n];
        let s = bench::run(&format!("quantize {}K f32 (per-tensor)", n / 1024), cfg, || {
            quant::quantize_slice(&xs, 0.031, 8, &mut q)
        });
        s.print();
        let thr = n as f64 / s.median_secs() / 1e9;
        let s2 = bench::run(&format!("dequantize {}K i32", n / 1024), cfg, || {
            quant::dequantize_slice(&q, 0.031, &mut back)
        });
        s2.print();
        println!("  -> quantize throughput {thr:.2} Gelem/s\n");
    }

    let (k, no) = (1152, 128);
    let w: Vec<f32> = (0..k * no).map(|_| rng.next_gauss() * 0.1).collect();
    let s = bench::run("weight scales per-channel (1152x128)", cfg, || {
        quant::weight_scales_per_col(&w, k, no, 8)
    });
    s.print();
    let scales = quant::weight_scales_per_col(&w, k, no, 8);
    let s = bench::run("weight quantize per-channel (1152x128)", cfg, || {
        quant::quantize_weights_per_col(&w, k, no, 8, &scales)
    });
    s.print();
}
