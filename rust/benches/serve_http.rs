//! HTTP serving bench: the whole submit → measure → swap plan → measure
//! → shadow → measure loop over the wire, artifact-free. Starts an
//! in-process `AdaptService` + HTTP front-end on an ephemeral port,
//! drives it with the `adapt client` load generator (keep-alive
//! connections, deterministic payloads), hot-swaps the plan between
//! phases, then turns on shadow mirroring of a candidate version and
//! measures the mirrored-traffic overhead vs plain serving. Emits
//! `artifacts/results/BENCH_serve_http.json` with per-phase throughput +
//! client latency, the server-side queue-wait / compute percentiles, the
//! live shadow disagreement report and the shadow overhead percentage.
//! A final connection-scaling phase sweeps the keep-alive connection
//! count (64 → 4096 full, 16 → 64 fast) against the readiness-loop
//! front-end and emits a `conn_scaling` curve (per-point throughput +
//! latency percentiles) — the CI connection-scaling gate validates its
//! presence.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench serve_http`

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig};
use adapt::graph::{retransform, LayerMode, Policy};
use adapt::lut::LutRegistry;
use adapt::service::client::{self, LoadConfig};
use adapt::service::http::{HttpServer, ServeOptions};
use adapt::service::AdaptService;
use adapt::trainer::synth;
use adapt::util::json::Json;

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let (requests, concurrency, workers) = if fast { (64, 2, 2) } else { (512, 4, 4) };
    println!(
        "== HTTP serving: {requests} requests x {concurrency} connections, {workers} workers =="
    );

    // Bundled tiny model on the emulator backend (no artifacts at all).
    let model = synth::tiny_cnn();
    let input_len: usize = model.input_shape.iter().product();
    let params = synth::tiny_params(&model, 0xBE5E);
    let plan = retransform(&model, &Policy::all(LayerMode::lut("mul8s_1l2h_like")));
    let ds = synth::tiny_dataset(128, 32);
    let scales = adapt::trainer::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        32,
        2,
        adapt::quant::calib::CalibratorKind::Percentile,
        0.999,
        1,
    )
    .expect("calibration");
    let spec = EmulatorSpec {
        model,
        params,
        plan,
        act_scales: scales,
        luts: LutRegistry::in_memory(),
        batch: 8,
        gemm_threads: 1,
    };
    let mut cfg = EngineConfig::emulator(spec);
    cfg.workers = workers;
    cfg.queue_depth = 128;
    cfg.max_wait = Duration::from_millis(2);
    let service = Arc::new(AdaptService::start(cfg).expect("service start"));
    // Raise the connection cap above the largest scaling point so the
    // conn_scaling sweep measures the event loops, not 503 refusals.
    let opts = ServeOptions {
        max_conns: 8192,
        ..ServeOptions::default()
    };
    let server =
        HttpServer::start_with(Arc::clone(&service), "127.0.0.1:0", opts).expect("server start");
    let addr = server.addr().to_string();
    println!("  transport: {} readiness loop", server.backend().name());

    let load = LoadConfig {
        addr: addr.clone(),
        requests,
        concurrency,
        input_len,
        top_k: Some(1),
        deadline_ms: None,
        seed: 0x10AD,
    };

    // Phase 1: the mixed-ACU starting plan.
    let phase1 = client::run_load(&load).expect("phase 1");
    println!(
        "  plan gen 0 (mul8s_1l2h_like): {}/{} ok, {:.1} req/s, client p50 {} µs",
        phase1.ok,
        requests,
        phase1.requests_per_sec(),
        phase1.percentile_us(0.50),
    );
    assert_eq!(phase1.errors, 0, "phase 1 must be clean");

    // Hot-swap to exact8 over the wire, then phase 2.
    let (status, body) = client::http_call(
        &addr,
        "POST",
        "/v1/plan",
        Some(r#"{"spec": "default=exact8"}"#),
    )
    .expect("plan swap call");
    assert_eq!(status, 200, "plan swap must succeed: {body}");
    let generation = Json::parse(&body)
        .unwrap()
        .get("generation")
        .unwrap()
        .usize()
        .unwrap();
    let phase2 = client::run_load(&LoadConfig {
        seed: 0x10AD ^ 0xFF,
        ..load.clone()
    })
    .expect("phase 2");
    println!(
        "  plan gen {generation} (exact8):          {}/{} ok, {:.1} req/s, client p50 {} µs",
        phase2.ok,
        requests,
        phase2.requests_per_sec(),
        phase2.percentile_us(0.50),
    );
    assert_eq!(phase2.errors, 0, "phase 2 must be clean");
    assert_eq!(
        phase2.by_generation.keys().copied().collect::<Vec<_>>(),
        vec![generation as u64],
        "every post-swap response must carry the new generation"
    );

    // Phase 3: shadow mode — create a candidate version (back to the
    // mixed plan, so the comparison has real disagreement) and mirror
    // every request to it while measuring throughput. The mirrored
    // traffic doubles the pool's work; the row quantifies that overhead.
    let model_name = "tiny_cnn";
    let (status, body) = client::http_call(
        &addr,
        "POST",
        &format!("/v2/models/{model_name}/plans"),
        Some(r#"{"spec": "default=mul8s_1l2h_like"}"#),
    )
    .expect("create candidate version");
    assert_eq!(status, 200, "candidate creation must succeed: {body}");
    let candidate = Json::parse(&body)
        .unwrap()
        .get("version")
        .unwrap()
        .usize()
        .unwrap();
    let (status, body) = client::http_call(
        &addr,
        "POST",
        &format!("/v2/models/{model_name}/plans/{candidate}/shadow"),
        Some("{}"),
    )
    .expect("start shadow");
    assert_eq!(status, 200, "shadow start must succeed: {body}");
    let phase3 = client::run_load(&LoadConfig {
        seed: 0x10AD ^ 0xF0F0,
        ..load.clone()
    })
    .expect("phase 3");
    assert_eq!(phase3.errors, 0, "phase 3 must be clean");
    let overhead_pct =
        (phase2.requests_per_sec() / phase3.requests_per_sec() - 1.0) * 100.0;
    println!(
        "  shadow v{candidate} (mirrored):        {}/{} ok, {:.1} req/s, client p50 {} µs \
         ({overhead_pct:+.1}% vs plain)",
        phase3.ok,
        requests,
        phase3.requests_per_sec(),
        phase3.percentile_us(0.50),
    );

    // Wait for the shadow collector to fold in every mirror, then read
    // the live disagreement report.
    let shadow_report = client::wait_shadow_report(
        &addr,
        model_name,
        candidate as u64,
        requests,
        Duration::from_secs(60),
    )
    .expect("shadow collector must catch up");
    let mirrored = shadow_report.get("mirrored").unwrap().usize().unwrap();
    println!(
        "  shadow report: {mirrored} mirrored, disagreement {:.1}%, top-1 flips {:.1}%, max |Δ| {:.3e}",
        shadow_report.get("disagreement_rate").unwrap().f64().unwrap() * 100.0,
        shadow_report.get("top1_flip_rate").unwrap().f64().unwrap() * 100.0,
        shadow_report.get("max_abs_delta").unwrap().f64().unwrap(),
    );

    // Phase 4: connection scaling. First promote the shadow candidate —
    // activation ends the shadow experiment, so the sweep below measures
    // plain serving (and the mirrored count read above stays final).
    let (status, body) = client::http_call(
        &addr,
        "POST",
        &format!("/v2/models/{model_name}/plans/{candidate}/activate"),
        Some("{}"),
    )
    .expect("activate candidate");
    assert_eq!(status, 200, "candidate activation must succeed: {body}");

    // Sweep keep-alive connection counts against the readiness loop.
    // Every point keeps the per-connection request count fixed at 2, so
    // the load grows with the fleet and each connection really speaks.
    let scaling_points: &[usize] = if fast {
        &[16, 64]
    } else {
        &[64, 256, 1024, 4096]
    };
    let mut scaling_total = 0usize;
    let mut conn_scaling = Vec::new();
    for (i, &conns) in scaling_points.iter().enumerate() {
        let point_requests = conns * 2;
        let report = client::run_load(&LoadConfig {
            requests: point_requests,
            concurrency: conns,
            seed: 0x5CA1E ^ ((i as u64 + 1) << 8),
            ..load.clone()
        })
        .expect("conn scaling point");
        assert_eq!(report.errors, 0, "conn scaling at {conns} connections must be clean");
        assert_eq!(
            report.ok,
            point_requests,
            "conn scaling at {conns} connections must answer every request"
        );
        scaling_total += point_requests;
        println!(
            "  conn scaling {conns:>5} conns: {}/{} ok, {:.1} req/s, client p50/p95/p99 = {}/{}/{} µs",
            report.ok,
            point_requests,
            report.requests_per_sec(),
            report.percentile_us(0.50),
            report.percentile_us(0.95),
            report.percentile_us(0.99),
        );
        let mut point = BTreeMap::new();
        point.insert("conns".to_string(), Json::Num(conns as f64));
        point.insert("requests".to_string(), Json::Num(point_requests as f64));
        point.insert("ok".to_string(), Json::Num(report.ok as f64));
        point.insert("errors".to_string(), Json::Num(report.errors as f64));
        point.insert("req_per_s".to_string(), Json::Num(report.requests_per_sec()));
        point.insert("p50_us".to_string(), Json::Num(report.percentile_us(0.50) as f64));
        point.insert("p95_us".to_string(), Json::Num(report.percentile_us(0.95) as f64));
        point.insert("p99_us".to_string(), Json::Num(report.percentile_us(0.99) as f64));
        conn_scaling.push(Json::Obj(point));
    }

    // Server-side view: totals + tail latency.
    let stats = service.stats();
    let (qp50, qp95, qp99) = stats.pool.queue_wait_percentiles_us();
    let (cp50, cp95, cp99) = stats.pool.compute_percentiles_us();
    println!(
        "  server: {} requests, {} batches, queue wait p50/p95/p99 = {qp50}/{qp95}/{qp99} µs, \
         compute p50/p95/p99 = {cp50}/{cp95}/{cp99} µs",
        stats.pool.total.requests, stats.pool.total.batches,
    );

    let mut doc = BTreeMap::new();
    doc.insert("requests".to_string(), Json::Num(requests as f64));
    doc.insert("concurrency".to_string(), Json::Num(concurrency as f64));
    doc.insert("workers".to_string(), Json::Num(workers as f64));
    doc.insert("phase1_mixed".to_string(), phase1.to_json());
    doc.insert("phase2_exact8".to_string(), phase2.to_json());
    doc.insert("phase3_shadow".to_string(), phase3.to_json());
    doc.insert("shadow_candidate".to_string(), Json::Num(candidate as f64));
    doc.insert("shadow_overhead_pct".to_string(), Json::Num(overhead_pct));
    doc.insert("shadow_report".to_string(), shadow_report);
    doc.insert("conn_scaling".to_string(), Json::Arr(conn_scaling));
    doc.insert("generation_after_swap".to_string(), Json::Num(generation as f64));
    doc.insert("server_stats".to_string(), stats.to_json());
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_serve_http.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }

    server.stop();
    let final_stats = Arc::try_unwrap(service)
        .map(|s| s.shutdown().expect("shutdown"))
        .unwrap_or_else(|arc| arc.engine().stats_snapshot());
    assert_eq!(
        final_stats.total.requests,
        3 * requests + mirrored + scaling_total,
        "3 measured phases + every completed mirror + the scaling sweep, exactly once each"
    );
    println!("== serve_http bench OK ==");
}
