//! ACU ablation bench: accuracy vs MRE vs power proxy across the whole
//! multiplier library on a trained CNN (ALWANN-style design-space sweep),
//! plus characterization cost of the library itself.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench multiplier_ablation`

use adapt::coordinator::experiments;
use adapt::data::Sizes;
use adapt::mult;
use adapt::runtime::Runtime;
use adapt::util::bench::{self, Config};

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let cfg = Config::default().from_env();

    // Characterization cost (exhaustive 8-bit, 65k pairs per ACU).
    let s = bench::run("characterize mitchell8 (exhaustive)", cfg, || {
        mult::characterize(mult::get("mitchell8").unwrap(), 0, 0)
    });
    s.print();
    let s = bench::run("characterize mul12s (200k sample)", cfg, || {
        mult::characterize(mult::get("mul12s_2km_like").unwrap(), 200_000, 0)
    });
    s.print();
    println!();

    let mut rt = match Runtime::open(&adapt::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("accuracy sweep needs artifacts/ (run `make artifacts`): {e:#}");
            return;
        }
    };
    let sizes = if fast { Sizes::small() } else { Sizes::default() };
    let model = if fast { "vae_mnist" } else { "small_vgg" };
    match experiments::ablation(&mut rt, model, &sizes, Some(if fast { 1 } else { 4 })) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("ablation failed: {e:#}"),
    }
}
