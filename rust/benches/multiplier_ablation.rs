//! ACU ablation bench: accuracy vs MRE vs power proxy across the whole
//! multiplier library on a trained CNN (ALWANN-style design-space sweep),
//! characterization cost of the library itself, plus — artifact-free —
//! heterogeneous per-layer plan throughput, the scratch-arena A/B
//! (reuse vs the seed's alloc-per-call executor), emitted as
//! `artifacts/results/BENCH_mixed_acu.json`, and the sequential-vs-pool
//! sensitivity-sweep comparison at 1/2/4 workers, emitted as
//! `artifacts/results/BENCH_parallel_sweep.json` (which also asserts the
//! parallel sweep's plan JSON is byte-identical to the sequential one).
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench multiplier_ablation`

use std::collections::BTreeMap;
use std::sync::Arc;

use adapt::coordinator::experiments;
use adapt::data::Sizes;
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::mult;
use adapt::runtime::Runtime;
use adapt::tensor::Tensor;
use adapt::util::bench::{self, Config};
use adapt::util::json::Json;
use adapt::util::rng::Rng;
use adapt::util::threadpool::ThreadPool;

/// Synthetic CNN big enough for the GEMM hot path to dominate:
/// conv(3->16) -> relu -> conv(16->32, s2) -> relu -> conv(32->32) ->
/// relu -> gap -> linear(32->10) on 16x16x3 inputs.
fn bench_model() -> Model {
    let conv = |id, cin, cout, stride, scale_idx, name: &str, input, p0| Node {
        id,
        op: Op::Conv2d {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride,
            pad: 1,
            groups: 1,
            scale_idx,
            name: name.into(),
        },
        inputs: vec![input],
        params: vec![p0, p0 + 1],
    };
    let p = |name: &str, shape: &[usize]| ParamSpec {
        name: name.into(),
        shape: shape.to_vec(),
    };
    Model {
        name: "bench_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![16, 16, 3],
        input_dtype: "f32".into(),
        out_dim: 10,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 4,
        params: vec![
            p("w1", &[3, 3, 3, 16]),
            p("b1", &[16]),
            p("w2", &[3, 3, 16, 32]),
            p("b2", &[32]),
            p("w3", &[3, 3, 32, 32]),
            p("b3", &[32]),
            p("w4", &[32, 10]),
            p("b4", &[10]),
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            conv(1, 3, 16, 1, 0, "stem", 0, 0),
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            conv(3, 16, 32, 2, 1, "mid1", 2, 2),
            Node { id: 4, op: Op::Relu, inputs: vec![3], params: vec![] },
            conv(5, 32, 32, 1, 2, "mid2", 4, 4),
            Node { id: 6, op: Op::Relu, inputs: vec![5], params: vec![] },
            Node { id: 7, op: Op::Gap, inputs: vec![6], params: vec![] },
            Node {
                id: 8,
                op: Op::Linear { din: 32, dout: 10, scale_idx: 3, name: "head".into() },
                inputs: vec![7],
                params: vec![6, 7],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn mixed_acu_section(cfg: Config, fast: bool) {
    let model = bench_model();
    let mut rng = Rng::new(0xBE9C);
    let params: Vec<Tensor> = model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.3).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect();
    let scales = vec![1.5 / 127.0, 3.0 / 127.0, 3.0 / 127.0, 3.0 / 127.0];
    let bs = if fast { 4 } else { 16 };
    let x: Vec<f32> = (0..bs * 16 * 16 * 3).map(|_| rng.next_gauss()).collect();
    let input = Tensor::from_vec(&[bs, 16, 16, 3], x).unwrap();
    let threads = adapt::util::threadpool::default_threads();
    let luts = LutRegistry::in_memory();

    // First/last layers exact, middle layers on two cheaper ACUs — the
    // canonical mixed-precision assignment (3 distinct ACUs in one pass).
    let homo = retransform(&model, &Policy::all(LayerMode::lut("exact8")));
    let hetero = retransform(
        &model,
        &Policy::all(LayerMode::lut("exact8"))
            .with_acu("mid1", "mul8s_1l2h_like")
            .with_acu("mid2", "drum8_6"),
    );
    assert_eq!(hetero.acus().len(), 3);

    let build = |plan: &adapt::graph::ExecutionPlan, reuse: bool| {
        let mut exec = Executor::new(
            &model,
            params.clone(),
            plan.clone(),
            scales.clone(),
            &luts,
            Style::Optimized { threads },
        )
        .unwrap();
        exec.set_scratch_reuse(reuse);
        exec
    };

    println!("Heterogeneous plan + scratch arena (batch {bs}, {threads} threads):");
    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    let cases: [(&str, &adapt::graph::ExecutionPlan, bool); 4] = [
        ("homogeneous exact8, scratch reuse", &homo, true),
        ("heterogeneous 3-ACU, scratch reuse", &hetero, true),
        ("homogeneous exact8, alloc-per-call", &homo, false),
        ("heterogeneous 3-ACU, alloc-per-call", &hetero, false),
    ];
    let mut medians = BTreeMap::new();
    for (label, plan, reuse) in cases {
        let exec = build(plan, reuse);
        let s = bench::run(&format!("  {label}"), cfg, || {
            exec.forward(Value::F(input.clone())).unwrap()
        });
        s.print();
        medians.insert(label.to_string(), s.median_secs());
        let mut entry = BTreeMap::new();
        entry.insert("median_s".to_string(), Json::Num(s.median_secs()));
        entry.insert(
            "samples_per_s".to_string(),
            Json::Num(bs as f64 / s.median_secs().max(1e-12)),
        );
        entry.insert("iters".to_string(), Json::Num(s.iters as f64));
        results.insert(label.to_string(), Json::Obj(entry));
    }
    let speedup = |a: &str, b: &str| medians[b] / medians[a].max(1e-12);
    let arena_speedup = speedup(
        "heterogeneous 3-ACU, scratch reuse",
        "heterogeneous 3-ACU, alloc-per-call",
    );
    println!(
        "  scratch arena vs alloc-per-call (hetero): {arena_speedup:.2}x  \
         (>= 1.0 expected: zero steady-state allocations)"
    );

    let mut doc = BTreeMap::new();
    doc.insert("batch".to_string(), Json::Num(bs as f64));
    doc.insert("threads".to_string(), Json::Num(threads as f64));
    doc.insert("acus".to_string(), Json::Arr(
        hetero.acus().into_iter().map(Json::Str).collect(),
    ));
    doc.insert("arena_speedup".to_string(), Json::Num(arena_speedup));
    doc.insert("results".to_string(), Json::Obj(results));
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_mixed_acu.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }
    println!();
}

/// Sequential vs pool-parallel sensitivity sweep on the synthetic CNN:
/// wall-clock at 1/2/4 workers plus a byte-level plan-JSON determinism
/// check, emitted as `BENCH_parallel_sweep.json`.
fn parallel_sweep_section(fast: bool) {
    let model = bench_model();
    let mut rng = Rng::new(0x51EE9);
    let params: Vec<Tensor> = model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.3).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect();
    let bs = if fast { 4 } else { 16 };
    let nb = if fast { 2 } else { 4 };
    let batches: Vec<experiments::EvalBatch> = (0..nb)
        .map(|bi| {
            let x: Vec<f32> = (0..bs * 16 * 16 * 3).map(|_| rng.next_gauss()).collect();
            experiments::EvalBatch {
                input: Value::F(Tensor::from_vec(&[bs, 16, 16, 3], x).unwrap()),
                labels: (0..bs).map(|i| ((bi + i) % 10) as i32).collect(),
                target: vec![],
            }
        })
        .collect();
    // gemm_threads 1: the sweep workers are the parallelism axis here.
    let ctx = Arc::new(experiments::SweepCtx {
        model,
        params,
        scales: vec![1.5 / 127.0, 3.0 / 127.0, 3.0 / 127.0, 3.0 / 127.0],
        luts: LutRegistry::in_memory(),
        batches,
        bs,
        gemm_threads: 1,
        comp: None,
    });
    let layers = ctx.layers();
    let acus: Vec<String> = ["mul8s_1l2h_like", "drum8_6", "trunc_out8_4", "mitchell8"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let reference = retransform(&ctx.model, &Policy::all(LayerMode::lut("exact8")));
    let base_acc = ctx.eval_plan(reference.clone()).unwrap();
    let budget = 0.05;

    let worst_drop =
        |accs: &[f64]| experiments::worst_drops(base_acc, accs, layers.len(), acus.len());

    println!(
        "Parallel sensitivity sweep ({} pairs, batch {bs} x {nb} eval batches):",
        layers.len() * acus.len()
    );
    let cfg = Config::endtoend().from_env();

    let mut seq_accs: Vec<f64> = Vec::new();
    let s_seq = bench::run("  sweep sequential", cfg, || {
        seq_accs = experiments::sweep_pairs(&ctx, &reference, &layers, &acus, None).unwrap();
    });
    s_seq.print();
    let (seq_plan, _, _) = experiments::greedy_mixed(
        &ctx,
        &reference,
        "exact8",
        base_acc,
        &layers,
        &worst_drop(&seq_accs),
        &acus,
        budget,
    )
    .unwrap();
    let seq_json = seq_plan.to_json(&ctx.model);

    let mut medians: BTreeMap<String, Json> = BTreeMap::new();
    medians.insert("sequential".to_string(), Json::Num(s_seq.median_secs()));
    let mut plan_match = true;
    let mut speedup_4w = 0.0;
    for workers in [1usize, 2, 4] {
        let pool = ThreadPool::new(workers);
        let mut par_accs: Vec<f64> = Vec::new();
        let s = bench::run(&format!("  sweep pool, {workers} workers"), cfg, || {
            par_accs =
                experiments::sweep_pairs(&ctx, &reference, &layers, &acus, Some(&pool)).unwrap();
        });
        s.print();
        assert_eq!(par_accs, seq_accs, "parallel sweep accuracies diverged from sequential");
        let (par_plan, _, _) = experiments::greedy_mixed(
            &ctx,
            &reference,
            "exact8",
            base_acc,
            &layers,
            &worst_drop(&par_accs),
            &acus,
            budget,
        )
        .unwrap();
        plan_match &= par_plan.to_json(&ctx.model) == seq_json;
        medians.insert(format!("workers_{workers}"), Json::Num(s.median_secs()));
        if workers == 4 {
            speedup_4w = s_seq.median_secs() / s.median_secs().max(1e-12);
        }
    }
    assert!(plan_match, "parallel sweep plan JSON diverged from sequential");
    println!(
        "  pool @4 workers: {speedup_4w:.2}x vs sequential (plan JSON byte-identical: {plan_match})"
    );

    let mut doc = BTreeMap::new();
    doc.insert(
        "pairs".to_string(),
        Json::Num((layers.len() * acus.len()) as f64),
    );
    doc.insert("batch".to_string(), Json::Num(bs as f64));
    doc.insert("eval_batches".to_string(), Json::Num(nb as f64));
    doc.insert("gemm_threads".to_string(), Json::Num(1.0));
    doc.insert(
        "acus".to_string(),
        Json::Arr(acus.iter().cloned().map(Json::Str).collect()),
    );
    doc.insert("median_s".to_string(), Json::Obj(medians));
    doc.insert("speedup_4_workers".to_string(), Json::Num(speedup_4w));
    doc.insert("plan_json_identical".to_string(), Json::Bool(plan_match));
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_parallel_sweep.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }
    println!();
}

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let cfg = Config::default().from_env();

    // Characterization cost (exhaustive 8-bit, 65k pairs per ACU).
    let s = bench::run("characterize mitchell8 (exhaustive)", cfg, || {
        mult::characterize(mult::get("mitchell8").unwrap(), 0, 0)
    });
    s.print();
    let s = bench::run("characterize mul12s (200k sample)", cfg, || {
        mult::characterize(mult::get("mul12s_2km_like").unwrap(), 200_000, 0)
    });
    s.print();
    println!();

    // Heterogeneous-plan + scratch-arena section (no artifacts needed).
    mixed_acu_section(cfg, fast);

    // Sequential vs pool-parallel sweep section (no artifacts needed).
    parallel_sweep_section(fast);

    let mut rt = match Runtime::open(&adapt::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("accuracy sweep needs artifacts/ (run `make artifacts`): {e:#}");
            return;
        }
    };
    let sizes = if fast { Sizes::small() } else { Sizes::default() };
    let model = if fast { "vae_mnist" } else { "small_vgg" };
    match experiments::ablation(&mut rt, model, &sizes, Some(if fast { 1 } else { 4 })) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("ablation failed: {e:#}"),
    }
}
