//! Kernel-tier microbench: scalar-tier LUT vs SIMD-gather LUT vs the
//! branchless closed-form kernels, per GEMM shape and ACU, emitted as
//! `artifacts/results/BENCH_gemm.json` with GFLOP/s and speedup columns.
//!
//! Every timed kernel is *validated first*: its output must match the
//! naive scalar LUT reference bit-for-bit on the bench inputs, so the
//! numbers can never come from a kernel that silently diverged.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench gemm_kernels`

use std::collections::BTreeMap;

use adapt::emulator::gemm;
use adapt::emulator::simd::{self, Isa};
use adapt::lut::Lut;
use adapt::mult;
use adapt::util::bench::{self, Config};
use adapt::util::json::Json;
use adapt::util::rng::Rng;

fn rand_q(rng: &mut Rng, len: usize, half: i64) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(-half, half) as i32).collect()
}

fn entry(s: &bench::Stats, macs: f64, naive: f64, scalar_lut: f64) -> Json {
    let med = s.median_secs().max(1e-12);
    let mut e = BTreeMap::new();
    e.insert("median_s".to_string(), Json::Num(s.median_secs()));
    e.insert("gflops".to_string(), Json::Num(2.0 * macs / med / 1e9));
    e.insert("speedup_vs_naive".to_string(), Json::Num(naive / med));
    e.insert("speedup_vs_scalar_lut".to_string(), Json::Num(scalar_lut / med));
    Json::Obj(e)
}

fn main() {
    let cfg = Config::default().from_env();
    let threads = adapt::util::threadpool::default_threads();
    let active = simd::isa();
    println!("GEMM kernel tiers (threads = {threads}, active ISA = {active:?})\n");

    // (m, k, n): conv-patch GEMM, fc GEMM, LSTM-gate GEMM.
    let shapes = [(4096usize, 288usize, 32usize), (256, 2048, 128), (32, 96, 256)];
    // Two closed-form families (floor-trunc, DRUM) + one opaque ACU that
    // can only take the gather path.
    let acus = ["mul8s_1l2h_like", "drum8_4", "mitchell8"];

    let mut all_shapes: BTreeMap<String, Json> = BTreeMap::new();
    let mut best_speedup = 0.0f64;
    for (m, k, n) in shapes {
        let mut rng = Rng::new(42);
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let wb: Vec<u16> = wq.iter().map(|&v| (v + 128) as u16).collect();
        let macs = (m * k * n) as f64;
        let mut by_acu: BTreeMap<String, Json> = BTreeMap::new();
        println!("GEMM {m}x{k}x{n} ({:.1} MMAC):", macs / 1e6);

        for acu in acus {
            let ml = mult::get(acu).unwrap();
            let lut = Lut::generate(ml);
            let mut want = vec![0i64; m * n];
            gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut want);
            let want = want; // frozen: the validation reference
            let check32 = |got: &[i32], label: &str| {
                assert!(
                    want.iter().zip(got).all(|(&a, &b)| a == b as i64),
                    "{acu} {m}x{k}x{n}: {label} diverged from the naive reference"
                );
            };

            println!("  {acu} ({:?}):", ml.form);
            let mut kernels: BTreeMap<String, Json> = BTreeMap::new();
            let mut acc64 = vec![0i64; m * n];
            let s = bench::run("    lut naive (baseline engine)", cfg, || {
                gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut acc64)
            });
            s.print();
            let naive = s.median_secs();
            kernels.insert("lut_naive".to_string(), entry(&s, macs, naive, naive));

            let mut acc32 = vec![0i32; m * n];
            gemm::lut_opt_biased_with(&xq, m, k, &wb, n, &lut, threads, Isa::Scalar, &mut acc32);
            check32(&acc32, "lut scalar tier");
            let s = bench::run("    lut blocked, scalar tier", cfg, || {
                gemm::lut_opt_biased_with(&xq, m, k, &wb, n, &lut, threads, Isa::Scalar, &mut acc32)
            });
            s.print();
            let scalar_lut = s.median_secs();
            kernels.insert("lut_scalar".to_string(), entry(&s, macs, naive, scalar_lut));

            if active != Isa::Scalar {
                gemm::lut_opt_biased_with(&xq, m, k, &wb, n, &lut, threads, active, &mut acc32);
                check32(&acc32, "lut SIMD tier");
                let s = bench::run("    lut blocked, SIMD gather", cfg, || {
                    gemm::lut_opt_biased_with(&xq, m, k, &wb, n, &lut, threads, active, &mut acc32)
                });
                s.print();
                best_speedup = best_speedup.max(scalar_lut / s.median_secs().max(1e-12));
                kernels.insert("lut_simd".to_string(), entry(&s, macs, naive, scalar_lut));
            }

            if ml.form.is_closed() {
                let mut tiers = vec![(Isa::Scalar, "cf_scalar", "    closed-form, scalar tier")];
                if active != Isa::Scalar {
                    tiers.push((active, "cf_simd", "    closed-form, SIMD"));
                }
                for (isa, name, label) in tiers {
                    gemm::cf_opt_i32_with(&xq, m, k, &wq, n, ml.form, threads, isa, &mut acc32);
                    check32(&acc32, name);
                    let s = bench::run(label, cfg, || {
                        gemm::cf_opt_i32_with(&xq, m, k, &wq, n, ml.form, threads, isa, &mut acc32)
                    });
                    s.print();
                    best_speedup = best_speedup.max(scalar_lut / s.median_secs().max(1e-12));
                    kernels.insert(name.to_string(), entry(&s, macs, naive, scalar_lut));
                }
            }
            by_acu.insert(acu.to_string(), Json::Obj(kernels));
        }
        println!();
        all_shapes.insert(format!("{m}x{k}x{n}"), Json::Obj(by_acu));
    }

    println!("best speedup vs blocked scalar-LUT tier: {best_speedup:.2}x");
    let mut doc = BTreeMap::new();
    doc.insert("threads".to_string(), Json::Num(threads as f64));
    doc.insert("isa".to_string(), Json::Str(format!("{active:?}")));
    doc.insert("best_speedup_vs_scalar_lut".to_string(), Json::Num(best_speedup));
    doc.insert("shapes".to_string(), Json::Obj(all_shapes));
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_gemm.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("written {}", path.display());
        }
    }
}
