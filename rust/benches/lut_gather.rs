//! LUT GEMM microbenchmarks (§4.3): naive scalar lookup vs the optimized
//! hoisted-row gather loop vs functional ACU vs fp32, across GEMM shapes.
//!
//! Reproduces the paper's §4.3 observation that vectorized gathers beat
//! scalar lookups by a modest constant (~1.4x for in-cache tables) and
//! quantifies the rest of the optimized engine's win (threads + locality).

use adapt::emulator::gemm;
use adapt::lut::Lut;
use adapt::mult;
use adapt::util::bench::{self, Config};
use adapt::util::rng::Rng;

fn rand_q(rng: &mut Rng, len: usize, half: i64) -> Vec<i32> {
    (0..len).map(|_| rng.range_i64(-half, half) as i32).collect()
}

fn main() {
    let cfg = Config::default().from_env();
    let lut = Lut::generate(mult::get("mul8s_1l2h_like").unwrap());
    let f12 = mult::get("mul12s_2km_like").unwrap().fun;
    let threads = adapt::util::threadpool::default_threads();
    println!("LUT gather GEMM microbench (threads = {threads}, LUT = {} KiB)\n",
        lut.size_bytes() / 1024);

    // (m, k, n): conv-patch GEMM, fc GEMM, LSTM-gate GEMM.
    for (m, k, n) in [(4096, 288, 32), (256, 2048, 128), (32, 96, 256)] {
        let mut rng = Rng::new(42);
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let x32: Vec<f32> = xq.iter().map(|&v| v as f32).collect();
        let w32: Vec<f32> = wq.iter().map(|&v| v as f32).collect();
        let mut acc = vec![0i64; m * n];
        let mut accf = vec![0f32; m * n];
        let macs = (m * k * n) as f64;

        println!("GEMM {m}x{k}x{n} ({:.1} MMAC):", macs / 1e6);
        let s = bench::run("  lut naive (baseline engine)", cfg, || {
            gemm::lut_naive(&xq, m, k, &wq, n, &lut, &mut acc)
        });
        s.print();
        let naive = s.median_secs();
        let s = bench::run("  lut optimized (row-hoisted, threaded)", cfg, || {
            gemm::lut_opt(&xq, m, k, &wq, n, &lut, threads, &mut acc)
        });
        s.print();
        let _opt_generic = s.median_secs();
        let wb: Vec<u16> = wq.iter().map(|&v| (v + 128) as u16).collect();
        let mut acc32 = vec![0i32; m * n];
        let s = bench::run("  lut optimized+biased u16/i32 (§Perf)", cfg, || {
            gemm::lut_opt_biased(&xq, m, k, &wb, n, &lut, threads, &mut acc32)
        });
        s.print();
        let opt = s.median_secs();
        let s = bench::run("  functional mul12s (no table)", cfg, || {
            gemm::func_opt(&xq, m, k, &wq, n, f12, threads, &mut acc)
        });
        s.print();
        let s = bench::run("  fp32 reference", cfg, || {
            gemm::fp32_opt(&x32, m, k, &w32, n, threads, &mut accf)
        });
        s.print();
        println!(
            "  -> optimized vs naive: {:.2}x   ({:.2} ns/MAC naive, {:.2} ns/MAC opt)\n",
            naive / opt,
            naive * 1e9 / macs,
            opt * 1e9 / macs
        );
    }
}
