//! Compensation bench: per-ACU accuracy recovery on the pre-trained
//! synthetic CNN. For each registry ACU of interest, evaluates the
//! all-that-ACU plan with and without calibrated compensation (exact8 is
//! the accuracy reference), reports the recovered fraction of the drop
//! plus the MAC-weighted and compensation-inclusive costs, and emits
//! `artifacts/results/BENCH_compensate.json`.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench compensate`

use std::collections::BTreeMap;
use std::time::Instant;

use adapt::compensate;
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Policy};
use adapt::lut::LutRegistry;
use adapt::search::{layer_macs, layer_outputs, plan_cost_comp, plan_cost_macs};
use adapt::trainer::{self, synth};
use adapt::util::json::Json;

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let threads = 2;
    let bs = 32;
    let eval_batches = if fast { 4 } else { 8 };
    let calib_batches = if fast { 1 } else { 2 };
    let acus = ["mitchell8", "drum8_6", "mul8s_1l2h_like", "trunc_out8_4"];

    let t0 = Instant::now();
    let ts = synth::tiny_pretrained(0xC0FF, threads).unwrap();
    let setup_wall = t0.elapsed().as_secs_f64();
    let luts = LutRegistry::in_memory();

    let modes: Vec<LayerMode> = acus.iter().map(|a| LayerMode::lut(*a)).collect();
    let bits = compensate::needed_bits(modes.iter()).unwrap();
    let t0 = Instant::now();
    let calib = compensate::collect(
        &ts.model, &ts.params, &ts.ds.train, bs, calib_batches, &ts.scales, &bits, threads,
    )
    .unwrap();
    let calib_wall = t0.elapsed().as_secs_f64();

    let eval = |p: &ExecutionPlan| {
        trainer::evaluate(
            &ts.model, ts.params.clone(), p, &ts.scales, &luts, &ts.ds.eval, bs, eval_batches,
            threads,
        )
        .unwrap()
    };
    let base_acc = eval(&retransform(&ts.model, &Policy::all(LayerMode::lut("exact8"))));
    let macs = layer_macs(&ts.model);
    let outs = layer_outputs(&ts.model);
    println!(
        "Compensation: {} ACUs on {} (base accuracy {base_acc:.4}), \
         {calib_batches} calib / {eval_batches} eval batches, calibration {calib_wall:.3}s \
         (setup {setup_wall:.3}s)",
        acus.len(),
        ts.model.name
    );

    let obj = |pairs: Vec<(&str, Json)>| {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    let mut rows = Vec::new();
    for acu in acus {
        let plan = retransform(&ts.model, &Policy::all(LayerMode::lut(acu)));
        let mut comp_plan = plan.clone();
        let t0 = Instant::now();
        let applied =
            compensate::compensate_plan(&ts.model, &ts.params, &ts.scales, &calib, &mut comp_plan)
                .unwrap();
        let fit_wall = t0.elapsed().as_secs_f64();
        assert!(applied >= 1, "{acu} is approximate; some layer must get a block");

        let uncomp = eval(&plan);
        let comp = eval(&comp_plan);
        let dropped = base_acc - uncomp;
        let recovered = if dropped <= 1e-9 { 1.0 } else { (comp - uncomp) / dropped };
        let cost = plan_cost_macs(&macs, &plan);
        let cost_comp = plan_cost_comp(&macs, &outs, &comp_plan);
        println!(
            "  {acu:>16}: uncompensated {uncomp:.4}, compensated {comp:.4} \
             (drop {dropped:.4}, recovered {recovered:.3}), {applied} layers, \
             fit {fit_wall:.3}s"
        );
        rows.push(obj(vec![
            ("acu", Json::Str(acu.to_string())),
            ("compensated_layers", Json::Num(applied as f64)),
            ("accuracy_uncompensated", Json::Num(uncomp)),
            ("accuracy_compensated", Json::Num(comp)),
            ("recovered_frac", Json::Num(recovered)),
            ("cost_macs", Json::Num(cost)),
            ("cost_with_comp_adds", Json::Num(cost_comp)),
            ("fit_wall_s", Json::Num(fit_wall)),
        ]));
    }

    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("model".to_string(), Json::Str(ts.model.name.clone()));
    doc.insert("batch".to_string(), Json::Num(bs as f64));
    doc.insert("eval_batches".to_string(), Json::Num(eval_batches as f64));
    doc.insert("calib_batches".to_string(), Json::Num(calib_batches as f64));
    doc.insert("base_accuracy".to_string(), Json::Num(base_acc));
    doc.insert("setup_wall_s".to_string(), Json::Num(setup_wall));
    doc.insert("calib_wall_s".to_string(), Json::Num(calib_wall));
    doc.insert("acus".to_string(), Json::Arr(rows));
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_compensate.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }
}
