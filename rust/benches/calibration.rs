//! Calibrator bench (§3.2.1): scale quality + cost for max / percentile /
//! MSE / entropy over synthetic activation distributions, checking the
//! paper's claim that two batches of percentile calibration land within
//! ~0.1% of the achievable quantized accuracy (here: within a small
//! relative error of the oracle 99.9-percentile clip).

use adapt::quant::calib::{Calibrator, CalibratorKind, HistogramCalibrator, MaxCalibrator};
use adapt::util::bench::{self, Config};
use adapt::util::rng::Rng;

fn main() {
    let cfg = Config::default().from_env();
    println!("Calibration bench: 2 batches x 128K activations (gaussian + 0.1% outliers)\n");

    let mut rng = Rng::new(3);
    let mut batches: Vec<Vec<f32>> = Vec::new();
    for _ in 0..2 {
        let mut xs: Vec<f32> = (0..128 * 1024).map(|_| rng.next_gauss()).collect();
        for _ in 0..128 {
            xs.push(rng.next_gauss() * 40.0); // heavy tail
        }
        batches.push(xs);
    }
    // Oracle: exact 99.9th percentile of |x| over the stream.
    let mut all: Vec<f32> = batches.iter().flatten().map(|v| v.abs()).collect();
    all.sort_by(f32::total_cmp);
    let oracle = all[(all.len() as f64 * 0.999) as usize];
    println!("oracle 99.9-pct |x| = {oracle:.3}\n");

    for kind in [
        CalibratorKind::Max,
        CalibratorKind::Percentile,
        CalibratorKind::Mse,
        CalibratorKind::Entropy,
    ] {
        let s = bench::run(&format!("{kind:?} calibrate (observe + scale)"), cfg, || {
            let mut c = HistogramCalibrator::new(kind);
            for b in &batches {
                c.observe(b);
            }
            c.scale(8)
        });
        s.print();
        let mut c = HistogramCalibrator::new(kind);
        for b in &batches {
            c.observe(b);
        }
        let clip = c.scale(8) * 127.0;
        println!(
            "  -> calib_max {clip:.3} ({:+.1}% vs oracle percentile)\n",
            100.0 * (clip - oracle) / oracle
        );
    }

    let s = bench::run("MaxCalibrator (streaming abs-max)", cfg, || {
        let mut c = MaxCalibrator::default();
        for b in &batches {
            c.observe(b);
        }
        c.scale(8)
    });
    s.print();
}
