//! Table 2 cost regeneration: per-step wall-clock of every training and
//! inference variant per Table-2 model — the components behind the
//! "re-train time" column. (The accuracy columns come from
//! `adapt table2` / the end_to_end example, which train to convergence.)
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench table2_retrain`

use adapt::coordinator::ops::{self, InferVariant, TrainVariant};
use adapt::data::{self, Sizes};
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;
use adapt::util::bench::{self, Config};

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let mut rt = match Runtime::open(&adapt::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("needs artifacts/ (run `make artifacts`): {e:#}");
            return;
        }
    };
    let cfg = Config::endtoend().from_env();
    let models: Vec<String> = if fast {
        vec!["vae_mnist".into()]
    } else {
        rt.manifest
            .models
            .iter()
            .filter(|(_, m)| m.table2)
            .map(|(n, _)| n.clone())
            .collect()
    };
    let sizes = Sizes::small();
    println!("Table 2 step costs (batch {})\n", rt.manifest.batch);

    for name in &models {
        let ds = data::load(&rt.manifest.model(name).unwrap().dataset.clone(), &sizes);
        let mut st = ops::ModelState::load_best(&rt, name).unwrap();
        ops::calibrate(&mut rt, &mut st, &ds, 1, CalibratorKind::Percentile, 0.999).unwrap();
        let lut = ops::load_lut_lit(&rt, "mul8s_1l2h_like").unwrap();

        println!("{name}:");
        let x = ops::batch_input(&st.model, &ds.eval, 0, rt.manifest.batch).unwrap();
        for (label, variant) in [
            ("fp32_infer", InferVariant::Fp32),
            ("approx_infer (LUT)", InferVariant::ApproxLut),
            ("quant12_infer", InferVariant::Quant12),
            ("approx12_infer", InferVariant::Approx12),
        ] {
            let lut_ref = (variant == InferVariant::ApproxLut).then_some(&lut);
            rt.prepare(name, variant.artifact()).unwrap();
            let s = bench::run(&format!("  {label}"), cfg, || {
                ops::infer_batch(&mut rt, &st, variant, &x, lut_ref).unwrap()
            });
            s.print();
        }
        for (label, variant) in [
            ("fp32_train step", TrainVariant::Fp32),
            ("qat_train step (LUT STE)", TrainVariant::QatLut),
            ("qat12_train step (functional)", TrainVariant::Qat12),
        ] {
            let lut_ref = matches!(variant, TrainVariant::QatLut).then_some(&lut);
            let s = bench::run(&format!("  {label}"), cfg, || {
                let mut st2 = ops::ModelState::load_best(&rt, name).unwrap();
                st2.act_scales = st.act_scales.clone();
                ops::train(&mut rt, &mut st2, variant, &ds, 1, 1e-4, lut_ref, 0).unwrap()
            });
            s.print();
        }
        println!();
    }
}
