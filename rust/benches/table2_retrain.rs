//! Table 2 cost regeneration: per-step wall-clock of every training and
//! inference variant per Table-2 model — the components behind the
//! "re-train time" column. (The accuracy columns come from
//! `adapt table2` / the end_to_end example, which train to convergence.)
//!
//! Two sections:
//!
//! * **Emulator trainer (artifact-free)** — step costs of the Rust QAT
//!   path on the bundled tiny model (inference forward vs taped forward
//!   vs STE backward vs a full fit step), emitted as
//!   `artifacts/results/BENCH_retrain.json`. Runs anywhere (CI
//!   bench-smoke included) — no PJRT, no artifacts directory needed.
//! * **PJRT variants (artifact-gated)** — the original per-variant rows,
//!   plus an emulator-trainer A/B epoch row (`ops::train_emulator`) so
//!   the two QAT paths can be compared on the same model.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench table2_retrain`

use std::collections::BTreeMap;

use adapt::coordinator::ops::{self, InferVariant, TrainVariant};
use adapt::data::{self, Sizes};
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, LayerMode, Op, Policy};
use adapt::lut::LutRegistry;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;
use adapt::trainer::{self, synth};
use adapt::util::bench::{self, Config};
use adapt::util::json::Json;

/// Artifact-free emulator-trainer step costs on the tiny model; emits
/// `BENCH_retrain.json` for the CI bench-smoke job.
fn emulator_section(cfg: Config) {
    let model = synth::tiny_cnn();
    let params = synth::tiny_params(&model, 0x7EA1);
    let ds = synth::tiny_dataset(256, 128);
    let luts = LutRegistry::in_memory();
    let threads = adapt::util::threadpool::default_threads();
    let bs = 32;
    let scales = trainer::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        bs,
        2,
        CalibratorKind::Percentile,
        0.999,
        threads,
    )
    .unwrap();
    let plan = synth::tiny_mixed_plan(&model);
    let x = ds.train.batch_tensor(0, bs);
    let labels = ds.train.batch_labels(0, bs);
    let exec = Executor::new(
        &model,
        params.clone(),
        plan.clone(),
        scales.clone(),
        &luts,
        Style::Optimized { threads },
    )
    .unwrap();

    println!("Emulator QAT step costs (tiny_cnn, batch {bs}, {threads} threads, mixed-ACU plan):");
    let s_fwd = bench::run("  emu fwd (inference)", cfg, || {
        exec.forward(Value::F(x.clone())).unwrap()
    });
    s_fwd.print();
    let s_taped = bench::run("  emu fwd (taped)", cfg, || {
        exec.forward_taped(Value::F(x.clone())).unwrap()
    });
    s_taped.print();

    let tape = exec.forward_taped(Value::F(x.clone())).unwrap();
    let last = model.nodes.last().unwrap().id;
    let out = match tape[last].as_ref().unwrap() {
        Value::F(t) => t.clone(),
        _ => unreachable!("tiny_cnn output is f32"),
    };
    let mut ws = trainer::Workspace::default();
    let s_bwd = bench::run("  emu bwd (clipped STE)", cfg, || {
        let (_, d_out) =
            trainer::loss_and_grad(trainer::LossKind::CrossEntropy, &out, &labels, &[]).unwrap();
        trainer::backward(&exec, &tape, d_out, threads, &mut ws).unwrap()
    });
    s_bwd.print();

    let step_cfg = trainer::TrainConfig {
        epochs: 1,
        lr: 1e-3,
        momentum: 0.9,
        batch: bs,
        seed: 1,
        threads,
        max_batches: Some(1),
        log_every: 0,
        approx_backward: None,
    };
    let s_step = bench::run("  emu train step (fit 1x1)", cfg, || {
        trainer::fit(
            &model,
            params.clone(),
            &plan,
            &scales,
            &luts,
            &ds.train,
            &step_cfg,
        )
        .unwrap()
    });
    s_step.print();
    println!();

    let mut doc = BTreeMap::new();
    doc.insert("model".to_string(), Json::Str("tiny_cnn".into()));
    doc.insert("batch".to_string(), Json::Num(bs as f64));
    doc.insert("threads".to_string(), Json::Num(threads as f64));
    doc.insert(
        "acus".to_string(),
        Json::Arr(plan.acus().into_iter().map(Json::Str).collect()),
    );
    let mut rows = BTreeMap::new();
    for (key, s) in [
        ("fwd_infer_s", &s_fwd),
        ("fwd_taped_s", &s_taped),
        ("bwd_ste_s", &s_bwd),
        ("train_step_s", &s_step),
    ] {
        rows.insert(key.to_string(), Json::Num(s.median_secs()));
    }
    doc.insert("median_s".to_string(), Json::Obj(rows));
    doc.insert(
        "bwd_over_fwd".to_string(),
        Json::Num(s_bwd.median_secs() / s_fwd.median_secs().max(1e-12)),
    );
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_retrain.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }
    println!();
}

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let cfg = Config::endtoend().from_env();

    // Artifact-free section first: runs everywhere, including CI.
    emulator_section(cfg);

    let mut rt = match Runtime::open(&adapt::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT section needs artifacts/ (run `make artifacts`): {e:#}");
            return;
        }
    };
    let models: Vec<String> = if fast {
        vec!["vae_mnist".into()]
    } else {
        rt.manifest
            .models
            .iter()
            .filter(|(_, m)| m.table2)
            .map(|(n, _)| n.clone())
            .collect()
    };
    let sizes = Sizes::small();
    let threads = adapt::util::threadpool::default_threads();
    println!("Table 2 step costs (batch {})\n", rt.manifest.batch);

    for name in &models {
        let ds = data::load(&rt.manifest.model(name).unwrap().dataset.clone(), &sizes);
        let mut st = ops::ModelState::load_best(&rt, name).unwrap();
        ops::calibrate(&mut rt, &mut st, &ds, 1, CalibratorKind::Percentile, 0.999).unwrap();
        let lut = ops::load_lut_lit(&rt, "mul8s_1l2h_like").unwrap();

        println!("{name}:");
        let x = ops::batch_input(&st.model, &ds.eval, 0, rt.manifest.batch).unwrap();
        for (label, variant) in [
            ("fp32_infer", InferVariant::Fp32),
            ("approx_infer (LUT)", InferVariant::ApproxLut),
            ("quant12_infer", InferVariant::Quant12),
            ("approx12_infer", InferVariant::Approx12),
        ] {
            let lut_ref = (variant == InferVariant::ApproxLut).then_some(&lut);
            rt.prepare(name, variant.artifact()).unwrap();
            let s = bench::run(&format!("  {label}"), cfg, || {
                ops::infer_batch(&mut rt, &st, variant, &x, lut_ref).unwrap()
            });
            s.print();
        }
        for (label, variant) in [
            ("fp32_train step", TrainVariant::Fp32),
            ("qat_train step (LUT STE)", TrainVariant::QatLut),
            ("qat12_train step (functional)", TrainVariant::Qat12),
        ] {
            let lut_ref = matches!(variant, TrainVariant::QatLut).then_some(&lut);
            let s = bench::run(&format!("  {label}"), cfg, || {
                let mut st2 = ops::ModelState::load_best(&rt, name).unwrap();
                st2.act_scales = st.act_scales.clone();
                ops::train(&mut rt, &mut st2, variant, &ds, 1, 1e-4, lut_ref, 0).unwrap()
            });
            s.print();
        }
        // Emulator-trainer A/B: the same QAT semantics on the Rust
        // engines (ops::train_emulator), one epoch over the small split.
        // LSTM/text models stay PJRT-only.
        let trainable = st
            .model
            .nodes
            .iter()
            .all(|n| !matches!(n.op, Op::Lstm { .. } | Op::Embedding { .. }));
        if trainable {
            let plan = retransform(
                &st.model,
                &Policy::all(LayerMode::lut("mul8s_1l2h_like")),
            );
            let luts = LutRegistry::from_manifest(&rt.manifest);
            let batch = rt.manifest.batch;
            let s = bench::run("  emu qat epoch (trainer::fit)", cfg, || {
                let mut st2 = ops::ModelState::load_best(&rt, name).unwrap();
                st2.act_scales = st.act_scales.clone();
                ops::train_emulator(&mut st2, &plan, &luts, &ds, 1, 1e-4, batch, 1, threads)
                    .unwrap()
            });
            s.print();
        }
        println!();
    }
}
