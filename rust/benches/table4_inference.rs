//! Table 4 regeneration: emulation wall-clock per DNN across the four
//! engines (native XLA fp32, baseline scalar LUT, AdaPT XLA approx path,
//! optimized Rust engine) and the speedup column.
//!
//! Full run: `cargo bench --bench table4_inference`
//! Smoke:    `ADAPT_BENCH_FAST=1 cargo bench --bench table4_inference`

use adapt::coordinator::experiments::{self, Table4Config};
use adapt::data::Sizes;
use adapt::runtime::Runtime;

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let mut rt = match Runtime::open(&adapt::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("table4 bench needs artifacts/ (run `make artifacts`): {e:#}");
            return;
        }
    };
    let cfg = Table4Config {
        models: if fast {
            vec!["vae_mnist".into(), "gan_fashion".into()]
        } else {
            vec![]
        },
        sizes: if fast { Sizes::small() } else { Sizes::default() },
        eval_batches: if fast { 1 } else { 2 },
        verbose: true,
        ..Table4Config::default()
    };
    println!("Table 4 — inference emulation wall-clock ({} batches of {})\n",
        cfg.eval_batches, rt.manifest.batch);
    match experiments::table4(&mut rt, &cfg) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("table4 failed: {e:#}"),
    }
    println!("(executable compile time, excluded from rows: {:.1?})", rt.compile_time);
}
