//! Plan-search bench: greedy first-fit vs MCTS at an equal evaluation
//! budget on the synthetic bench CNN, artifact-free. Measures plan
//! quality (accuracy + MAC-weighted power savings) and wall time for
//! both searchers, re-runs MCTS on a 4-worker pool to assert the
//! determinism contract (byte-identical plan JSON), and emits
//! `artifacts/results/BENCH_plan_search.json`.
//!
//! Smoke: `ADAPT_BENCH_FAST=1 cargo bench --bench plan_search`

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use adapt::coordinator::experiments::{self, EvalBatch, SweepCtx};
use adapt::emulator::Value;
use adapt::graph::{retransform, LayerMode, Model, Node, Op, ParamSpec, Policy};
use adapt::lut::LutRegistry;
use adapt::search::mcts::{self, MctsConfig, SearchSpace};
use adapt::search::{layer_macs, plan_cost_macs};
use adapt::tensor::Tensor;
use adapt::util::json::Json;
use adapt::util::rng::Rng;
use adapt::util::threadpool::ThreadPool;

/// Same 4-quantizable-layer CNN as `multiplier_ablation.rs`:
/// conv(3->16) -> relu -> conv(16->32, s2) -> relu -> conv(32->32) ->
/// relu -> gap -> linear(32->10) on 16x16x3 inputs.
fn bench_model() -> Model {
    let conv = |id, cin, cout, stride, scale_idx, name: &str, input, p0| Node {
        id,
        op: Op::Conv2d {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride,
            pad: 1,
            groups: 1,
            scale_idx,
            name: name.into(),
        },
        inputs: vec![input],
        params: vec![p0, p0 + 1],
    };
    let p = |name: &str, shape: &[usize]| ParamSpec {
        name: name.into(),
        shape: shape.to_vec(),
    };
    Model {
        name: "bench_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "none".into(),
        input_shape: vec![16, 16, 3],
        input_dtype: "f32".into(),
        out_dim: 10,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 4,
        params: vec![
            p("w1", &[3, 3, 3, 16]),
            p("b1", &[16]),
            p("w2", &[3, 3, 16, 32]),
            p("b2", &[32]),
            p("w3", &[3, 3, 32, 32]),
            p("b3", &[32]),
            p("w4", &[32, 10]),
            p("b4", &[10]),
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node { id: 0, op: Op::Input, inputs: vec![], params: vec![] },
            conv(1, 3, 16, 1, 0, "stem", 0, 0),
            Node { id: 2, op: Op::Relu, inputs: vec![1], params: vec![] },
            conv(3, 16, 32, 2, 1, "mid1", 2, 2),
            Node { id: 4, op: Op::Relu, inputs: vec![3], params: vec![] },
            conv(5, 32, 32, 1, 2, "mid2", 4, 4),
            Node { id: 6, op: Op::Relu, inputs: vec![5], params: vec![] },
            Node { id: 7, op: Op::Gap, inputs: vec![6], params: vec![] },
            Node {
                id: 8,
                op: Op::Linear { din: 32, dout: 10, scale_idx: 3, name: "head".into() },
                inputs: vec![7],
                params: vec![6, 7],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn main() {
    let fast = std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1");
    let model = bench_model();
    let mut rng = Rng::new(0x9C75);
    let params: Vec<Tensor> = model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.3).collect();
            Tensor::from_vec(&spec.shape, data).unwrap()
        })
        .collect();
    let bs = if fast { 4 } else { 16 };
    let nb = if fast { 2 } else { 4 };
    let batches: Vec<EvalBatch> = (0..nb)
        .map(|bi| {
            let x: Vec<f32> = (0..bs * 16 * 16 * 3).map(|_| rng.next_gauss()).collect();
            EvalBatch {
                input: Value::F(Tensor::from_vec(&[bs, 16, 16, 3], x).unwrap()),
                labels: (0..bs).map(|i| ((bi + i) % 10) as i32).collect(),
                target: vec![],
            }
        })
        .collect();
    let ctx = Arc::new(SweepCtx {
        model,
        params,
        scales: vec![1.5 / 127.0, 3.0 / 127.0, 3.0 / 127.0, 3.0 / 127.0],
        luts: LutRegistry::in_memory(),
        batches,
        bs,
        gemm_threads: 1,
        comp: None,
    });
    let layers = ctx.layers();
    let acus: Vec<String> = ["mul8s_1l2h_like", "drum8_6", "trunc_out8_4", "mitchell8"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let reference = retransform(&ctx.model, &Policy::all(LayerMode::lut("exact8")));
    let base_acc = ctx.eval_plan(reference.clone()).unwrap();
    let budget = 0.05;
    let macs = layer_macs(&ctx.model);
    let ref_cost = plan_cost_macs(&macs, &reference);
    let savings_of = |plan: &adapt::graph::ExecutionPlan| {
        ((ref_cost - plan_cost_macs(&macs, plan)) / ref_cost.max(1e-12)).clamp(0.0, 1.0)
    };

    println!(
        "Plan search: {} layers x {} ACUs, batch {bs} x {nb} eval batches, \
         accuracy budget {budget}",
        layers.len(),
        acus.len()
    );

    // Greedy pipeline: sweep prior + first-fit descent (both timed — the
    // sweep is part of greedy's cost, and MCTS reuses the same prior).
    let t0 = Instant::now();
    let pair = experiments::sweep_pairs(&ctx, &reference, &layers, &acus, None).unwrap();
    let sweep_wall = t0.elapsed().as_secs_f64();
    let worst = experiments::worst_drops(base_acc, &pair, layers.len(), acus.len());
    let t0 = Instant::now();
    let (gplan, gacc, gevals) = experiments::greedy_mixed(
        &ctx, &reference, "exact8", base_acc, &layers, &worst, &acus, budget,
    )
    .unwrap();
    let greedy_wall = t0.elapsed().as_secs_f64();
    let gsavings = savings_of(&gplan);
    println!(
        "  greedy: accuracy {gacc:.4} (base {base_acc:.4}), savings {gsavings:.4}, \
         {gevals} evals, {greedy_wall:.3}s (+{sweep_wall:.3}s sweep)"
    );

    // MCTS at the same total budget (sweep pairs + greedy's descent;
    // greedy's plan is the incumbent and is charged 1 evaluation).
    let eval_budget = (pair.len() + gevals).max(16);
    let space = || {
        SearchSpace::build(
            &ctx.model,
            reference.clone(),
            "exact8",
            base_acc,
            budget,
            &layers,
            &pair,
            &acus,
        )
        .unwrap()
    };
    let greward = space().reward(gacc, &gplan);
    let cfg = MctsConfig { seed: 0x5EED, evals: eval_budget, ..MctsConfig::default() };
    let t0 = Instant::now();
    let out = mcts::search(&ctx, space(), &cfg, Some((&gplan, gacc)), None, None).unwrap();
    let mcts_wall = t0.elapsed().as_secs_f64();
    println!(
        "  mcts:   accuracy {:.4}, savings {:.4}, {} evals / {} playouts \
         ({} cache hits), {mcts_wall:.3}s",
        out.accuracy, out.savings, out.evals, out.playouts, out.cache_hits
    );
    let mcts_not_worse = out.reward >= greward;
    assert!(
        mcts_not_worse,
        "MCTS reward {} fell below greedy's {greward} at equal budget",
        out.reward
    );
    assert!(out.evals <= eval_budget, "budget overrun: {} > {eval_budget}", out.evals);

    // Determinism: the same search on a 4-worker pool must emit
    // byte-identical plan JSON and identical statistics.
    let seq_json = out.plan.to_json(&ctx.model);
    let pool = ThreadPool::new(4);
    let t0 = Instant::now();
    let par = mcts::search(&ctx, space(), &cfg, Some((&gplan, gacc)), Some(&pool), None).unwrap();
    let pool_wall = t0.elapsed().as_secs_f64();
    let plan_json_identical = par.plan.to_json(&ctx.model) == seq_json
        && par.accuracy == out.accuracy
        && par.evals == out.evals
        && par.playouts == out.playouts;
    assert!(plan_json_identical, "4-worker MCTS diverged from sequential");
    println!(
        "  mcts @4 workers: {pool_wall:.3}s ({:.2}x vs sequential), plan byte-identical: \
         {plan_json_identical}",
        mcts_wall / pool_wall.max(1e-12)
    );

    let obj = |pairs: Vec<(&str, Json)>| {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("layers".to_string(), Json::Num(layers.len() as f64));
    doc.insert(
        "acus".to_string(),
        Json::Arr(acus.iter().cloned().map(Json::Str).collect()),
    );
    doc.insert("batch".to_string(), Json::Num(bs as f64));
    doc.insert("eval_batches".to_string(), Json::Num(nb as f64));
    doc.insert("base_accuracy".to_string(), Json::Num(base_acc));
    doc.insert("accuracy_budget".to_string(), Json::Num(budget));
    doc.insert("eval_budget".to_string(), Json::Num(eval_budget as f64));
    doc.insert("sweep_wall_s".to_string(), Json::Num(sweep_wall));
    doc.insert(
        "greedy".to_string(),
        obj(vec![
            ("accuracy", Json::Num(gacc)),
            ("savings", Json::Num(gsavings)),
            ("evals", Json::Num(gevals as f64)),
            ("wall_s", Json::Num(greedy_wall)),
        ]),
    );
    doc.insert(
        "mcts".to_string(),
        obj(vec![
            ("accuracy", Json::Num(out.accuracy)),
            ("savings", Json::Num(out.savings)),
            ("evals", Json::Num(out.evals as f64)),
            ("playouts", Json::Num(out.playouts as f64)),
            ("cache_hits", Json::Num(out.cache_hits as f64)),
            ("feasible", Json::Bool(out.feasible)),
            ("wall_s", Json::Num(mcts_wall)),
            ("wall_s_4_workers", Json::Num(pool_wall)),
        ]),
    );
    doc.insert("mcts_not_worse".to_string(), Json::Bool(mcts_not_worse));
    doc.insert(
        "plan_json_identical".to_string(),
        Json::Bool(plan_json_identical),
    );
    let dir = adapt::artifacts_dir().join("results");
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join("BENCH_plan_search.json");
        if std::fs::write(&path, Json::Obj(doc).to_string()).is_ok() {
            println!("  written {}", path.display());
        }
    }
}
