//! Vendored API-compatible subset of the `anyhow` crate.
//!
//! The build environment is offline, so the handful of `anyhow` features
//! this workspace uses are reimplemented here: [`Error`] (a boxed context
//! chain), [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait for `Result`/`Option`.
//!
//! Semantics mirror upstream where it matters to callers:
//! * `{}` displays the outermost context only,
//! * `{:#}` displays the whole chain joined by `": "`,
//! * `Debug` renders the message plus a `Caused by:` list (what
//!   `.unwrap()` prints in tests),
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain.

use std::fmt;

/// Error: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (used by [`anyhow!`]).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading x").context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: reading x: gone");
    }

    #[test]
    fn macros_and_context_trait() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "failed with code 7");

        let r: Result<u32> = None.context("missing key");
        assert_eq!(format!("{}", r.unwrap_err()), "missing key");

        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let r = r.with_context(|| format!("step {}", 2));
        assert_eq!(format!("{:#}", r.unwrap_err()), "step 2: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }
}
