//! Stub of the `xla-rs` API surface the `adapt` crate uses.
//!
//! The container this workspace builds in has no XLA/PJRT shared
//! libraries, so the runtime layer is stubbed: [`Literal`] is a real
//! in-memory host buffer (marshalling code works unchanged), while
//! [`PjRtClient::cpu`] fails with a clear message. Everything downstream
//! already degrades gracefully — the artifact-gated tests skip, the CLI
//! and benches print the same "run `make artifacts`" guidance they print
//! when the artifacts directory is absent.
//!
//! To re-enable the PJRT fast path, replace this path dependency with the
//! real `xla-rs` crate; the type and method names match.

use std::borrow::Borrow;
use std::fmt;

/// Error type (implements `std::error::Error` so `?` converts to anyhow).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (the workspace is built against the \
         vendored `xla` stub; swap rust/vendor/xla for the real xla-rs crate \
         and install the XLA runtime to enable AOT execution)"
    ))
}

/// Host literal: dims + typed data. Mirrors the subset of xla-rs
/// `Literal` the coordinator marshals through.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    I32 { dims: Vec<i64>, data: Vec<i32> },
    Tuple(Vec<Literal>),
}

/// Element types `Literal::vec1` / `Literal::to_vec` accept.
pub trait NativeType: Copy {
    fn vec1(data: &[Self]) -> Literal;
    fn to_vec(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::F32 {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    fn to_vec(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::I32 {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    fn to_vec(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1(data)
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal::F32 {
            dims: vec![],
            data: vec![v],
        }
    }

    fn numel(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(Literal::numel).sum(),
        }
    }

    /// Reshape (same element count).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.numel() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.numel()
            )));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 {
                dims: dims.to_vec(),
                data,
            },
            Literal::I32 { data, .. } => Literal::I32 {
                dims: dims.to_vec(),
                data,
            },
            Literal::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::to_vec(self)
    }

    /// Decompose a tuple literal (non-tuples decompose to themselves).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Ok(vec![other]),
        }
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("parsing HLO text"))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT CPU client (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compiling executable"))
    }
}

/// Compiled executable handle (stub: unreachable without a client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("fetching result literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        let l = Literal::vec1(&[1i32, 2]).reshape(&[3]);
        assert!(l.is_err());
    }

    #[test]
    fn client_is_stubbed() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT backend unavailable"));
    }
}
