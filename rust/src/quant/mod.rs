//! Quantization + calibration (§3.2).
//!
//! Symmetric affine quantizer (zero_point = 0) mirroring
//! `python/compile/quantize.py` bit-for-bit: `q = clip(floor(x/s + .5))`,
//! per-tensor activation scales, per-output-channel weight scales.
//!
//! Calibrators learn the activation `calib_max` offline from the fp32
//! activation taps (the `acts` AOT executable): the paper's default is a
//! 99.9-percentile histogram calibrator ("we saw it performed the best
//! overall"), with max / MSE / entropy "transparently" selectable — all
//! four are implemented here and swept by `cargo bench --bench calibration`.

pub mod calib;

pub use calib::{Calibrator, CalibratorKind, HistogramCalibrator, MaxCalibrator};

/// Largest representable magnitude at a bitwidth (127 at 8-bit).
pub fn qmax_for(bits: u32) -> i32 {
    (1i32 << (bits - 1)) - 1
}

/// Quantize one value: round-half-up, clip to the symmetric range.
///
/// NOTE: true division, not multiply-by-reciprocal — the XLA artifacts
/// compute `floor(x / s + 0.5)` and a 1-ulp reciprocal difference flips
/// boundary values, breaking the bit-exact emulator/XLA cross-check.
#[inline(always)]
pub fn quantize_one(x: f32, scale: f32, qmax: i32) -> i32 {
    let q = (x / scale + 0.5).floor();
    (q as i32).clamp(-qmax, qmax)
}

/// Quantize a slice with one scale (per-tensor activations).
pub fn quantize_slice(xs: &[f32], scale: f32, bits: u32, out: &mut [i32]) {
    let qmax = qmax_for(bits);
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = quantize_one(x, scale, qmax);
    }
}

/// Dequantize: q * scale.
pub fn dequantize_slice(qs: &[i32], scale: f32, out: &mut [f32]) {
    for (o, &q) in out.iter_mut().zip(qs) {
        *o = q as f32 * scale;
    }
}

/// Per-output-channel weight scales for a (K, N) row-major weight matrix:
/// `scale[n] = max_k |w[k,n]| / qmax` (mirror of `weight_scale_per_col`).
pub fn weight_scales_per_col(w: &[f32], k: usize, n: usize, bits: u32) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let qmax = qmax_for(bits) as f32;
    let mut amax = vec![0.0f32; n];
    for row in w.chunks_exact(n) {
        for (m, &v) in amax.iter_mut().zip(row) {
            *m = m.max(v.abs());
        }
    }
    amax.iter().map(|&m| m.max(1e-12) / qmax).collect()
}

/// Quantize a (K, N) weight matrix with per-column scales.
pub fn quantize_weights_per_col(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
    scales: &[f32],
) -> Vec<i32> {
    let qmax = qmax_for(bits);
    let mut out = vec![0i32; k * n];
    for ki in 0..k {
        for ni in 0..n {
            out[ki * n + ni] = quantize_one(w[ki * n + ni], scales[ni], qmax);
        }
    }
    out
}

/// Fake-quantize (quant-dequant) — used by tests to mirror the QAT forward.
pub fn fake_quant(x: f32, scale: f32, bits: u32) -> f32 {
    quantize_one(x, scale, qmax_for(bits)) as f32 * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax_for(8), 127);
        assert_eq!(qmax_for(12), 2047);
    }

    #[test]
    fn round_half_up_matches_python_floor_form() {
        // floor(x/s + 0.5): 0.5 rounds to 1, -0.5 rounds to 0, 1.5 -> 2.
        assert_eq!(quantize_one(0.5, 1.0, 127), 1);
        assert_eq!(quantize_one(-0.5, 1.0, 127), 0);
        assert_eq!(quantize_one(1.5, 1.0, 127), 2);
        assert_eq!(quantize_one(-1.5, 1.0, 127), -1);
    }

    #[test]
    fn clipping_is_symmetric() {
        assert_eq!(quantize_one(1e9, 1.0, 127), 127);
        assert_eq!(quantize_one(-1e9, 1.0, 127), -127);
    }

    #[test]
    fn quant_dequant_error_bounded_by_half_scale() {
        let scale = 0.031;
        for i in -100..100 {
            let x = i as f32 * 0.017;
            if x.abs() < scale * 126.0 {
                let r = fake_quant(x, scale, 8);
                assert!((r - x).abs() <= scale * 0.5 + 1e-6, "{x} -> {r}");
            }
        }
    }

    #[test]
    fn weight_scales_per_column() {
        // 2x3 matrix; column abs-maxes are 4, 5, 6.
        let w = [1.0f32, -5.0, 2.0, -4.0, 3.0, 6.0];
        let s = weight_scales_per_col(&w, 2, 3, 8);
        assert!((s[0] - 4.0 / 127.0).abs() < 1e-7);
        assert!((s[1] - 5.0 / 127.0).abs() < 1e-7);
        assert!((s[2] - 6.0 / 127.0).abs() < 1e-7);
        let q = quantize_weights_per_col(&w, 2, 3, 8, &s);
        assert_eq!(q[1], -127); // -5 is the max of its column
        assert_eq!(q[5], 127);
    }
}
