//! Activation-range calibrators (§3.2.1).
//!
//! The paper uses TensorRT's calibrator classes; this module is that
//! substrate, built from scratch: a streaming |x| histogram with dynamic
//! range growth (bin-merging, the TensorRT scheme) and four scale-selection
//! rules — max, percentile (paper default, 99.9 %), MSE, and KL/entropy.

use super::qmax_for;

/// Scale-selection rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibratorKind {
    Max,
    /// Percentile in permille-of-one form, e.g. 0.999.
    Percentile,
    Mse,
    Entropy,
}

impl CalibratorKind {
    pub fn parse(s: &str) -> Option<CalibratorKind> {
        Some(match s {
            "max" => CalibratorKind::Max,
            "percentile" => CalibratorKind::Percentile,
            "mse" => CalibratorKind::Mse,
            "entropy" => CalibratorKind::Entropy,
            _ => return None,
        })
    }
}

/// Common interface: stream activation tensors, then compute a scale.
pub trait Calibrator {
    fn observe(&mut self, xs: &[f32]);
    fn scale(&self, bits: u32) -> f32;
}

/// Plain abs-max calibration ("simply finding the max absolute number").
#[derive(Default, Debug)]
pub struct MaxCalibrator {
    amax: f32,
}

impl Calibrator for MaxCalibrator {
    fn observe(&mut self, xs: &[f32]) {
        for &x in xs {
            self.amax = self.amax.max(x.abs());
        }
    }

    fn scale(&self, bits: u32) -> f32 {
        (self.amax.max(1e-12)) / qmax_for(bits) as f32
    }
}

/// Streaming |x| histogram with TensorRT-style dynamic growth: when a new
/// maximum arrives the bin width doubles and existing counts merge 2->1,
/// so earlier observations are never discarded.
#[derive(Debug)]
pub struct HistogramCalibrator {
    pub kind: CalibratorKind,
    /// Percentile level for `CalibratorKind::Percentile` (paper: 0.999).
    pub percentile: f64,
    bins: Vec<u64>,
    bin_width: f32,
    total: u64,
}

pub const HIST_BINS: usize = 2048;

impl HistogramCalibrator {
    pub fn new(kind: CalibratorKind) -> Self {
        Self {
            kind,
            percentile: 0.999,
            bins: vec![0; HIST_BINS],
            bin_width: 0.0,
            total: 0,
        }
    }

    pub fn with_percentile(mut self, p: f64) -> Self {
        self.percentile = p;
        self
    }

    fn grow_to(&mut self, amax: f32) {
        if self.bin_width == 0.0 {
            self.bin_width = amax / HIST_BINS as f32;
            return;
        }
        while amax > self.bin_width * HIST_BINS as f32 {
            // Double the width: merge bin pairs into the lower half.
            for i in 0..HIST_BINS / 2 {
                self.bins[i] = self.bins[2 * i] + self.bins[2 * i + 1];
            }
            for b in self.bins[HIST_BINS / 2..].iter_mut() {
                *b = 0;
            }
            self.bin_width *= 2.0;
        }
    }

    /// The |x| value at the right edge of bin i.
    fn edge(&self, i: usize) -> f32 {
        (i + 1) as f32 * self.bin_width
    }

    fn cdf_value(&self, q: f64) -> f32 {
        let target = (self.total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.edge(i);
            }
        }
        self.edge(HIST_BINS - 1)
    }

    /// Expected quantization MSE if the range is clipped at `clip`,
    /// approximating in-bin mass at bin centers.
    fn mse_at(&self, clip: f32, bits: u32) -> f64 {
        let step = clip / qmax_for(bits) as f32;
        let mut err = 0.0f64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let center = (i as f32 + 0.5) * self.bin_width;
            let e = if center > clip {
                (center - clip) as f64 // clipped mass
            } else {
                // uniform rounding error inside a quant step: std = step/sqrt(12)
                (step as f64) / 12f64.sqrt()
            };
            err += c as f64 * e * e;
        }
        err / self.total.max(1) as f64
    }

    /// KL divergence between the clipped/requantized distribution and the
    /// original histogram (TensorRT's entropy calibrator, simplified to
    /// symmetric ranges).
    fn kl_at(&self, clip_bin: usize, bits: u32) -> f64 {
        let levels = qmax_for(bits) as usize + 1;
        let nb = clip_bin + 1;
        if nb < levels {
            return f64::INFINITY;
        }
        // Reference distribution: bins 0..nb with the clipped tail folded
        // into the last bin.
        let tail: u64 = self.bins[nb..].iter().sum();
        let mut p: Vec<f64> = self.bins[..nb].iter().map(|&c| c as f64).collect();
        *p.last_mut().unwrap() += tail as f64;
        // Quantized distribution: nb bins squeezed into `levels` buckets,
        // then re-expanded uniformly over the nonzero source bins.
        let mut q = vec![0.0f64; nb];
        let per = nb as f64 / levels as f64;
        for l in 0..levels {
            let lo = (l as f64 * per) as usize;
            let hi = (((l + 1) as f64 * per) as usize).min(nb).max(lo + 1);
            let mass: f64 = p[lo..hi].iter().sum();
            let nz = p[lo..hi].iter().filter(|&&v| v > 0.0).count();
            if nz > 0 {
                let share = mass / nz as f64;
                for (i, slot) in q[lo..hi].iter_mut().enumerate() {
                    if p[lo + i] > 0.0 {
                        *slot = share;
                    }
                }
            }
        }
        let psum: f64 = p.iter().sum();
        let qsum: f64 = q.iter().sum();
        if psum == 0.0 || qsum == 0.0 {
            return f64::INFINITY;
        }
        let mut kl = 0.0;
        for (pi, qi) in p.iter().zip(&q) {
            if *pi > 0.0 && *qi > 0.0 {
                let pn = pi / psum;
                let qn = qi / qsum;
                kl += pn * (pn / qn).ln();
            }
        }
        kl
    }
}

impl Calibrator for HistogramCalibrator {
    fn observe(&mut self, xs: &[f32]) {
        let mut amax = 0.0f32;
        for &x in xs {
            amax = amax.max(x.abs());
        }
        if amax > 0.0 {
            self.grow_to(amax);
        }
        if self.bin_width == 0.0 {
            return; // all zeros so far
        }
        let inv = 1.0 / self.bin_width;
        for &x in xs {
            let b = ((x.abs() * inv) as usize).min(HIST_BINS - 1);
            self.bins[b] += 1;
        }
        self.total += xs.len() as u64;
    }

    fn scale(&self, bits: u32) -> f32 {
        let qmax = qmax_for(bits) as f32;
        if self.total == 0 || self.bin_width == 0.0 {
            return 1e-12;
        }
        let calib_max = match self.kind {
            CalibratorKind::Max => self.edge(
                self.bins
                    .iter()
                    .rposition(|&c| c > 0)
                    .unwrap_or(HIST_BINS - 1),
            ),
            CalibratorKind::Percentile => self.cdf_value(self.percentile),
            CalibratorKind::Mse => {
                // Sweep 128 candidate clips across the occupied range.
                let top = self.edge(
                    self.bins
                        .iter()
                        .rposition(|&c| c > 0)
                        .unwrap_or(HIST_BINS - 1),
                );
                let mut best = (f64::INFINITY, top);
                for i in 1..=128 {
                    let clip = top * i as f32 / 128.0;
                    let e = self.mse_at(clip, bits);
                    if e < best.0 {
                        best = (e, clip);
                    }
                }
                best.1
            }
            CalibratorKind::Entropy => {
                let top_bin = self
                    .bins
                    .iter()
                    .rposition(|&c| c > 0)
                    .unwrap_or(HIST_BINS - 1);
                let start = (qmax_for(bits) as usize + 1).min(top_bin);
                let mut best = (f64::INFINITY, self.edge(top_bin));
                let step = ((top_bin - start) / 64).max(1);
                let mut cb = start;
                while cb <= top_bin {
                    let kl = self.kl_at(cb, bits);
                    if kl < best.0 {
                        best = (kl, self.edge(cb));
                    }
                    cb += step;
                }
                best.1
            }
        };
        calib_max.max(1e-12) / qmax
    }
}

/// Construct the calibrator the paper defaults to (99.9 % percentile).
pub fn default_calibrator() -> HistogramCalibrator {
    HistogramCalibrator::new(CalibratorKind::Percentile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gauss_samples(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.next_gauss()).collect()
    }

    #[test]
    fn max_calibrator_tracks_abs_max() {
        let mut c = MaxCalibrator::default();
        c.observe(&[0.5, -3.0, 1.0]);
        c.observe(&[2.0]);
        assert!((c.scale(8) - 3.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn percentile_clips_outliers() {
        // 10k gaussians plus one huge outlier: percentile scale must stay
        // near the gaussian range, max scale must chase the outlier.
        let mut xs = gauss_samples(10_000, 1);
        xs.push(1000.0);
        let mut hist = HistogramCalibrator::new(CalibratorKind::Percentile);
        hist.observe(&xs);
        let mut mx = MaxCalibrator::default();
        mx.observe(&xs);
        let s_h = hist.scale(8);
        let s_m = mx.scale(8);
        assert!(s_m > 5.0 / 127.0, "max should see the outlier: {s_m}");
        assert!(s_h < 8.0 / 127.0, "percentile should clip it: {s_h}");
        assert!(s_h > 2.0 / 127.0, "but keep the gaussian mass: {s_h}");
    }

    #[test]
    fn histogram_growth_preserves_counts() {
        let mut hist = HistogramCalibrator::new(CalibratorKind::Max);
        hist.observe(&[0.1; 100]);
        hist.observe(&[50.0]); // forces several doublings
        let total: u64 = hist.bins.iter().sum();
        assert_eq!(total, 101);
        assert_eq!(hist.total, 101);
    }

    #[test]
    fn mse_beats_max_on_outliers_at_low_bitwidth() {
        // At 8 bits the rounding error is so small that MSE correctly keeps
        // the outliers in range; at 4 bits (15 levels) clipping wins — the
        // classic MSE-calibration trade-off.
        let mut xs = gauss_samples(20_000, 2);
        for i in 0..3 {
            xs.push(15.0 + i as f32);
        }
        let mut mse = HistogramCalibrator::new(CalibratorKind::Mse);
        mse.observe(&xs);
        let clip4 = mse.scale(4) * qmax_for(4) as f32;
        assert!(clip4 < 10.0, "4-bit MSE clip {clip4} should drop outliers");
        let clip8 = mse.scale(8) * qmax_for(8) as f32;
        assert!(clip8 > clip4, "8-bit clip {clip8} should be wider");
    }

    #[test]
    fn entropy_produces_finite_reasonable_scale() {
        let xs = gauss_samples(30_000, 3);
        let mut ent = HistogramCalibrator::new(CalibratorKind::Entropy);
        ent.observe(&xs);
        let s = ent.scale(8);
        let clip = s * 127.0;
        assert!(clip > 1.0 && clip < 6.0, "clip {clip}");
    }

    #[test]
    fn zero_stream_yields_tiny_scale() {
        let hist = HistogramCalibrator::new(CalibratorKind::Percentile);
        assert!(hist.scale(8) <= 1e-11);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(CalibratorKind::parse("mse"), Some(CalibratorKind::Mse));
        assert_eq!(CalibratorKind::parse("nope"), None);
    }
}
