//! Behavioral approximate-multiplier library (EvoApprox substitute).
//!
//! Bit-exact Rust mirrors of `python/compile/multipliers.py` — the Python
//! side generates the LUT artifacts at `make artifacts`, and `cargo test`
//! cross-checks every entry of every shipped LUT against these models
//! (`rust/tests/lut_cross_check.rs`), so the two languages can never drift.
//!
//! All models act on magnitudes with the exact product sign re-applied;
//! operands are signed two's-complement `bits`-wide values.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Fixed-point fraction bits for the Mitchell multiplier (mirror of
/// `multipliers.MITCHELL_FRAC_BITS`).
pub const MITCHELL_FRAC_BITS: u32 = 16;

/// The product function of one approximate compute unit.
pub type MulFn = fn(i64, i64) -> i64;

fn split_sign(a: i64, b: i64) -> (i64, i64, i64) {
    let sign = a.signum() * b.signum();
    (a.abs(), b.abs(), sign)
}

fn floor_log2(x: i64) -> u32 {
    debug_assert!(x >= 1);
    63 - (x as u64).leading_zeros()
}

/// Exact signed product.
pub fn exact(a: i64, b: i64) -> i64 {
    a * b
}

/// Input truncation: zero the k magnitude LSBs of both operands.
pub fn trunc_in(a: i64, b: i64, k: u32) -> i64 {
    let (aa, ab, sign) = split_sign(a, b);
    let mask = !((1i64 << k) - 1);
    sign * ((aa & mask) * (ab & mask))
}

/// Partial-product perforation: drop the k lowest rows (zero b's k LSBs).
pub fn perf_pp(a: i64, b: i64, k: u32) -> i64 {
    let (aa, ab, sign) = split_sign(a, b);
    let mask = !((1i64 << k) - 1);
    sign * (aa * (ab & mask))
}

/// Fixed-width output truncation: exact product with k LSBs zeroed.
pub fn trunc_out(a: i64, b: i64, k: u32) -> i64 {
    let (aa, ab, sign) = split_sign(a, b);
    let mask = !((1i64 << k) - 1);
    sign * ((aa * ab) & mask)
}

/// Output truncation with midpoint compensation on nonzero products.
pub fn comp_trunc_out(a: i64, b: i64, k: u32) -> i64 {
    let (aa, ab, sign) = split_sign(a, b);
    let p = aa * ab;
    let mask = !((1i64 << k) - 1);
    let comp = if p > 0 { 1i64 << (k - 1) } else { 0 };
    sign * ((p & mask) + comp)
}

/// Mitchell logarithmic multiplier, integer fixed-point form.
/// See the Python mirror for the derivation; identical shift arithmetic.
pub fn mitchell(a: i64, b: i64) -> i64 {
    let f = MITCHELL_FRAC_BITS;
    let (aa, ab, sign) = split_sign(a, b);
    if aa == 0 || ab == 0 {
        return 0;
    }
    let ka = floor_log2(aa);
    let kb = floor_log2(ab);
    let one = 1i64 << f;
    let fa = ((aa << f) >> ka) - one;
    let fb = ((ab << f) >> kb) - one;
    let ksum = ka + kb;
    let fsum = fa + fb;
    let (mant, kk) = if fsum >= one {
        (fsum, ksum + 1)
    } else {
        (one + fsum, ksum)
    };
    let p = if kk >= f {
        mant << (kk - f)
    } else {
        mant >> (f - kk)
    };
    sign * p
}

/// Fixed-width array truncation on the two's-complement product:
/// `floor(a*b / 2^k) * 2^k` (arithmetic shift). Sign-ASYMMETRIC: always
/// rounds toward -inf, so every product carries a negative bias that
/// accumulates across the dot product — the gate-level error mode that
/// actually damages DNN accuracy (and that QAT recovers).
pub fn floor_trunc(a: i64, b: i64, k: u32) -> i64 {
    ((a * b) >> k) << k
}

/// DRUM-k: keep k leading magnitude bits (unbiased via the trailing-one
/// trick), multiply exactly, shift back.
pub fn drum(a: i64, b: i64, k: u32) -> i64 {
    let (aa, ab, sign) = split_sign(a, b);
    let reduce = |x: i64| -> i64 {
        if x == 0 {
            return 0;
        }
        let lx = floor_log2(x);
        let t = lx.saturating_sub(k - 1);
        if t == 0 {
            x
        } else {
            ((x >> t) << t) | (1i64 << (t - 1))
        }
    };
    sign * (reduce(aa) * reduce(ab))
}

/// Closed-form descriptor of an ACU — the contract of the emulator's
/// kernel-compilation layer (`emulator::simd` / `emulator::gemm`).
///
/// Families whose product is a short sequence of bit operations carry
/// their parameters here so the GEMM kernels can lower them to branchless
/// inner loops that never touch a LUT (the TFApprox "functional" trick).
/// [`Form::Opaque`] marks models with no such lowering (e.g. Mitchell);
/// those always go through the LUT/function paths.
///
/// Adding a new closed-form family means: a variant here, a branchless
/// body in [`Form::mul_i32`]/[`Form::mul_i64`] (they must stay bit-exact
/// vs the reference [`MulFn`] — see the `form_matches_fun` test), and a
/// vector body in `emulator::simd::cf_row_i32`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Form {
    /// No closed form; LUT or behavioral function only.
    Opaque,
    Exact,
    /// [`trunc_in`] with k masked magnitude LSBs per operand.
    TruncIn(u32),
    /// [`perf_pp`] with k perforated rows (weight-operand mask).
    PerfPp(u32),
    /// [`trunc_out`] with k zeroed product LSBs.
    TruncOut(u32),
    /// [`comp_trunc_out`]: truncation plus midpoint compensation.
    CompTruncOut(u32),
    /// [`floor_trunc`]: two's-complement arithmetic-shift truncation.
    FloorTrunc(u32),
    /// [`drum`] keeping k leading magnitude bits per operand.
    Drum(u32),
}

/// Branchless DRUM operand reduction on a nonnegative magnitude: keep the
/// k leading bits, set the trailing-one unbiasing bit. `x == 0` and the
/// no-truncation case (`t == 0`) fall out of the arithmetic with no
/// branches: `(x | 1)` pins `leading_zeros` and `(1 << 0) >> 1 == 0`.
#[inline(always)]
pub fn drum_reduce_i32(x: i32, k: u32) -> i32 {
    let lx = 31 - (x | 1).leading_zeros();
    let t = lx.saturating_sub(k - 1);
    ((x >> t) << t) | ((1i32 << t) >> 1)
}

/// 64-bit twin of [`drum_reduce_i32`].
#[inline(always)]
pub fn drum_reduce_i64(x: i64, k: u32) -> i64 {
    let lx = 63 - (x | 1).leading_zeros();
    let t = lx.saturating_sub(k - 1);
    ((x >> t) << t) | ((1i64 << t) >> 1)
}

impl Form {
    /// Whether a branchless closed-form kernel exists for this ACU.
    pub fn is_closed(self) -> bool {
        self != Form::Opaque
    }

    /// Branchless i32 product — bit-exact vs the reference [`MulFn`] of
    /// the same family. Valid for operands whose product magnitude fits
    /// i32 (any registry bitwidth; the *accumulator* width is the
    /// caller's concern). Sign handling is the two's-complement identity
    /// `(p ^ neg) - neg` with `neg = (a ^ b) >> 31` — no `signum`, no
    /// branches, and exact for `a == 0` or `b == 0` (magnitude is 0).
    #[inline(always)]
    pub fn mul_i32(self, a: i32, b: i32) -> i32 {
        let neg = (a ^ b) >> 31;
        let aa = a.wrapping_abs();
        let ab = b.wrapping_abs();
        match self {
            Form::Opaque => unreachable!("opaque ACU has no closed form"),
            Form::Exact => a * b,
            Form::TruncIn(k) => {
                let mask = !((1i32 << k) - 1);
                let p = (aa & mask) * (ab & mask);
                (p ^ neg) - neg
            }
            Form::PerfPp(k) => {
                let mask = !((1i32 << k) - 1);
                let p = aa * (ab & mask);
                (p ^ neg) - neg
            }
            Form::TruncOut(k) => {
                let mask = !((1i32 << k) - 1);
                let p = (aa * ab) & mask;
                (p ^ neg) - neg
            }
            Form::CompTruncOut(k) => {
                // Compensation keys off the *untruncated* product being
                // nonzero (p >= 0 here, so p > 0 <=> p != 0).
                let mask = !((1i32 << k) - 1);
                let p = aa * ab;
                let r = (p & mask) + (((p != 0) as i32) << (k - 1));
                (r ^ neg) - neg
            }
            Form::FloorTrunc(k) => ((a * b) >> k) << k,
            Form::Drum(k) => {
                let p = drum_reduce_i32(aa, k) * drum_reduce_i32(ab, k);
                (p ^ neg) - neg
            }
        }
    }

    /// 64-bit twin of [`mul_i32`] for wide-operand functional plans.
    #[inline(always)]
    pub fn mul_i64(self, a: i64, b: i64) -> i64 {
        let neg = (a ^ b) >> 63;
        let aa = a.wrapping_abs();
        let ab = b.wrapping_abs();
        match self {
            Form::Opaque => unreachable!("opaque ACU has no closed form"),
            Form::Exact => a * b,
            Form::TruncIn(k) => {
                let mask = !((1i64 << k) - 1);
                let p = (aa & mask) * (ab & mask);
                (p ^ neg) - neg
            }
            Form::PerfPp(k) => {
                let mask = !((1i64 << k) - 1);
                let p = aa * (ab & mask);
                (p ^ neg) - neg
            }
            Form::TruncOut(k) => {
                let mask = !((1i64 << k) - 1);
                let p = (aa * ab) & mask;
                (p ^ neg) - neg
            }
            Form::CompTruncOut(k) => {
                let mask = !((1i64 << k) - 1);
                let p = aa * ab;
                let r = (p & mask) + (((p != 0) as i64) << (k - 1));
                (r ^ neg) - neg
            }
            Form::FloorTrunc(k) => ((a * b) >> k) << k,
            Form::Drum(k) => {
                let p = drum_reduce_i64(aa, k) * drum_reduce_i64(ab, k);
                (p ^ neg) - neg
            }
        }
    }
}

/// A named ACU with its bitwidth and power proxy (mirrors the Python
/// registry; power normalized to exact8 == 1.0).
#[derive(Clone, Copy, Debug)]
pub struct Multiplier {
    pub name: &'static str,
    pub bits: u32,
    pub fun: MulFn,
    pub power: f64,
    /// Sign-magnitude models satisfy approx(-a,b) == -approx(a,b); the
    /// two's-complement floor-truncation family does not.
    pub symmetric: bool,
    /// Closed-form kernel descriptor ([`Form::Opaque`] = LUT/function
    /// only). Must agree bit-for-bit with `fun` — tested exhaustively.
    pub form: Form,
}

impl Multiplier {
    pub fn qmax(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    pub fn apply(&self, a: i64, b: i64) -> i64 {
        (self.fun)(a, b)
    }
}

macro_rules! mul_entry {
    ($name:literal, $bits:literal, $power:literal, $form:expr, $f:expr) => {
        mul_entry!($name, $bits, $power, $form, $f, true)
    };
    ($name:literal, $bits:literal, $power:literal, $form:expr, $f:expr, $sym:literal) => {
        Multiplier {
            name: $name,
            bits: $bits,
            fun: $f,
            power: $power,
            symmetric: $sym,
            form: $form,
        }
    };
}

/// The full registry — order matches the Python `LUT_ACUS` superset.
pub const REGISTRY: &[Multiplier] = &[
    mul_entry!("exact8", 8, 1.00, Form::Exact, |a, b| exact(a, b)),
    mul_entry!("trunc_in8_2", 8, 0.62, Form::TruncIn(2), |a, b| trunc_in(a, b, 2)),
    mul_entry!("perf_pp8_3", 8, 0.66, Form::PerfPp(3), |a, b| perf_pp(a, b, 3)),
    mul_entry!("perf_pp8_5", 8, 0.45, Form::PerfPp(5), |a, b| perf_pp(a, b, 5)),
    mul_entry!("trunc_out8_4", 8, 0.78, Form::TruncOut(4), |a, b| trunc_out(a, b, 4)),
    mul_entry!(
        "comp_trunc_out8_6",
        8,
        0.70,
        Form::CompTruncOut(6),
        |a, b| comp_trunc_out(a, b, 6)
    ),
    mul_entry!("mitchell8", 8, 0.40, Form::Opaque, |a, b| mitchell(a, b)),
    mul_entry!("drum8_4", 8, 0.52, Form::Drum(4), |a, b| drum(a, b, 4)),
    mul_entry!("drum8_6", 8, 0.74, Form::Drum(6), |a, b| drum(a, b, 6)),
    mul_entry!(
        "floor_trunc8_5",
        8,
        0.72,
        Form::FloorTrunc(5),
        |a, b| floor_trunc(a, b, 5),
        false
    ),
    mul_entry!(
        "floor_trunc8_6",
        8,
        0.65,
        Form::FloorTrunc(6),
        |a, b| floor_trunc(a, b, 6),
        false
    ),
    mul_entry!(
        "floor_trunc8_7",
        8,
        0.58,
        Form::FloorTrunc(7),
        |a, b| floor_trunc(a, b, 7),
        false
    ),
    mul_entry!("exact12", 12, 2.25, Form::Exact, |a, b| exact(a, b)),
    mul_entry!("trunc_out12_4", 12, 1.95, Form::TruncOut(4), |a, b| trunc_out(a, b, 4)),
    mul_entry!(
        "comp_trunc_out12_4",
        12,
        1.97,
        Form::CompTruncOut(4),
        |a, b| comp_trunc_out(a, b, 4)
    ),
    mul_entry!("mitchell12", 12, 0.90, Form::Opaque, |a, b| mitchell(a, b)),
    mul_entry!("drum12_6", 12, 1.15, Form::Drum(6), |a, b| drum(a, b, 6)),
    // Table-2 operating-point aliases (same functions as in Python).
    mul_entry!(
        "mul8s_1l2h_like",
        8,
        0.65,
        Form::FloorTrunc(6),
        |a, b| floor_trunc(a, b, 6),
        false
    ),
    mul_entry!("mul12s_2km_like", 12, 1.95, Form::TruncOut(4), |a, b| trunc_out(a, b, 4)),
];

/// Look up an ACU by name.
pub fn get(name: &str) -> Result<&'static Multiplier> {
    REGISTRY
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow!("unknown multiplier {name:?}"))
}

/// All names at a given bitwidth.
pub fn names_with_bits(bits: u32) -> Vec<&'static str> {
    REGISTRY
        .iter()
        .filter(|m| m.bits == bits)
        .map(|m| m.name)
        .collect()
}

/// Error characterization of an ACU vs the exact product (Table-2 header
/// metrics). 8-bit: exhaustive; wider: deterministic sampling.
#[derive(Clone, Debug)]
pub struct ErrorProfile {
    pub name: String,
    pub bits: u32,
    /// Mean absolute error as % of the 2^(2b) output range (EvoApprox MAE%).
    pub mae_pct: f64,
    /// Mean relative error % over nonzero exact products.
    pub mre_pct: f64,
    /// Worst-case absolute error.
    pub wce: i64,
    pub power: f64,
}

pub fn characterize(m: &Multiplier, samples: usize, seed: u64) -> ErrorProfile {
    let half = 1i64 << (m.bits - 1);
    let mut abs_sum = 0.0f64;
    let mut rel_sum = 0.0f64;
    let mut rel_n = 0u64;
    let mut wce = 0i64;
    let mut n = 0u64;
    let mut eval = |a: i64, b: i64| {
        let ex = a * b;
        let ap = m.apply(a, b);
        let err = (ap - ex).abs();
        abs_sum += err as f64;
        wce = wce.max(err);
        if ex != 0 {
            rel_sum += err as f64 / ex.abs() as f64;
            rel_n += 1;
        }
        n += 1;
    };
    if m.bits <= 8 {
        for a in -half..half {
            for b in -half..half {
                eval(a, b);
            }
        }
    } else {
        let mut rng = crate::util::rng::Rng::new(seed);
        for _ in 0..samples.max(1) {
            let a = rng.range_i64(-half, half);
            let b = rng.range_i64(-half, half);
            eval(a, b);
        }
    }
    let out_range = (1u64 << (2 * m.bits)) as f64;
    ErrorProfile {
        name: m.name.to_string(),
        bits: m.bits,
        mae_pct: abs_sum / n as f64 / out_range * 100.0,
        mre_pct: rel_sum / rel_n as f64 * 100.0,
        wce,
        power: m.power,
    }
}

/// Characterize the whole registry (the `adapt multipliers` report).
pub fn characterize_all(samples: usize) -> BTreeMap<String, ErrorProfile> {
    REGISTRY
        .iter()
        .map(|m| (m.name.to_string(), characterize(m, samples, 0)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_annihilates_for_all() {
        for m in REGISTRY {
            let half = 1i64 << (m.bits - 1);
            for v in [-half, -3, -1, 0, 1, 5, half - 1] {
                assert_eq!(m.apply(0, v), 0, "{} 0*{v}", m.name);
                assert_eq!(m.apply(v, 0), 0, "{} {v}*0", m.name);
            }
        }
    }

    #[test]
    fn floor_trunc_is_negatively_biased() {
        let mut bias = 0i64;
        for a in -128i64..128 {
            for b in -128i64..128 {
                let e = floor_trunc(a, b, 6) - a * b;
                assert!(e <= 0, "floor rounds toward -inf");
                assert!(e > -64);
                bias += e;
            }
        }
        let mean = bias as f64 / 65536.0;
        assert!((-32.0..-28.0).contains(&mean), "mean bias {mean}");
    }

    #[test]
    fn sign_symmetry() {
        for m in REGISTRY.iter().filter(|m| m.symmetric) {
            let half = 1i64 << (m.bits - 1);
            let vals = [1, 2, 7, half / 2, half - 1];
            for &a in &vals {
                for &b in &vals {
                    let p = m.apply(a, b);
                    assert_eq!(m.apply(-a, b), -p, "{}", m.name);
                    assert_eq!(m.apply(a, -b), -p, "{}", m.name);
                    assert_eq!(m.apply(-a, -b), p, "{}", m.name);
                }
            }
        }
    }

    #[test]
    fn exact_is_exact() {
        assert_eq!(exact(-128, 127), -16256);
        assert_eq!(exact(2047, -2048), -4192256);
    }

    #[test]
    fn mitchell_exact_on_powers_of_two() {
        // log-domain addition is exact when both mantissa fractions are 0.
        for &a in &[1i64, 2, 4, 8, 16, 32, 64] {
            for &b in &[1i64, 2, 4, 8, 16, 32, 64] {
                assert_eq!(mitchell(a, b), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn mitchell_underestimates_within_bound() {
        // Mitchell's classic property: approx <= exact, relative error
        // bounded (~8.6% continuous; integer fixed-point reaches 11.1% at
        // tiny operands, e.g. 3*3 -> 8).
        for a in 1..128i64 {
            for b in 1..128i64 {
                let ap = mitchell(a, b);
                let ex = a * b;
                assert!(ap <= ex, "{a}*{b}: {ap} > {ex}");
                let rel = (ex - ap) as f64 / ex as f64;
                assert!(rel <= 0.12, "{a}*{b}: rel {rel}");
            }
        }
    }

    #[test]
    fn drum_keeps_small_operands_exact() {
        for a in -15i64..16 {
            for b in -15i64..16 {
                assert_eq!(drum(a, b, 4), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn trunc_out_bounded_error() {
        for a in -128i64..128 {
            for b in -128i64..128 {
                let err = (trunc_out(a, b, 4) - a * b).abs();
                assert!(err < 16, "{a}*{b} err {err}");
            }
        }
    }

    #[test]
    fn characterization_matches_python_numbers() {
        // Values printed by `python compile/multipliers.py` (exhaustive).
        let m = get("mitchell8").unwrap();
        let p = characterize(m, 0, 0);
        assert!((p.mre_pct - 3.69941).abs() < 0.01, "MRE {}", p.mre_pct);
        assert_eq!(p.wce, 1024);
        let m = get("trunc_out8_4").unwrap();
        let p = characterize(m, 0, 0);
        assert!((p.mre_pct - 1.18521).abs() < 0.01);
        assert_eq!(p.wce, 15);
        let m = get("mul8s_1l2h_like").unwrap();
        let p = characterize(m, 0, 0);
        assert!((p.mre_pct - 5.673).abs() < 0.01, "MRE {}", p.mre_pct);
        assert_eq!(p.wce, 63);
    }

    #[test]
    fn registry_lookup() {
        assert!(get("mul8s_1l2h_like").is_ok());
        assert!(get("nope").is_err());
        assert_eq!(names_with_bits(8).len(), 13);
    }

    #[test]
    fn form_matches_fun_exhaustive_at_8bit() {
        // The closed-form kernels compile `form`, the LUTs compile `fun`;
        // this is the contract that lets the emulator swap between them.
        for m in REGISTRY.iter().filter(|m| m.bits == 8) {
            if !m.form.is_closed() {
                continue;
            }
            for a in -128i64..128 {
                for b in -128i64..128 {
                    let want = m.apply(a, b);
                    let got32 = m.form.mul_i32(a as i32, b as i32) as i64;
                    let got64 = m.form.mul_i64(a, b);
                    assert_eq!(got32, want, "{} mul_i32 {a}*{b}", m.name);
                    assert_eq!(got64, want, "{} mul_i64 {a}*{b}", m.name);
                }
            }
        }
    }

    #[test]
    fn form_matches_fun_sampled_at_12bit() {
        let mut rng = crate::util::rng::Rng::new(41);
        for m in REGISTRY.iter().filter(|m| m.bits == 12) {
            if !m.form.is_closed() {
                continue;
            }
            let half = 1i64 << (m.bits - 1);
            for _ in 0..20_000 {
                let a = rng.range_i64(-half, half);
                let b = rng.range_i64(-half, half);
                let want = m.apply(a, b);
                assert_eq!(
                    m.form.mul_i32(a as i32, b as i32) as i64,
                    want,
                    "{} mul_i32 {a}*{b}",
                    m.name
                );
                assert_eq!(m.form.mul_i64(a, b), want, "{} mul_i64 {a}*{b}", m.name);
            }
        }
    }

    #[test]
    fn drum_reduce_edge_cases() {
        // Branchless reduction must keep x == 0 and small operands exact.
        for k in [4u32, 6] {
            assert_eq!(drum_reduce_i32(0, k), 0);
            assert_eq!(drum_reduce_i64(0, k), 0);
            for x in 0..(1i32 << k) {
                assert_eq!(drum_reduce_i32(x, k), x, "k={k} x={x}");
            }
        }
    }
}
