//! Product look-up tables (§3.4 "LUT generator" + §4.3 table layout).
//!
//! A LUT materializes an ACU as a `(2^b, 2^b)` i32 table indexed by
//! biased-unsigned operands (`value + 2^(b-1)`), so the emulator's inner
//! loop is a pure gather — "we would compute any approximate unit without
//! the need to implement the corresponding function directly" (§4).
//!
//! Tables are loaded from the binary artifacts Python emits (format below)
//! or generated in-process from [`crate::mult`]; `cargo test` cross-checks
//! the two sources entry-for-entry. Storage is 64-byte aligned, mirroring
//! the paper's cache-line-aligned tables.
//!
//! Binary format (little-endian):
//! `magic u32 | bits u32 | n u32 | reserved u32 | n*n i32 row-major`.

pub mod registry;

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::mult::Multiplier;

pub use registry::LutRegistry;

pub const LUT_MAGIC: u32 = 0x4C55_5401;

/// 64-byte-aligned i32 buffer (one cache line on x86).
#[repr(C, align(64))]
struct AlignedBlock([i32; 16]);

/// An in-memory product LUT.
pub struct Lut {
    pub bits: u32,
    /// Side length (2^bits).
    pub n: usize,
    // Backing storage in aligned blocks; `data` indexes into it.
    blocks: Vec<AlignedBlock>,
}

impl Lut {
    /// Entries as a flat row-major slice of length n*n.
    #[inline]
    pub fn data(&self) -> &[i32] {
        // Safety-free flattening: AlignedBlock is repr(C) over [i32; 16].
        let ptr = self.blocks.as_ptr() as *const i32;
        unsafe { std::slice::from_raw_parts(ptr, self.n * self.n) }
    }

    fn alloc(bits: u32) -> Lut {
        let n = 1usize << bits;
        let words = n * n;
        let nblocks = words.div_ceil(16);
        let mut blocks = Vec::with_capacity(nblocks);
        blocks.resize_with(nblocks, || AlignedBlock([0; 16]));
        Lut { bits, n, blocks }
    }

    fn data_mut(&mut self) -> &mut [i32] {
        let ptr = self.blocks.as_mut_ptr() as *mut i32;
        unsafe { std::slice::from_raw_parts_mut(ptr, self.n * self.n) }
    }

    /// Generate from a behavioral multiplier (the in-process LUT generator).
    pub fn generate(m: &Multiplier) -> Lut {
        let mut lut = Lut::alloc(m.bits);
        let n = lut.n;
        let half = (n / 2) as i64;
        let data = lut.data_mut();
        for (i, row) in data.chunks_mut(n).enumerate() {
            let a = i as i64 - half;
            for (j, slot) in row.iter_mut().enumerate() {
                let b = j as i64 - half;
                *slot = m.apply(a, b) as i32;
            }
        }
        lut
    }

    /// Load from the Python-emitted artifact.
    pub fn load(path: &Path) -> Result<Lut> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening LUT {}", path.display()))?;
        let mut header = [0u8; 16];
        f.read_exact(&mut header)?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let bits = u32::from_le_bytes(header[4..8].try_into().unwrap());
        let n = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
        if magic != LUT_MAGIC {
            bail!("bad LUT magic {magic:#x} in {}", path.display());
        }
        if n != (1usize << bits) {
            bail!("LUT n {n} != 2^{bits}");
        }
        let mut lut = Lut::alloc(bits);
        let mut bytes = vec![0u8; n * n * 4];
        f.read_exact(&mut bytes)?;
        let data = lut.data_mut();
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = i32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(lut)
    }

    /// Scalar lookup of the signed product approx(a, b).
    #[inline(always)]
    pub fn mul(&self, a: i32, b: i32) -> i32 {
        let half = (self.n / 2) as i32;
        let ia = (a + half) as usize;
        let ib = (b + half) as usize;
        debug_assert!(ia < self.n && ib < self.n, "operand out of range");
        self.data()[ia * self.n + ib]
    }

    /// Row slice for operand `a` — hoisted out of inner GEMM loops so the
    /// hot loop is `row[(b + half)]` with a single add.
    #[inline(always)]
    pub fn row(&self, a: i32) -> &[i32] {
        let half = (self.n / 2) as i32;
        let ia = (a + half) as usize;
        &self.data()[ia * self.n..(ia + 1) * self.n]
    }

    /// Size in bytes (cache/VMEM footprint reporting).
    pub fn size_bytes(&self) -> usize {
        self.n * self.n * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult;

    #[test]
    fn generate_matches_behavioral() {
        let m = mult::get("mitchell8").unwrap();
        let lut = Lut::generate(m);
        assert_eq!(lut.n, 256);
        for &(a, b) in &[(0, 0), (-128, 127), (5, -7), (127, 127), (-1, -1)] {
            assert_eq!(lut.mul(a, b) as i64, m.apply(a as i64, b as i64));
        }
    }

    #[test]
    fn alignment_is_64_bytes() {
        let m = mult::get("exact8").unwrap();
        let lut = Lut::generate(m);
        assert_eq!(lut.data().as_ptr() as usize % 64, 0);
    }

    #[test]
    fn row_equals_mul() {
        let m = mult::get("drum8_4").unwrap();
        let lut = Lut::generate(m);
        let row = lut.row(-3);
        for b in -128..128 {
            assert_eq!(row[(b + 128) as usize], lut.mul(-3, b));
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("adapt_lut_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 32]).unwrap();
        assert!(Lut::load(&p).is_err());
    }

    #[test]
    fn roundtrip_through_file() {
        let m = mult::get("trunc_out8_4").unwrap();
        let lut = Lut::generate(m);
        let dir = std::env::temp_dir().join("adapt_lut_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.bin");
        // Write in the Python format.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&LUT_MAGIC.to_le_bytes());
        bytes.extend_from_slice(&8u32.to_le_bytes());
        bytes.extend_from_slice(&256u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        for v in lut.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let re = Lut::load(&p).unwrap();
        assert_eq!(re.data(), lut.data());
    }
}
