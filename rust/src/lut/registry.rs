//! Shared ACU table registry: resolves ACU *names* to `Arc<Lut>` exactly
//! once per process, so a heterogeneous per-layer plan that uses the same
//! ACU in twenty layers (or twenty executors serving the same model)
//! shares one 256 KiB table instead of twenty.
//!
//! Resolution order:
//! 1. the in-memory cache,
//! 2. the LUT artifact file named by the manifest (bit-exact with the
//!    Python generator — `rust/tests/lut_cross_check.rs`),
//! 3. in-process generation from [`crate::mult`] (artifact-free runs:
//!    tests, benches, `adapt plan`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::Lut;
use crate::graph::Manifest;
use crate::mult;

/// Thread-safe name -> `Arc<Lut>` resolver.
pub struct LutRegistry {
    /// ACU name -> artifact path (from the manifest; may be empty).
    files: BTreeMap<String, PathBuf>,
    cache: Mutex<BTreeMap<String, Arc<Lut>>>,
}

impl LutRegistry {
    /// Registry with no artifact files: every table is generated from the
    /// behavioral multiplier library on first use.
    pub fn in_memory() -> LutRegistry {
        LutRegistry {
            files: BTreeMap::new(),
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Registry backed by the manifest's LUT artifacts, falling back to
    /// in-process generation for ACUs the artifacts don't cover.
    pub fn from_manifest(manifest: &Manifest) -> LutRegistry {
        let files = manifest
            .luts
            .iter()
            .map(|(name, meta)| (name.clone(), manifest.root.join(&meta.file)))
            .collect();
        LutRegistry {
            files,
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Resolve an ACU name to its shared table.
    pub fn get(&self, acu: &str) -> Result<Arc<Lut>> {
        let mut cache = self.cache.lock().expect("lut registry poisoned");
        if let Some(lut) = cache.get(acu) {
            return Ok(lut.clone());
        }
        let lut = match self.files.get(acu).filter(|p| p.exists()) {
            Some(path) => Lut::load(path)
                .with_context(|| format!("loading LUT artifact for ACU {acu:?}"))?,
            None => {
                let m = mult::get(acu)
                    .with_context(|| format!("ACU {acu:?}: no LUT artifact and no behavioral model"))?;
                Lut::generate(m)
            }
        };
        let lut = Arc::new(lut);
        cache.insert(acu.to_string(), lut.clone());
        Ok(lut)
    }

    /// Resolve a whole plan's worth of names up front (fail fast at
    /// executor construction instead of mid-forward).
    pub fn preload(&self, acus: &[String]) -> Result<()> {
        for acu in acus {
            self.get(acu)?;
        }
        Ok(())
    }

    /// Number of resolved tables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().expect("lut registry poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_and_caches() {
        let reg = LutRegistry::in_memory();
        let a = reg.get("drum8_4").unwrap();
        let b = reg.get("drum8_4").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same Arc shared across lookups");
        assert_eq!(reg.cached(), 1);
        assert_eq!(a.mul(-3, 5), mult::get("drum8_4").unwrap().apply(-3, 5) as i32);
    }

    #[test]
    fn unknown_acu_errors() {
        let reg = LutRegistry::in_memory();
        assert!(reg.get("no_such_acu").is_err());
    }

    #[test]
    fn preload_resolves_all() {
        let reg = LutRegistry::in_memory();
        reg.preload(&["exact8".to_string(), "mitchell8".to_string()]).unwrap();
        assert_eq!(reg.cached(), 2);
    }
}
