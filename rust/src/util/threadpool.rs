//! Thread pools — the stand-in for the paper's OpenMP layer (§4.2).
//!
//! Two tiers of parallelism live here:
//!
//! * **Scoped helpers** ([`parallel_for_chunks`], [`parallel_map_into`])
//!   split an index range into contiguous chunks, one per worker, exactly
//!   like `#pragma omp parallel for schedule(static)` over the batch/row
//!   dimension of the im2col GEMM. Workers are spawned per call via
//!   `std::thread::scope`: each layer GEMM borrows stack-local slices, and
//!   scoped spawning keeps those borrows simple and the code free of
//!   unsafe. They are also safe to call from *inside* a [`ThreadPool`]
//!   job (no shared queue, so no nested-parallelism deadlock).
//!
//! * **[`ThreadPool`]** is the persistent pool for coarse-grained work:
//!   long sweeps submit many independent jobs (one per (layer, ACU) pair)
//!   and the same workers serve all of them, so per-worker state (e.g. an
//!   executor scratch arena in a `thread_local`) survives from job to job.
//!   [`ThreadPool::run_ordered`] returns results in submission order no
//!   matter which worker finished first — the property the deterministic
//!   sensitivity sweep is built on.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Number of workers to use: `ADAPT_THREADS` env or available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ADAPT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolQueue {
    jobs: VecDeque<Job>,
    closed: bool,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    /// Signalled on every submit and on close.
    available: Condvar,
}

/// A persistent worker pool with job submission.
///
/// Workers live for the life of the pool (dropped => queue closes, workers
/// drain remaining jobs and join). Jobs are `'static` + `Send`; shared
/// read-only context crosses into jobs via `Arc`.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> ThreadPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue {
                jobs: VecDeque::new(),
                closed: false,
            }),
            available: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("adapt-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool with [`default_threads`] workers (`ADAPT_THREADS` env).
    pub fn with_default_threads() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().expect("pool queue poisoned");
        q.jobs.push_back(Box::new(f));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run a batch of jobs and return their results **in submission
    /// order**, regardless of which worker finished first. A panicking job
    /// is re-raised on the caller once all results are in flight.
    pub fn run_ordered<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.submit(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for _ in 0..n {
            let (i, r) = rx.recv().expect("pool worker died mid-batch");
            match r {
                Ok(v) => slots[i] = Some(v),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        slots
            .into_iter()
            .map(|v| v.expect("every job reports exactly once"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            q.closed = true;
        }
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break Some(j);
                }
                if q.closed {
                    break None;
                }
                q = shared.available.wait(q).expect("pool queue poisoned");
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Run `body(start, end)` over disjoint chunks of `0..n` on `threads`
/// workers. `body` must be `Sync` (immutable captures) — mutation goes
/// through the per-chunk output slices the callers split beforehand.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            scope.spawn(move || body(lo, hi));
        }
    });
}

/// Map `0..n` through `f` in parallel, writing into the provided output
/// slice (one element per index). This is the mutable-output variant used
/// by the emulator's row-parallel GEMM.
pub fn parallel_map_into<T, F>(out: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut base = 0usize;
        let f = &f;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            scope.spawn(move || {
                for (i, slot) in head.iter_mut().enumerate() {
                    f(base + i, slot);
                }
            });
            rest = tail;
            base += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_once() {
        let hits = AtomicUsize::new(0);
        parallel_for_chunks(1000, 4, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn map_into_writes_every_slot() {
        let mut out = vec![0usize; 257];
        parallel_map_into(&mut out, 4, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let mut out = vec![0u32; 5];
        parallel_map_into(&mut out, 1, |i, slot| *slot = i as u32);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_range_is_noop() {
        parallel_for_chunks(0, 8, |_, _| panic!("must not run"));
        let mut out: Vec<u8> = vec![];
        parallel_map_into(&mut out, 8, |_, _| {});
    }

    #[test]
    fn pool_run_ordered_preserves_submission_order() {
        let pool = ThreadPool::new(4);
        // Reverse sleep times so late submissions finish first.
        let jobs: Vec<_> = (0..16u64)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_millis((16 - i) % 4));
                    i * 3
                }
            })
            .collect();
        let out = pool.run_ordered(jobs);
        assert_eq!(out, (0..16u64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pool_workers_persist_across_batches() {
        // Worker-local state (thread ids) must repeat across run_ordered
        // calls: the whole point of a persistent pool.
        let pool = ThreadPool::new(2);
        let ids = |pool: &ThreadPool| -> std::collections::BTreeSet<String> {
            let jobs: Vec<_> = (0..8)
                .map(|_| {
                    move || {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        format!("{:?}", std::thread::current().id())
                    }
                })
                .collect();
            pool.run_ordered(jobs).into_iter().collect()
        };
        let first = ids(&pool);
        let second = ids(&pool);
        assert!(!first.is_empty() && first.len() <= 2);
        assert!(second.is_subset(&first), "workers were respawned");
    }

    #[test]
    fn pool_submit_runs_fire_and_forget_jobs() {
        let pool = ThreadPool::new(3);
        let hits = std::sync::Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let hits = std::sync::Arc::clone(&hits);
            pool.submit(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // close + join drains the queue
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn pool_clamps_zero_threads() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.run_ordered(vec![|| 7usize]), vec![7]);
    }
}
