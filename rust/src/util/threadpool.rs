//! Scoped threadpool — the stand-in for the paper's OpenMP layer (§4.2).
//!
//! `parallel_for` splits an index range into contiguous chunks, one per
//! worker, exactly like `#pragma omp parallel for schedule(static)` over
//! the batch/row dimension of the im2col GEMM. Workers are spawned per
//! call via `std::thread::scope`; for the long-running inference engine the
//! pool amortizes nothing anyway (each layer GEMM is milliseconds), and
//! scoped spawning keeps borrows simple and the code free of unsafe.

/// Number of workers to use: `ADAPT_THREADS` env or available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ADAPT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `body(start, end)` over disjoint chunks of `0..n` on `threads`
/// workers. `body` must be `Sync` (immutable captures) — mutation goes
/// through the per-chunk output slices the callers split beforehand.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            scope.spawn(move || body(lo, hi));
        }
    });
}

/// Map `0..n` through `f` in parallel, writing into the provided output
/// slice (one element per index). This is the mutable-output variant used
/// by the emulator's row-parallel GEMM.
pub fn parallel_map_into<T, F>(out: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut base = 0usize;
        let f = &f;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            scope.spawn(move || {
                for (i, slot) in head.iter_mut().enumerate() {
                    f(base + i, slot);
                }
            });
            rest = tail;
            base += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_once() {
        let hits = AtomicUsize::new(0);
        parallel_for_chunks(1000, 4, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn map_into_writes_every_slot() {
        let mut out = vec![0usize; 257];
        parallel_map_into(&mut out, 4, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let mut out = vec![0u32; 5];
        parallel_map_into(&mut out, 1, |i, slot| *slot = i as u32);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_range_is_noop() {
        parallel_for_chunks(0, 8, |_, _| panic!("must not run"));
        let mut out: Vec<u8> = vec![];
        parallel_map_into(&mut out, 8, |_, _| {});
    }
}
