//! Minimal JSON parser + writer (serde substitute for `manifest.json`).
//!
//! Supports the full JSON grammar we emit from Python: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Numbers are kept as
//! f64 with integer accessors — manifest integers (shapes, ids) are well
//! inside the 2^53 exact range.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Max container nesting the parser accepts. The parser recurses per
/// level, so an adversarial `[[[[...` document must hit this typed error
/// long before it can exhaust the thread's stack (serving threads parse
/// untrusted request bodies).
pub const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ----- typed accessors (ergonomic for manifest walking) -------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn i64(&self) -> Result<i64> {
        let n = self.f64()?;
        if n.fract() != 0.0 || n.abs() > 9.0e15 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn usize(&self) -> Result<usize> {
        let v = self.i64()?;
        usize::try_from(v).context("negative integer where usize expected")
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// Array of usize (shapes etc.).
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    /// Array of numbers as f32 (inference payloads). f64 → f32 is exact
    /// for values that entered as f32 (see [`from_f32s`](Self::from_f32s)).
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.arr()?.iter().map(|v| Ok(v.f64()? as f32)).collect()
    }

    /// JSON array from an f32 slice. f32 → f64 is exact, and the writer
    /// prints a round-tripping decimal, so the payload is bit-identical
    /// after parse + `as f32` on the other end.
    pub fn from_f32s(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ----- writer --------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no inf/NaN: emit null rather than an unparseable
                // bare `inf` (the bit-exact round-trip promise covers
                // finite floats only).
                if !n.is_finite() {
                    out.push_str("null");
                // -0.0 must keep its sign bit (inference payloads promise
                // bit-exact f32 round-trips), so it takes the float path.
                } else if n.fract() == 0.0
                    && n.abs() < 9.0e15
                    && (*n != 0.0 || n.is_sign_positive())
                {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} levels at byte {}", self.i);
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs: manifest is ASCII, but be correct.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().context("bad number")?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"shape": [2, 3], "name": "w", "flag": false}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().usize_vec().unwrap(), vec![2, 3]);
        assert_eq!(v.get("name").unwrap().str().unwrap(), "w");
        assert!(!v.get("flag").unwrap().bool().unwrap());
        assert!(v.get("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé 😀""#).unwrap();
        assert_eq!(v.str().unwrap(), "Aé 😀");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.25e2").unwrap().f64().unwrap(), -325.0);
        assert_eq!(Json::parse("42").unwrap().i64().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().i64().is_err());
    }

    #[test]
    fn f32_payloads_roundtrip_bit_exact() {
        let xs = vec![
            0.1f32,
            -0.0,
            1.0 / 3.0,
            f32::MIN_POSITIVE,
            3.402_823_3e38,
            -7.25,
        ];
        let text = Json::from_f32s(&xs).to_string();
        let back = Json::parse(&text).unwrap().f32_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} mangled to {b}");
        }
    }

    #[test]
    fn deep_nesting_and_empty() {
        let v = Json::parse(r#"{"a": [], "b": {}}"#).unwrap();
        assert!(v.get("a").unwrap().arr().unwrap().is_empty());
        assert!(v.get("b").unwrap().obj().unwrap().is_empty());
    }

    #[test]
    fn depth_cap_rejects_instead_of_overflowing() {
        // Within the cap parses fine (cap counts containers, so exactly
        // MAX_DEPTH arrays is legal).
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // One past the cap is a typed error...
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&over).unwrap_err();
        assert!(e.to_string().contains("nesting"), "got: {e}");
        // ...and so is an adversarial 100k-deep document — an error, not
        // a stack overflow (serving threads parse untrusted bodies).
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        let bomb = format!("{}{}", "{\"a\":".repeat(100_000), "1");
        assert!(Json::parse(&bomb).is_err());
        // Mixed nesting counts every container level.
        let mixed = format!("{}1{}", "[{\"k\":".repeat(70), "}]".repeat(70));
        assert!(Json::parse(&mixed).is_err(), "140 levels exceeds the cap");
    }

    #[test]
    fn unicode_escape_surrogate_pairs() {
        // A surrogate pair (U+1F600) assembles into one char.
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.str().unwrap(), "😀");
        // Pair + ASCII escapes + BMP escape in one string.
        let v = Json::parse(r#""aA😀\né""#).unwrap();
        assert_eq!(v.str().unwrap(), "aA😀\né");
        // A lone high surrogate is malformed, not a panic.
        assert!(Json::parse(r#""\ud83d""#).is_err());
        assert!(Json::parse(r#""\ud83dx""#).is_err());
        // Truncated escapes are malformed too.
        assert!(Json::parse(r#""\u00""#).is_err());
        assert!(Json::parse(r#""\ud83d\ude""#).is_err());
        // Escaped strings survive a write → parse round trip.
        let original = Json::Str("quote\" slash\\ tab\t 😀 \u{1} end".into());
        assert_eq!(Json::parse(&original.to_string()).unwrap(), original);
    }

    #[test]
    fn non_finite_numbers_write_as_null_not_bare_inf() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // The output stays parseable JSON.
        let text = Json::Arr(vec![Json::Num(1.5), Json::Num(f64::NAN)]).to_string();
        assert_eq!(text, "[1.5,null]");
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn extreme_f32_values_roundtrip_through_from_f32s() {
        let xs = vec![
            f32::MAX,
            -f32::MAX,
            f32::MIN_POSITIVE,          // smallest normal
            -f32::MIN_POSITIVE,
            f32::from_bits(1),          // smallest subnormal
            f32::from_bits(0x007f_ffff), // largest subnormal
            -f32::from_bits(1),
            0.0,
            -0.0,
            1.0e-45,
            3.402_823_4e38,
        ];
        let text = Json::from_f32s(&xs).to_string();
        let back = Json::parse(&text).unwrap().f32_vec().unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a:e} mangled to {b:e}");
        }
    }
}
