//! Tiny CLI argument parser (clap substitute).
//!
//! Grammar: `adapt <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be written `--key=value` or `--key value`. An option may
//! repeat (`--model a --model b`): [`Args::get`] returns the last value,
//! [`Args::get_all`] every value in order.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options
                        .entry(k.to_string())
                        .or_default()
                        .push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.entry(body.to_string()).or_default().push(v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last value of a (possibly repeated) option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every value of a repeated option, in order (`--model a --model b`).
    pub fn get_all(&self, name: &str) -> Vec<String> {
        self.options.get(name).cloned().unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .filter(|p| !p.is_empty())
                    .map(|p| p.to_string())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("table2 extra --model small_vgg --epochs 2 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.get("model"), Some("small_vgg"));
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 2);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn flag_followed_by_word_consumes_it_as_value() {
        // Documented greedy behavior: `--verbose extra` means verbose=extra.
        let a = parse("run --verbose extra");
        assert_eq!(a.get("verbose"), Some("extra"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form_and_lists() {
        let a = parse("bench --models=a,b,c --lr=0.01");
        assert_eq!(a.get_list("models"), vec!["a", "b", "c"]);
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn pool_flags_parse_in_both_spellings() {
        // The engine-pool / sweep flags: `--workers N` and `--queue-depth D`
        // (space or `=` form), defaults applying when absent.
        let a = parse("serve --model small_vgg --workers 4 --queue-depth=128");
        assert_eq!(a.get_usize("workers", 1).unwrap(), 4);
        assert_eq!(a.get_usize("queue-depth", 256).unwrap(), 128);
        let b = parse("sensitivity --model small_vgg");
        assert_eq!(b.get_usize("workers", 8).unwrap(), 8);
        assert!(parse("serve --workers nope").get_usize("workers", 1).is_err());
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let a = parse("serve --model alpha --model beta --synthetic");
        assert_eq!(a.get_all("model"), vec!["alpha", "beta"]);
        assert_eq!(a.get("model"), Some("beta"), "get returns the last value");
        assert!(a.get_all("nope").is_empty());
        let b = parse("serve --workers=2 --workers=4");
        assert_eq!(b.get_usize("workers", 1).unwrap(), 4);
    }

    #[test]
    fn f64_option() {
        let a = parse("sensitivity --budget 2.5");
        assert_eq!(a.get_f64("budget", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        assert!(parse("x --budget nope").get_f64("budget", 0.0).is_err());
    }
}
