//! Deterministic PRNGs: SplitMix64 (seeding) + xoshiro256** (streams).
//!
//! Every stochastic choice in the framework — synthetic datasets, property
//! tests, workload generators — flows through these so runs are exactly
//! reproducible across machines (a requirement for the Table-2 accuracy
//! comparisons, where the Rust coordinator generates the training data).

/// SplitMix64: used to expand a seed into stream states.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. per dataset split / per class).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[1].wrapping_mul(5)).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) at f64 precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's unbiased bounded generation (rejection on the low word).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi) (i64 range).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (deterministic pair caching omitted
    /// on purpose: one value per call keeps streams simple to reason about).
    pub fn next_gauss(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        let mut r = Rng::new(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.next_gauss() as f64;
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forks_are_decorrelated() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
