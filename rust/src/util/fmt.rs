//! Small formatting helpers for reports and benches.

use std::time::Duration;

/// Human duration: ns/µs/ms/s/min with 3 significant-ish digits.
pub fn dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns < 60_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else {
        format!("{:.2} min", ns as f64 / 60e9)
    }
}

/// Counts with M/G suffixes (params, MACs — Table 1 style).
pub fn count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}G", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.2}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

/// Percentage with 2 decimals.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

/// Render a simple aligned table (the report format for Tables 1–4).
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str("| ");
            out.push_str(c);
            out.push_str(&" ".repeat(widths[i].saturating_sub(c.len()) + 1));
        }
        out.push_str("|\n");
    };
    line(
        &mut out,
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    );
    let mut sep = String::new();
    for w in &widths {
        sep.push_str(&format!("|{}", "-".repeat(w + 2)));
    }
    sep.push_str("|\n");
    out.push_str(&sep);
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(dur(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(dur(Duration::from_secs(90)), "1.50 min");
    }

    #[test]
    fn counts() {
        assert_eq!(count(950), "950");
        assert_eq!(count(23_520_000), "23.52M");
        assert_eq!(count(2_850_000_000), "2.85G");
    }

    #[test]
    fn table_renders_aligned() {
        let t = table(
            &["DNN", "acc"],
            &[vec!["resnet".into(), "93.4%".into()]],
        );
        assert!(t.contains("| DNN"));
        assert!(t.contains("| resnet"));
        assert!(t.lines().count() == 3);
    }
}
