//! Micro-benchmark harness (criterion substitute for `cargo bench`).
//!
//! Warms up, then runs timed iterations until both a minimum iteration
//! count and a minimum wall-clock budget are met; reports median, mean,
//! MAD and throughput. Deliberately small: deterministic workloads + a
//! single core mean simple robust statistics beat criterion's resampling.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
    pub total: Duration,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>12} median  {:>12} mean  ±{:>10}  ({} iters)",
            self.name,
            super::fmt::dur(self.median),
            super::fmt::dur(self.mean),
            super::fmt::dur(self.mad),
            self.iters
        );
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 1000,
            min_time: Duration::from_millis(300),
        }
    }
}

impl Config {
    /// Config for expensive end-to-end cases (seconds per iteration).
    pub fn endtoend() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            min_time: Duration::from_millis(500),
        }
    }

    /// Honour `ADAPT_BENCH_FAST=1` for smoke runs (CI / tests).
    pub fn from_env(self) -> Self {
        if std::env::var("ADAPT_BENCH_FAST").as_deref() == Ok("1") {
            Self {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 3,
                min_time: Duration::from_millis(1),
            }
        } else {
            self
        }
    }
}

/// Time `f` under `cfg`; the closure's return value is black-boxed.
pub fn run<T, F: FnMut() -> T>(name: &str, cfg: Config, mut f: F) -> Stats {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
        let done_iters = samples.len() >= cfg.min_iters;
        let done_time = start.elapsed() >= cfg.min_time;
        if (done_iters && done_time) || samples.len() >= cfg.max_iters {
            break;
        }
    }
    let total: Duration = samples.iter().sum();
    let mut sorted = samples.clone();
    sorted.sort_unstable();
    let median = sorted[sorted.len() / 2];
    let mean = total / samples.len() as u32;
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|s| {
            if *s > median {
                *s - median
            } else {
                median - *s
            }
        })
        .collect();
    devs.sort_unstable();
    let mad = devs[devs.len() / 2];
    Stats {
        name: name.to_string(),
        iters: samples.len(),
        median,
        mean,
        mad,
        total,
    }
}

/// Optimizer fence (std::hint::black_box stabilized in 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = Config {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            min_time: Duration::from_millis(1),
        };
        let s = run("spin", cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.median > Duration::ZERO);
        assert!(s.iters >= 3 && s.iters <= 5);
    }

    #[test]
    fn respects_max_iters() {
        let cfg = Config {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: Duration::from_secs(60),
        };
        let s = run("fast", cfg, || 1 + 1);
        assert_eq!(s.iters, 2);
    }
}
