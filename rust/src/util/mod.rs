//! Dependency-free substrates.
//!
//! The build environment vendors only `xla`/`anyhow`-tier crates, so the
//! conveniences a framework normally pulls from crates.io are implemented
//! here: a JSON parser/writer ([`json`]), a CLI argument parser ([`cli`]),
//! deterministic PRNGs ([`rng`]), a scoped threadpool ([`threadpool`] —
//! the OpenMP stand-in of §4.2), a micro-benchmark harness ([`bench`] —
//! the criterion stand-in used by `cargo bench`), and tiny formatting
//! helpers ([`fmt`]).

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod threadpool;
