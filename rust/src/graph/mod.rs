//! The shared model IR + manifest loader + the graph re-transform tool.
//!
//! `python/compile/nn.py` authors each model as a flat SSA graph; `aot.py`
//! writes it verbatim into `artifacts/manifest.json`. This module parses it
//! into typed Rust nodes so the emulators execute *exactly* the graph the
//! XLA artifacts were lowered from.
//!
//! [`retransform`] is the paper's §3.4 "graph re-transform tool": it walks
//! a model and swaps vanilla layers for their approximate equivalents
//! according to a user policy (all layers, a name filter, per-layer
//! bitwidths for mixed precision) producing an [`ExecutionPlan`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Typed IR operation.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv2d {
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        scale_idx: usize,
        name: String,
    },
    Linear {
        din: usize,
        dout: usize,
        scale_idx: usize,
        name: String,
    },
    Lstm {
        din: usize,
        hidden: usize,
        scale_idx: usize,
        scale_idx2: usize,
        name: String,
    },
    Embedding {
        vocab: usize,
        dim: usize,
    },
    Relu,
    Sigmoid,
    Tanh,
    AvgPool2,
    Gap,
    Flatten,
    Add,
    Concat,
    ChannelShuffle {
        groups: usize,
    },
    SliceLast {
        start: usize,
        end: usize,
    },
    Reshape {
        shape: Vec<usize>,
    },
}

impl Op {
    /// Does this node own quantizable GEMMs (i.e. can it be approximated)?
    pub fn is_quantizable(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Linear { .. } | Op::Lstm { .. })
    }

    /// Layer name for quantizable ops (policy filters key on this).
    pub fn layer_name(&self) -> Option<&str> {
        match self {
            Op::Conv2d { name, .. } | Op::Linear { name, .. } | Op::Lstm { name, .. } => {
                Some(name)
            }
            _ => None,
        }
    }
}

/// One IR node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub params: Vec<usize>,
}

/// Parameter spec (positional, shapes as lowered).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A model as described by the manifest.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub paper_row: String,
    pub kind: String,
    pub dataset: String,
    pub input_shape: Vec<usize>,
    pub input_dtype: String,
    pub out_dim: usize,
    pub loss: String,
    pub metric: String,
    pub table2: bool,
    pub n_scales: usize,
    pub params: Vec<ParamSpec>,
    pub params_count: u64,
    pub macs: u64,
    pub nodes: Vec<Node>,
    pub weights_file: String,
    pub artifacts: BTreeMap<String, String>,
}

/// LUT artifact metadata.
#[derive(Clone, Debug)]
pub struct LutMeta {
    pub file: String,
    pub bits: u32,
    pub mae_pct: f64,
    pub mre_pct: f64,
    pub wce: i64,
    pub power: f64,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch: usize,
    pub trunc12_k: u32,
    pub luts: BTreeMap<String, LutMeta>,
    pub models: BTreeMap<String, Model>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let batch = j.get("batch")?.usize()?;
        let trunc12_k = j.get("trunc12_k")?.usize()? as u32;

        let mut luts = BTreeMap::new();
        for (name, lm) in j.get("luts")?.obj()? {
            luts.insert(
                name.clone(),
                LutMeta {
                    file: lm.get("file")?.str()?.to_string(),
                    bits: lm.get("bits")?.usize()? as u32,
                    mae_pct: lm.get("mae_pct")?.f64()?,
                    mre_pct: lm.get("mre_pct")?.f64()?,
                    wce: lm.get("wce")?.i64()?,
                    power: lm.get("power")?.f64()?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, mj)?);
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            batch,
            trunc12_k,
            luts,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&Model> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, model: &str, variant: &str) -> Result<PathBuf> {
        let m = self.model(model)?;
        let rel = m
            .artifacts
            .get(variant)
            .with_context(|| format!("model {model:?} has no variant {variant:?}"))?;
        Ok(self.root.join(rel))
    }

    pub fn lut_path(&self, acu: &str) -> Result<PathBuf> {
        let lm = self
            .luts
            .get(acu)
            .with_context(|| format!("no LUT artifact for ACU {acu:?}"))?;
        Ok(self.root.join(&lm.file))
    }
}

fn parse_model(name: &str, mj: &Json) -> Result<Model> {
    let params = mj
        .get("params")?
        .arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.str()?.to_string(),
                shape: p.get("shape")?.usize_vec()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let mut nodes = Vec::new();
    for nj in mj.get("graph")?.arr()? {
        nodes.push(parse_node(nj).with_context(|| format!("in model {name}"))?);
    }

    let mut artifacts = BTreeMap::new();
    for (k, v) in mj.get("artifacts")?.obj()? {
        artifacts.insert(k.clone(), v.str()?.to_string());
    }

    Ok(Model {
        name: name.to_string(),
        paper_row: mj.get("paper_row")?.str()?.to_string(),
        kind: mj.get("kind")?.str()?.to_string(),
        dataset: mj.get("dataset")?.str()?.to_string(),
        input_shape: mj.get("input_shape")?.usize_vec()?,
        input_dtype: mj.get("input_dtype")?.str()?.to_string(),
        out_dim: mj.get("out_dim")?.usize()?,
        loss: mj.get("loss")?.str()?.to_string(),
        metric: mj.get("metric")?.str()?.to_string(),
        table2: mj.get("table2")?.bool()?,
        n_scales: mj.get("n_scales")?.usize()?,
        params,
        params_count: mj.get("params_count")?.i64()? as u64,
        macs: mj.get("macs")?.i64()? as u64,
        nodes,
        weights_file: mj.get("weights_file")?.str()?.to_string(),
        artifacts,
    })
}

fn parse_node(nj: &Json) -> Result<Node> {
    let id = nj.get("id")?.usize()?;
    let op_name = nj.get("op")?.str()?;
    let at = nj.opt("attrs");
    let ga = |k: &str| -> Result<usize> {
        at.with_context(|| format!("op {op_name} missing attrs"))?
            .get(k)?
            .usize()
    };
    let gs = |k: &str| -> Result<String> {
        Ok(at
            .with_context(|| format!("op {op_name} missing attrs"))?
            .get(k)?
            .str()?
            .to_string())
    };
    let op = match op_name {
        "input" => Op::Input,
        "conv2d" => Op::Conv2d {
            kh: ga("kh")?,
            kw: ga("kw")?,
            cin: ga("cin")?,
            cout: ga("cout")?,
            stride: ga("stride")?,
            pad: ga("pad")?,
            groups: ga("groups")?,
            scale_idx: ga("scale_idx")?,
            name: gs("name")?,
        },
        "linear" => Op::Linear {
            din: ga("din")?,
            dout: ga("dout")?,
            scale_idx: ga("scale_idx")?,
            name: gs("name")?,
        },
        "lstm" => Op::Lstm {
            din: ga("din")?,
            hidden: ga("hidden")?,
            scale_idx: ga("scale_idx")?,
            scale_idx2: ga("scale_idx2")?,
            name: gs("name")?,
        },
        "embedding" => Op::Embedding {
            vocab: ga("vocab")?,
            dim: ga("dim")?,
        },
        "relu" => Op::Relu,
        "sigmoid" => Op::Sigmoid,
        "tanh" => Op::Tanh,
        "avgpool2" => Op::AvgPool2,
        "gap" => Op::Gap,
        "flatten" => Op::Flatten,
        "add" => Op::Add,
        "concat" => Op::Concat,
        "channel_shuffle" => Op::ChannelShuffle {
            groups: ga("groups")?,
        },
        "slice_last" => Op::SliceLast {
            start: ga("start")?,
            end: ga("end")?,
        },
        "reshape" => Op::Reshape {
            shape: at
                .with_context(|| "reshape missing attrs")?
                .get("shape")?
                .usize_vec()?,
        },
        other => bail!("unknown op {other:?}"),
    };
    let inputs = nj
        .get("inputs")?
        .arr()?
        .iter()
        .map(|v| v.usize())
        .collect::<Result<Vec<_>>>()?;
    let params = match nj.opt("params") {
        Some(p) => p.arr()?.iter().map(|v| v.usize()).collect::<Result<Vec<_>>>()?,
        None => vec![],
    };
    Ok(Node {
        id,
        op,
        inputs,
        params,
    })
}

// ---------------------------------------------------------------------------
// Re-transform tool (§3.4)
// ---------------------------------------------------------------------------

/// How one quantizable layer executes. Each approximated layer carries its
/// own ACU identity, so a single plan can mix accelerators per layer
/// (MAx-DNN-style heterogeneous assignment).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerMode {
    /// Vanilla fp32 layer (approximation disabled).
    Fp32,
    /// Quantize + route products through the named LUT ACU (8-bit family).
    ApproxLut { acu: String },
    /// Quantize + functional ACU at `bits` with output truncation `k`
    /// (the large-bitwidth fallback; k = 0 means exact-quantized).
    ApproxFunc { bits: u32, trunc_k: u32 },
}

impl LayerMode {
    /// LUT mode for a named ACU.
    pub fn lut(acu: impl Into<String>) -> LayerMode {
        LayerMode::ApproxLut { acu: acu.into() }
    }

    /// Parse the CLI/plan-file spelling: `fp32`, `func:<bits>:<trunc_k>`,
    /// or a LUT ACU name (e.g. `mul8s_1l2h_like`).
    pub fn parse(s: &str) -> Result<LayerMode> {
        if s.eq_ignore_ascii_case("fp32") {
            return Ok(LayerMode::Fp32);
        }
        if let Some(rest) = s.strip_prefix("func:") {
            let (bits, k) = rest
                .split_once(':')
                .with_context(|| format!("bad func mode {s:?} (want func:<bits>:<k>)"))?;
            return Ok(LayerMode::ApproxFunc {
                bits: bits.parse().with_context(|| format!("bad bits in {s:?}"))?,
                trunc_k: k.parse().with_context(|| format!("bad trunc_k in {s:?}"))?,
            });
        }
        Ok(LayerMode::lut(s))
    }

    /// Compact human/JSON-free label (inverse of [`LayerMode::parse`]).
    pub fn label(&self) -> String {
        match self {
            LayerMode::Fp32 => "fp32".to_string(),
            LayerMode::ApproxLut { acu } => acu.clone(),
            LayerMode::ApproxFunc { bits, trunc_k } => format!("func:{bits}:{trunc_k}"),
        }
    }
}

/// Plan-JSON schema generation this build writes. Readers tolerate newer
/// schemas: unknown per-layer keys are preserved, unknown top-level keys
/// ignored. Bumped to 2 when the `compensation` block was added.
pub const PLAN_SCHEMA: u32 = 2;

/// Output-channel count of a quantizable node, for per-channel
/// compensation sizing. `None` for LSTM (gate-structured outputs — the
/// per-channel correction model does not apply).
fn node_out_channels(node: &Node) -> Option<usize> {
    match &node.op {
        Op::Conv2d { cout, .. } => Some(*cout),
        Op::Linear { dout, .. } => Some(*dout),
        _ => None,
    }
}

/// Calibrated additive error-correction terms for one approximated layer
/// (Zervakis-style control-variate compensation): the executor folds
/// `constant + channels[n]` into output channel `n`'s bias at prepare
/// time, so a compensated plan costs nothing extra on the GEMM hot path.
#[derive(Clone, Debug, PartialEq)]
pub struct Compensation {
    /// Constant correction added to every output channel.
    pub constant: f32,
    /// Per-output-channel residuals (empty = constant-only; otherwise one
    /// entry per output channel, added on top of `constant`).
    pub channels: Vec<f32>,
}

impl Compensation {
    /// The effective correction for output channel `n`.
    pub fn term(&self, n: usize) -> f32 {
        self.constant + self.channels.get(n).copied().unwrap_or(0.0)
    }

    /// Is this a no-op correction (identical execution to no block at all)?
    pub fn is_zero(&self) -> bool {
        self.constant == 0.0 && self.channels.iter().all(|&c| c == 0.0)
    }
}

/// Per-layer execution assignment produced by [`retransform`] (or loaded
/// from a plan JSON) — the first-class mixed-precision artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutionPlan {
    /// node id -> mode for every quantizable node.
    pub modes: BTreeMap<usize, LayerMode>,
    /// node id -> calibrated error compensation (approximated layers only;
    /// nodes without an entry run uncompensated).
    pub compensation: BTreeMap<usize, Compensation>,
    /// node id -> per-layer JSON keys this build does not understand,
    /// preserved verbatim through a parse → serialize round-trip so newer
    /// plans survive older tooling (forward compatibility).
    pub extras: BTreeMap<usize, BTreeMap<String, Json>>,
}

impl ExecutionPlan {
    /// A plan from bare mode assignments (no compensation, no extras).
    pub fn from_modes(modes: BTreeMap<usize, LayerMode>) -> ExecutionPlan {
        ExecutionPlan {
            modes,
            compensation: BTreeMap::new(),
            extras: BTreeMap::new(),
        }
    }
    /// Distinct LUT ACU names this plan needs (for registry preloading).
    pub fn acus(&self) -> Vec<String> {
        let mut set = std::collections::BTreeSet::new();
        for mode in self.modes.values() {
            if let LayerMode::ApproxLut { acu } = mode {
                set.insert(acu.clone());
            }
        }
        set.into_iter().collect()
    }

    /// Mode for one node (Fp32 for nodes the plan does not cover).
    pub fn mode_of(&self, node_id: usize) -> LayerMode {
        self.modes
            .get(&node_id)
            .cloned()
            .unwrap_or(LayerMode::Fp32)
    }

    /// Serialize as a plan JSON document:
    ///
    /// ```json
    /// {"model": "small_vgg", "version": 1, "layers": [
    ///   {"node": 1, "name": "c1", "mode": "lut", "acu": "exact8"},
    ///   {"node": 5, "name": "fc", "mode": "fp32"}]}
    /// ```
    pub fn to_json(&self, model: &Model) -> String {
        self.to_json_with(model, None)
    }

    /// [`to_json`](Self::to_json) with an optional `provenance` string
    /// recording which search produced the plan (e.g. `"greedy"`,
    /// `"mcts:<seed>/<budget>"`). Readers that predate the field ignore
    /// unknown top-level keys, so the document stays backward-compatible.
    pub fn to_json_with(&self, model: &Model, provenance: Option<&str>) -> String {
        let mut layers = Vec::new();
        for node in &model.nodes {
            let Some(mode) = self.modes.get(&node.id) else {
                continue;
            };
            let mut entry = BTreeMap::new();
            entry.insert("node".to_string(), Json::Num(node.id as f64));
            if let Some(name) = node.op.layer_name() {
                entry.insert("name".to_string(), Json::Str(name.to_string()));
            }
            match mode {
                LayerMode::Fp32 => {
                    entry.insert("mode".to_string(), Json::Str("fp32".into()));
                }
                LayerMode::ApproxLut { acu } => {
                    entry.insert("mode".to_string(), Json::Str("lut".into()));
                    entry.insert("acu".to_string(), Json::Str(acu.clone()));
                }
                LayerMode::ApproxFunc { bits, trunc_k } => {
                    entry.insert("mode".to_string(), Json::Str("func".into()));
                    entry.insert("bits".to_string(), Json::Num(*bits as f64));
                    entry.insert("trunc_k".to_string(), Json::Num(*trunc_k as f64));
                }
            }
            if let Some(comp) = self.compensation.get(&node.id) {
                let mut c = BTreeMap::new();
                c.insert("constant".to_string(), Json::Num(comp.constant as f64));
                c.insert("channels".to_string(), Json::from_f32s(&comp.channels));
                entry.insert("compensation".to_string(), Json::Obj(c));
            }
            if let Some(extra) = self.extras.get(&node.id) {
                for (k, v) in extra {
                    entry.entry(k.clone()).or_insert_with(|| v.clone());
                }
            }
            layers.push(Json::Obj(entry));
        }
        let mut doc = BTreeMap::new();
        doc.insert("model".to_string(), Json::Str(model.name.clone()));
        doc.insert("version".to_string(), Json::Num(1.0));
        doc.insert("schema".to_string(), Json::Num(PLAN_SCHEMA as f64));
        if let Some(p) = provenance {
            if !p.trim().is_empty() {
                doc.insert("provenance".to_string(), Json::Str(p.to_string()));
            }
        }
        doc.insert("layers".to_string(), Json::Arr(layers));
        Json::Obj(doc).to_string()
    }

    /// Provenance string of a plan JSON document, if it carries one
    /// (trimmed, capped at 80 chars so stored `PlanStore` source tags
    /// stay bounded).
    pub fn provenance_of(text: &str) -> Option<String> {
        let j = Json::parse(text).ok()?;
        let p = j.opt("provenance")?.str().ok()?.trim().to_string();
        if p.is_empty() {
            return None;
        }
        Some(p.chars().take(80).collect())
    }

    /// Parse a plan JSON document against `model`, validating that every
    /// referenced node exists and is quantizable and that the plan covers
    /// every quantizable node. Per-layer keys this build does not know are
    /// preserved in [`extras`](Self::extras) (and re-emitted by
    /// [`to_json_with`](Self::to_json_with)) rather than rejected, so plans
    /// written by newer schemas still load.
    pub fn from_json(text: &str, model: &Model) -> Result<ExecutionPlan> {
        let j = Json::parse(text).context("parsing plan JSON")?;
        if let Some(m) = j.opt("model") {
            let name = m.str()?;
            if name != model.name {
                bail!("plan was written for model {name:?}, not {:?}", model.name);
            }
        }
        let mut modes = BTreeMap::new();
        let mut compensation = BTreeMap::new();
        let mut extras: BTreeMap<usize, BTreeMap<String, Json>> = BTreeMap::new();
        for entry in j.get("layers")?.arr()? {
            let id = entry.get("node")?.usize()?;
            let node = model
                .nodes
                .iter()
                .find(|n| n.id == id)
                .with_context(|| format!("plan references unknown node {id}"))?;
            if !node.op.is_quantizable() {
                bail!("plan assigns a mode to non-quantizable node {id}");
            }
            if let Some(name) = entry.opt("name") {
                let name = name.str()?;
                if node.op.layer_name() != Some(name) {
                    bail!(
                        "plan node {id} is named {name:?} but the model calls it {:?}",
                        node.op.layer_name().unwrap_or("<unnamed>")
                    );
                }
            }
            let mode = match entry.get("mode")?.str()? {
                "fp32" => LayerMode::Fp32,
                "lut" => LayerMode::lut(entry.get("acu")?.str()?),
                "func" => LayerMode::ApproxFunc {
                    bits: entry.get("bits")?.usize()? as u32,
                    trunc_k: entry.get("trunc_k")?.usize()? as u32,
                },
                other => bail!("unknown plan mode {other:?} for node {id}"),
            };
            if let Some(cj) = entry.opt("compensation") {
                let comp = Compensation {
                    constant: cj.get("constant")?.f64()? as f32,
                    channels: match cj.opt("channels") {
                        Some(ch) => ch.f32_vec()?,
                        None => vec![],
                    },
                };
                if matches!(mode, LayerMode::Fp32) {
                    bail!("plan node {id} carries compensation but runs fp32");
                }
                let cout = node_out_channels(node);
                match cout {
                    None => bail!(
                        "plan node {id} ({:?}) does not support compensation",
                        node.op.layer_name().unwrap_or("<unnamed>")
                    ),
                    Some(cout) => {
                        if !comp.channels.is_empty() && comp.channels.len() != cout {
                            bail!(
                                "plan node {id} compensation has {} channel terms, \
                                 layer has {cout} output channels",
                                comp.channels.len()
                            );
                        }
                    }
                }
                compensation.insert(id, comp);
            }
            let known = [
                "node",
                "name",
                "mode",
                "acu",
                "bits",
                "trunc_k",
                "compensation",
            ];
            let mut extra = BTreeMap::new();
            for (k, v) in entry.obj()? {
                if !known.contains(&k.as_str()) {
                    extra.insert(k.clone(), v.clone());
                }
            }
            if !extra.is_empty() {
                extras.insert(id, extra);
            }
            if modes.insert(id, mode).is_some() {
                bail!("plan assigns node {id} twice");
            }
        }
        for node in &model.nodes {
            if node.op.is_quantizable() && !modes.contains_key(&node.id) {
                bail!(
                    "plan misses quantizable node {} ({:?})",
                    node.id,
                    node.op.layer_name().unwrap_or("<unnamed>")
                );
            }
        }
        Ok(ExecutionPlan {
            modes,
            compensation,
            extras,
        })
    }

    /// One line per layer (reports / `adapt plan`).
    pub fn describe(&self, model: &Model) -> String {
        let mut out = String::new();
        for node in &model.nodes {
            if let Some(mode) = self.modes.get(&node.id) {
                let comp = match self.compensation.get(&node.id) {
                    Some(c) if !c.channels.is_empty() => {
                        format!("  [comp: const + {}ch]", c.channels.len())
                    }
                    Some(_) => "  [comp: const]".to_string(),
                    None => String::new(),
                };
                out.push_str(&format!(
                    "  node {:>3}  {:<24} {}{comp}\n",
                    node.id,
                    node.op.layer_name().unwrap_or("<unnamed>"),
                    mode.label()
                ));
            }
        }
        out
    }
}

/// Layer-selection policy — the "easily enabled or disabled for the layers
/// of the model" knob. Mixed precision = different modes (and different
/// ACUs) per layer name.
#[derive(Clone, Debug, Default)]
pub struct Policy {
    /// Default mode for quantizable layers not matched below.
    pub default_mode: Option<LayerMode>,
    /// Exact-name overrides (e.g. keep the classifier head fp32).
    pub overrides: BTreeMap<String, LayerMode>,
}

impl Policy {
    pub fn all(mode: LayerMode) -> Policy {
        Policy {
            default_mode: Some(mode),
            overrides: BTreeMap::new(),
        }
    }

    pub fn with_override(mut self, layer: &str, mode: LayerMode) -> Policy {
        self.overrides.insert(layer.to_string(), mode);
        self
    }

    /// Assign a specific LUT ACU to one layer by name.
    pub fn with_acu(self, layer: &str, acu: &str) -> Policy {
        self.with_override(layer, LayerMode::lut(acu))
    }

    /// Override keys that name no quantizable layer of `model` — the typo
    /// guard for user-authored specs. `retransform` silently skips
    /// unmatched names (a policy may be shared across models), so
    /// user-facing paths should check this and error loudly.
    pub fn unmatched_overrides(&self, model: &Model) -> Vec<String> {
        let names: std::collections::BTreeSet<&str> = model
            .nodes
            .iter()
            .filter_map(|n| n.op.layer_name())
            .collect();
        self.overrides
            .keys()
            .filter(|k| !names.contains(k.as_str()))
            .cloned()
            .collect()
    }

    /// Parse a CLI spec: comma-separated `key=mode` pairs where `key` is a
    /// layer name or the word `default`, and `mode` follows
    /// [`LayerMode::parse`]. Example:
    /// `default=mul8s_1l2h_like,conv1=exact8,fc=fp32,head=func:12:4`.
    pub fn parse_spec(spec: &str) -> Result<Policy> {
        let mut policy = Policy::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, val) = part
                .split_once('=')
                .with_context(|| format!("bad policy entry {part:?} (want key=mode)"))?;
            let mode = LayerMode::parse(val.trim())?;
            if key.trim() == "default" {
                policy.default_mode = Some(mode);
            } else {
                policy.overrides.insert(key.trim().to_string(), mode);
            }
        }
        Ok(policy)
    }
}

/// Walk the model and assign each quantizable node its execution mode —
/// the recursive search-and-replace of the paper's re-transform tool.
pub fn retransform(model: &Model, policy: &Policy) -> ExecutionPlan {
    let mut modes = BTreeMap::new();
    for node in &model.nodes {
        if !node.op.is_quantizable() {
            continue;
        }
        let name = node.op.layer_name().unwrap_or_default();
        let mode = policy
            .overrides
            .get(name)
            .or(policy.default_mode.as_ref())
            .cloned()
            .unwrap_or(LayerMode::Fp32);
        modes.insert(node.id, mode);
    }
    ExecutionPlan::from_modes(modes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Model {
        Model {
            name: "t".into(),
            paper_row: "t".into(),
            kind: "cnn".into(),
            dataset: "d".into(),
            input_shape: vec![4, 4, 1],
            input_dtype: "f32".into(),
            out_dim: 2,
            loss: "ce".into(),
            metric: "top1".into(),
            table2: false,
            n_scales: 2,
            params: vec![],
            params_count: 0,
            macs: 0,
            nodes: vec![
                Node {
                    id: 0,
                    op: Op::Input,
                    inputs: vec![],
                    params: vec![],
                },
                Node {
                    id: 1,
                    op: Op::Conv2d {
                        kh: 3,
                        kw: 3,
                        cin: 1,
                        cout: 4,
                        stride: 1,
                        pad: 1,
                        groups: 1,
                        scale_idx: 0,
                        name: "c1".into(),
                    },
                    inputs: vec![0],
                    params: vec![0, 1],
                },
                Node {
                    id: 2,
                    op: Op::Linear {
                        din: 64,
                        dout: 2,
                        scale_idx: 1,
                        name: "fc".into(),
                    },
                    inputs: vec![1],
                    params: vec![2, 3],
                },
            ],
            weights_file: String::new(),
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn retransform_all_layers() {
        let m = tiny_model();
        let plan = retransform(&m, &Policy::all(LayerMode::lut("exact8")));
        assert_eq!(plan.modes.len(), 2);
        assert!(plan
            .modes
            .values()
            .all(|m| *m == LayerMode::lut("exact8")));
        assert_eq!(plan.acus(), vec!["exact8".to_string()]);
    }

    #[test]
    fn retransform_override_keeps_head_exact() {
        let m = tiny_model();
        let plan = retransform(
            &m,
            &Policy::all(LayerMode::lut("exact8")).with_override("fc", LayerMode::Fp32),
        );
        assert_eq!(plan.modes[&1], LayerMode::lut("exact8"));
        assert_eq!(plan.modes[&2], LayerMode::Fp32);
    }

    #[test]
    fn retransform_per_layer_acus() {
        // Heterogeneous assignment: each layer gets its own ACU.
        let m = tiny_model();
        let plan = retransform(
            &m,
            &Policy::all(LayerMode::lut("mul8s_1l2h_like"))
                .with_acu("c1", "drum8_4")
                .with_override("fc", LayerMode::ApproxFunc { bits: 12, trunc_k: 4 }),
        );
        assert_eq!(plan.modes[&1], LayerMode::lut("drum8_4"));
        assert_eq!(
            plan.modes[&2],
            LayerMode::ApproxFunc { bits: 12, trunc_k: 4 }
        );
        assert_eq!(plan.acus(), vec!["drum8_4".to_string()]);
    }

    #[test]
    fn default_policy_is_fp32() {
        let m = tiny_model();
        let plan = retransform(&m, &Policy::default());
        assert!(plan.modes.values().all(|m| *m == LayerMode::Fp32));
    }

    #[test]
    fn layer_mode_parse_roundtrip() {
        for mode in [
            LayerMode::Fp32,
            LayerMode::lut("mitchell8"),
            LayerMode::ApproxFunc { bits: 12, trunc_k: 4 },
        ] {
            assert_eq!(LayerMode::parse(&mode.label()).unwrap(), mode);
        }
        assert!(LayerMode::parse("func:12").is_err());
    }

    #[test]
    fn policy_spec_parsing() {
        let p = Policy::parse_spec("default=mul8s_1l2h_like,c1=exact8,fc=fp32").unwrap();
        assert_eq!(p.default_mode, Some(LayerMode::lut("mul8s_1l2h_like")));
        assert_eq!(p.overrides["c1"], LayerMode::lut("exact8"));
        assert_eq!(p.overrides["fc"], LayerMode::Fp32);
        assert!(Policy::parse_spec("no-equals-sign").is_err());
    }

    #[test]
    fn unmatched_overrides_are_reported() {
        let m = tiny_model();
        let p = Policy::parse_spec("default=exact8,c1=drum8_4,classifier=fp32").unwrap();
        assert_eq!(p.unmatched_overrides(&m), vec!["classifier".to_string()]);
        let ok = Policy::parse_spec("c1=exact8,fc=fp32").unwrap();
        assert!(ok.unmatched_overrides(&m).is_empty());
    }

    #[test]
    fn plan_json_roundtrip() {
        let m = tiny_model();
        let plan = retransform(
            &m,
            &Policy::all(LayerMode::lut("mul8s_1l2h_like"))
                .with_acu("c1", "drum8_4")
                .with_override("fc", LayerMode::ApproxFunc { bits: 12, trunc_k: 4 }),
        );
        let text = plan.to_json(&m);
        let re = ExecutionPlan::from_json(&text, &m).unwrap();
        assert_eq!(re, plan);
    }

    #[test]
    fn plan_json_compensation_and_extras_roundtrip() {
        let m = tiny_model();
        let mut plan = retransform(&m, &Policy::all(LayerMode::lut("mitchell8")));
        plan.compensation.insert(
            1,
            Compensation {
                constant: 0.125,
                channels: vec![0.5, -0.25, 0.0, 1.0e-3],
            },
        );
        let text = plan.to_json(&m);
        assert!(text.contains("\"schema\":2"), "missing schema field: {text}");
        let re = ExecutionPlan::from_json(&text, &m).unwrap();
        assert_eq!(re, plan);
        // Byte-level stability: serialize(parse(s)) == s.
        assert_eq!(re.to_json(&m), text);

        // Unknown per-layer keys from a future schema survive the
        // parse -> serialize round trip instead of erroring.
        let future = r#"{"layers": [
            {"node": 1, "mode": "lut", "acu": "exact8", "robustness": {"pgd": 0.5}},
            {"node": 2, "mode": "fp32"}]}"#;
        let p = ExecutionPlan::from_json(future, &m).unwrap();
        let text2 = p.to_json(&m);
        assert!(text2.contains("\"robustness\""), "extra key dropped: {text2}");
        assert_eq!(ExecutionPlan::from_json(&text2, &m).unwrap(), p);
    }

    #[test]
    fn plan_json_compensation_validation() {
        let m = tiny_model();
        // Compensation on an fp32 layer is rejected.
        let bad = r#"{"layers": [
            {"node": 1, "mode": "fp32", "compensation": {"constant": 0.1}},
            {"node": 2, "mode": "fp32"}]}"#;
        assert!(ExecutionPlan::from_json(bad, &m).is_err());
        // Channel-count mismatch is rejected (c1 has cout = 4).
        let bad = r#"{"layers": [
            {"node": 1, "mode": "lut", "acu": "exact8",
             "compensation": {"constant": 0.0, "channels": [1.0, 2.0]}},
            {"node": 2, "mode": "fp32"}]}"#;
        assert!(ExecutionPlan::from_json(bad, &m).is_err());
        // Constant-only compensation (no channels key) parses fine.
        let ok = r#"{"layers": [
            {"node": 1, "mode": "lut", "acu": "exact8",
             "compensation": {"constant": 0.25}},
            {"node": 2, "mode": "fp32"}]}"#;
        let p = ExecutionPlan::from_json(ok, &m).unwrap();
        assert_eq!(p.compensation[&1].constant, 0.25);
        assert!(p.compensation[&1].channels.is_empty());
    }

    #[test]
    fn plan_json_validation() {
        let m = tiny_model();
        // Unknown node id.
        let bad = r#"{"layers": [{"node": 99, "mode": "fp32"}]}"#;
        assert!(ExecutionPlan::from_json(bad, &m).is_err());
        // Missing coverage of node 2.
        let partial = r#"{"layers": [{"node": 1, "mode": "lut", "acu": "exact8"}]}"#;
        assert!(ExecutionPlan::from_json(partial, &m).is_err());
        // Wrong model name.
        let wrong = r#"{"model": "other", "layers": []}"#;
        assert!(ExecutionPlan::from_json(wrong, &m).is_err());
        // Name mismatch on a node.
        let misnamed = r#"{"layers": [
            {"node": 1, "name": "nope", "mode": "fp32"},
            {"node": 2, "mode": "fp32"}]}"#;
        assert!(ExecutionPlan::from_json(misnamed, &m).is_err());
    }
}
