//! The shared model IR + manifest loader + the graph re-transform tool.
//!
//! `python/compile/nn.py` authors each model as a flat SSA graph; `aot.py`
//! writes it verbatim into `artifacts/manifest.json`. This module parses it
//! into typed Rust nodes so the emulators execute *exactly* the graph the
//! XLA artifacts were lowered from.
//!
//! [`retransform`] is the paper's §3.4 "graph re-transform tool": it walks
//! a model and swaps vanilla layers for their approximate equivalents
//! according to a user policy (all layers, a name filter, per-layer
//! bitwidths for mixed precision) producing an [`ExecutionPlan`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Typed IR operation.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    Input,
    Conv2d {
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        scale_idx: usize,
        name: String,
    },
    Linear {
        din: usize,
        dout: usize,
        scale_idx: usize,
        name: String,
    },
    Lstm {
        din: usize,
        hidden: usize,
        scale_idx: usize,
        scale_idx2: usize,
        name: String,
    },
    Embedding {
        vocab: usize,
        dim: usize,
    },
    Relu,
    Sigmoid,
    Tanh,
    AvgPool2,
    Gap,
    Flatten,
    Add,
    Concat,
    ChannelShuffle {
        groups: usize,
    },
    SliceLast {
        start: usize,
        end: usize,
    },
    Reshape {
        shape: Vec<usize>,
    },
}

impl Op {
    /// Does this node own quantizable GEMMs (i.e. can it be approximated)?
    pub fn is_quantizable(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Linear { .. } | Op::Lstm { .. })
    }

    /// Layer name for quantizable ops (policy filters key on this).
    pub fn layer_name(&self) -> Option<&str> {
        match self {
            Op::Conv2d { name, .. } | Op::Linear { name, .. } | Op::Lstm { name, .. } => {
                Some(name)
            }
            _ => None,
        }
    }
}

/// One IR node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub params: Vec<usize>,
}

/// Parameter spec (positional, shapes as lowered).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A model as described by the manifest.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub paper_row: String,
    pub kind: String,
    pub dataset: String,
    pub input_shape: Vec<usize>,
    pub input_dtype: String,
    pub out_dim: usize,
    pub loss: String,
    pub metric: String,
    pub table2: bool,
    pub n_scales: usize,
    pub params: Vec<ParamSpec>,
    pub params_count: u64,
    pub macs: u64,
    pub nodes: Vec<Node>,
    pub weights_file: String,
    pub artifacts: BTreeMap<String, String>,
}

/// LUT artifact metadata.
#[derive(Clone, Debug)]
pub struct LutMeta {
    pub file: String,
    pub bits: u32,
    pub mae_pct: f64,
    pub mre_pct: f64,
    pub wce: i64,
    pub power: f64,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch: usize,
    pub trunc12_k: u32,
    pub luts: BTreeMap<String, LutMeta>,
    pub models: BTreeMap<String, Model>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let batch = j.get("batch")?.usize()?;
        let trunc12_k = j.get("trunc12_k")?.usize()? as u32;

        let mut luts = BTreeMap::new();
        for (name, lm) in j.get("luts")?.obj()? {
            luts.insert(
                name.clone(),
                LutMeta {
                    file: lm.get("file")?.str()?.to_string(),
                    bits: lm.get("bits")?.usize()? as u32,
                    mae_pct: lm.get("mae_pct")?.f64()?,
                    mre_pct: lm.get("mre_pct")?.f64()?,
                    wce: lm.get("wce")?.i64()?,
                    power: lm.get("power")?.f64()?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, mj)?);
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            batch,
            trunc12_k,
            luts,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&Model> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, model: &str, variant: &str) -> Result<PathBuf> {
        let m = self.model(model)?;
        let rel = m
            .artifacts
            .get(variant)
            .with_context(|| format!("model {model:?} has no variant {variant:?}"))?;
        Ok(self.root.join(rel))
    }

    pub fn lut_path(&self, acu: &str) -> Result<PathBuf> {
        let lm = self
            .luts
            .get(acu)
            .with_context(|| format!("no LUT artifact for ACU {acu:?}"))?;
        Ok(self.root.join(&lm.file))
    }
}

fn parse_model(name: &str, mj: &Json) -> Result<Model> {
    let params = mj
        .get("params")?
        .arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.str()?.to_string(),
                shape: p.get("shape")?.usize_vec()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let mut nodes = Vec::new();
    for nj in mj.get("graph")?.arr()? {
        nodes.push(parse_node(nj).with_context(|| format!("in model {name}"))?);
    }

    let mut artifacts = BTreeMap::new();
    for (k, v) in mj.get("artifacts")?.obj()? {
        artifacts.insert(k.clone(), v.str()?.to_string());
    }

    Ok(Model {
        name: name.to_string(),
        paper_row: mj.get("paper_row")?.str()?.to_string(),
        kind: mj.get("kind")?.str()?.to_string(),
        dataset: mj.get("dataset")?.str()?.to_string(),
        input_shape: mj.get("input_shape")?.usize_vec()?,
        input_dtype: mj.get("input_dtype")?.str()?.to_string(),
        out_dim: mj.get("out_dim")?.usize()?,
        loss: mj.get("loss")?.str()?.to_string(),
        metric: mj.get("metric")?.str()?.to_string(),
        table2: mj.get("table2")?.bool()?,
        n_scales: mj.get("n_scales")?.usize()?,
        params,
        params_count: mj.get("params_count")?.i64()? as u64,
        macs: mj.get("macs")?.i64()? as u64,
        nodes,
        weights_file: mj.get("weights_file")?.str()?.to_string(),
        artifacts,
    })
}

fn parse_node(nj: &Json) -> Result<Node> {
    let id = nj.get("id")?.usize()?;
    let op_name = nj.get("op")?.str()?;
    let at = nj.opt("attrs");
    let ga = |k: &str| -> Result<usize> {
        at.with_context(|| format!("op {op_name} missing attrs"))?
            .get(k)?
            .usize()
    };
    let gs = |k: &str| -> Result<String> {
        Ok(at
            .with_context(|| format!("op {op_name} missing attrs"))?
            .get(k)?
            .str()?
            .to_string())
    };
    let op = match op_name {
        "input" => Op::Input,
        "conv2d" => Op::Conv2d {
            kh: ga("kh")?,
            kw: ga("kw")?,
            cin: ga("cin")?,
            cout: ga("cout")?,
            stride: ga("stride")?,
            pad: ga("pad")?,
            groups: ga("groups")?,
            scale_idx: ga("scale_idx")?,
            name: gs("name")?,
        },
        "linear" => Op::Linear {
            din: ga("din")?,
            dout: ga("dout")?,
            scale_idx: ga("scale_idx")?,
            name: gs("name")?,
        },
        "lstm" => Op::Lstm {
            din: ga("din")?,
            hidden: ga("hidden")?,
            scale_idx: ga("scale_idx")?,
            scale_idx2: ga("scale_idx2")?,
            name: gs("name")?,
        },
        "embedding" => Op::Embedding {
            vocab: ga("vocab")?,
            dim: ga("dim")?,
        },
        "relu" => Op::Relu,
        "sigmoid" => Op::Sigmoid,
        "tanh" => Op::Tanh,
        "avgpool2" => Op::AvgPool2,
        "gap" => Op::Gap,
        "flatten" => Op::Flatten,
        "add" => Op::Add,
        "concat" => Op::Concat,
        "channel_shuffle" => Op::ChannelShuffle {
            groups: ga("groups")?,
        },
        "slice_last" => Op::SliceLast {
            start: ga("start")?,
            end: ga("end")?,
        },
        "reshape" => Op::Reshape {
            shape: at
                .with_context(|| "reshape missing attrs")?
                .get("shape")?
                .usize_vec()?,
        },
        other => bail!("unknown op {other:?}"),
    };
    let inputs = nj
        .get("inputs")?
        .arr()?
        .iter()
        .map(|v| v.usize())
        .collect::<Result<Vec<_>>>()?;
    let params = match nj.opt("params") {
        Some(p) => p.arr()?.iter().map(|v| v.usize()).collect::<Result<Vec<_>>>()?,
        None => vec![],
    };
    Ok(Node {
        id,
        op,
        inputs,
        params,
    })
}

// ---------------------------------------------------------------------------
// Re-transform tool (§3.4)
// ---------------------------------------------------------------------------

/// How one quantizable layer executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerMode {
    /// Vanilla fp32 layer (approximation disabled).
    Fp32,
    /// Quantize + route products through the named LUT ACU (8-bit family).
    ApproxLut,
    /// Quantize + functional ACU at `bits` with output truncation `k`
    /// (the large-bitwidth fallback; k = 0 means exact-quantized).
    ApproxFunc { bits: u32, trunc_k: u32 },
}

/// Per-layer execution assignment produced by [`retransform`].
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    /// node id -> mode for every quantizable node.
    pub modes: BTreeMap<usize, LayerMode>,
}

/// Layer-selection policy — the "easily enabled or disabled for the layers
/// of the model" knob. Mixed precision = different modes per name.
#[derive(Clone, Debug, Default)]
pub struct Policy {
    /// Default mode for quantizable layers not matched below.
    pub default_mode: Option<LayerMode>,
    /// Exact-name overrides (e.g. keep the classifier head fp32).
    pub overrides: BTreeMap<String, LayerMode>,
}

impl Policy {
    pub fn all(mode: LayerMode) -> Policy {
        Policy {
            default_mode: Some(mode),
            overrides: BTreeMap::new(),
        }
    }

    pub fn with_override(mut self, layer: &str, mode: LayerMode) -> Policy {
        self.overrides.insert(layer.to_string(), mode);
        self
    }
}

/// Walk the model and assign each quantizable node its execution mode —
/// the recursive search-and-replace of the paper's re-transform tool.
pub fn retransform(model: &Model, policy: &Policy) -> ExecutionPlan {
    let mut modes = BTreeMap::new();
    for node in &model.nodes {
        if !node.op.is_quantizable() {
            continue;
        }
        let name = node.op.layer_name().unwrap_or_default();
        let mode = policy
            .overrides
            .get(name)
            .copied()
            .or(policy.default_mode)
            .unwrap_or(LayerMode::Fp32);
        modes.insert(node.id, mode);
    }
    ExecutionPlan { modes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Model {
        Model {
            name: "t".into(),
            paper_row: "t".into(),
            kind: "cnn".into(),
            dataset: "d".into(),
            input_shape: vec![4, 4, 1],
            input_dtype: "f32".into(),
            out_dim: 2,
            loss: "ce".into(),
            metric: "top1".into(),
            table2: false,
            n_scales: 2,
            params: vec![],
            params_count: 0,
            macs: 0,
            nodes: vec![
                Node {
                    id: 0,
                    op: Op::Input,
                    inputs: vec![],
                    params: vec![],
                },
                Node {
                    id: 1,
                    op: Op::Conv2d {
                        kh: 3,
                        kw: 3,
                        cin: 1,
                        cout: 4,
                        stride: 1,
                        pad: 1,
                        groups: 1,
                        scale_idx: 0,
                        name: "c1".into(),
                    },
                    inputs: vec![0],
                    params: vec![0, 1],
                },
                Node {
                    id: 2,
                    op: Op::Linear {
                        din: 64,
                        dout: 2,
                        scale_idx: 1,
                        name: "fc".into(),
                    },
                    inputs: vec![1],
                    params: vec![2, 3],
                },
            ],
            weights_file: String::new(),
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn retransform_all_layers() {
        let m = tiny_model();
        let plan = retransform(&m, &Policy::all(LayerMode::ApproxLut));
        assert_eq!(plan.modes.len(), 2);
        assert!(plan.modes.values().all(|m| *m == LayerMode::ApproxLut));
    }

    #[test]
    fn retransform_override_keeps_head_exact() {
        let m = tiny_model();
        let plan = retransform(
            &m,
            &Policy::all(LayerMode::ApproxLut).with_override("fc", LayerMode::Fp32),
        );
        assert_eq!(plan.modes[&1], LayerMode::ApproxLut);
        assert_eq!(plan.modes[&2], LayerMode::Fp32);
    }

    #[test]
    fn default_policy_is_fp32() {
        let m = tiny_model();
        let plan = retransform(&m, &Policy::default());
        assert!(plan.modes.values().all(|m| *m == LayerMode::Fp32));
    }
}
