//! Training losses + their gradients (mirrors `python/compile/train.py`'s
//! `loss_value` exactly, including the VAE clip behavior).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Trainable loss families (the manifest's `loss` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// Softmax cross-entropy over logits (classifiers): `-mean(logp[y])`.
    CrossEntropy,
    /// Mean binary cross-entropy of the reconstruction vs the (clipped)
    /// input (the deterministic-AE objective of the VAE models).
    Vae,
}

impl LossKind {
    pub fn parse(loss: &str) -> Result<LossKind> {
        match loss {
            "ce" => Ok(LossKind::CrossEntropy),
            "vae" => Ok(LossKind::Vae),
            other => bail!("model loss {other:?} is not trainable by the emulator trainer"),
        }
    }
}

/// Scalar loss + `dL/d(out)`. `labels` drive cross-entropy; `target` (the
/// flat input batch) drives the VAE reconstruction loss and is ignored by
/// CE (pass `&[]`).
pub fn loss_and_grad(
    kind: LossKind,
    out: &Tensor,
    labels: &[i32],
    target: &[f32],
) -> Result<(f32, Tensor)> {
    match kind {
        LossKind::CrossEntropy => {
            let n = labels.len();
            anyhow::ensure!(n > 0 && out.data.len() % n == 0, "bad logits shape");
            let c = out.data.len() / n;
            let mut grad = Tensor::zeros(&out.shape);
            let mut loss = 0.0f64;
            let inv = 1.0 / n as f32;
            for (i, &label) in labels.iter().enumerate() {
                let row = &out.data[i * c..(i + 1) * c];
                let y = label as usize;
                anyhow::ensure!(y < c, "label {y} out of range {c}");
                let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let mut se = 0.0f32;
                for &v in row {
                    se += (v - mx).exp();
                }
                let lse = mx + se.ln();
                loss += (lse - row[y]) as f64;
                let grow = &mut grad.data[i * c..(i + 1) * c];
                for (g, &v) in grow.iter_mut().zip(row) {
                    *g = ((v - mx).exp() / se) * inv;
                }
                grow[y] -= inv;
            }
            Ok(((loss / n as f64) as f32, grad))
        }
        LossKind::Vae => {
            anyhow::ensure!(
                out.data.len() == target.len(),
                "reconstruction/target length mismatch: {} vs {}",
                out.data.len(),
                target.len()
            );
            let n_tot = out.data.len().max(1);
            let inv = 1.0 / n_tot as f32;
            let mut grad = Tensor::zeros(&out.shape);
            let mut loss = 0.0f64;
            for ((g, &o), &t0) in grad.data.iter_mut().zip(&out.data).zip(target) {
                let t = t0.clamp(0.0, 1.0);
                let r = o.clamp(1e-6, 1.0 - 1e-6);
                loss -= (t * r.ln() + (1.0 - t) * (1.0 - r).ln()) as f64;
                // Clip STE: the forward clamped `out` into (1e-6, 1-1e-6);
                // gradients vanish where that clamp saturated.
                *g = if o > 1e-6 && o < 1.0 - 1e-6 {
                    (r - t) / (r * (1.0 - r)) * inv
                } else {
                    0.0
                };
            }
            Ok(((loss / n_tot as f64) as f32, grad))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce_loss_and_grad_against_finite_differences() {
        let out = Tensor::from_vec(&[2, 3], vec![0.2, -0.4, 1.1, -0.8, 0.3, 0.05]).unwrap();
        let labels = [2i32, 1];
        let (loss, grad) = loss_and_grad(LossKind::CrossEntropy, &out, &labels, &[]).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        // Grad rows sum to zero (softmax minus one-hot, both mass 1/n).
        for i in 0..2 {
            let s: f32 = grad.data[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "row {i} grad sum {s}");
        }
        let eps = 1e-3f32;
        for j in 0..6 {
            let mut plus = out.clone();
            plus.data[j] += eps;
            let mut minus = out.clone();
            minus.data[j] -= eps;
            let (lp, _) = loss_and_grad(LossKind::CrossEntropy, &plus, &labels, &[]).unwrap();
            let (lm, _) = loss_and_grad(LossKind::CrossEntropy, &minus, &labels, &[]).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad.data[j]).abs() < 1e-3 + 0.02 * fd.abs(),
                "d[{j}]: fd {fd} vs analytic {}",
                grad.data[j]
            );
        }
    }

    #[test]
    fn vae_loss_and_grad_against_finite_differences() {
        let out = Tensor::from_vec(&[1, 4], vec![0.3, 0.7, 0.5, 0.9]).unwrap();
        let target = [0.0f32, 1.0, 0.5, 1.0];
        let (loss, grad) = loss_and_grad(LossKind::Vae, &out, &[], &target).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        let eps = 1e-3f32;
        for j in 0..4 {
            let mut plus = out.clone();
            plus.data[j] += eps;
            let mut minus = out.clone();
            minus.data[j] -= eps;
            let (lp, _) = loss_and_grad(LossKind::Vae, &plus, &[], &target).unwrap();
            let (lm, _) = loss_and_grad(LossKind::Vae, &minus, &[], &target).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad.data[j]).abs() < 2e-3 + 0.02 * fd.abs(),
                "d[{j}]: fd {fd} vs analytic {}",
                grad.data[j]
            );
        }
    }

    #[test]
    fn vae_grad_vanishes_where_clipped() {
        let out = Tensor::from_vec(&[1, 2], vec![0.0, 1.0]).unwrap();
        let target = [1.0f32, 0.0];
        let (_, grad) = loss_and_grad(LossKind::Vae, &out, &[], &target).unwrap();
        assert_eq!(grad.data, vec![0.0, 0.0]);
    }

    #[test]
    fn unknown_loss_is_rejected() {
        assert!(LossKind::parse("none").is_err());
        assert_eq!(LossKind::parse("ce").unwrap(), LossKind::CrossEntropy);
    }
}
