//! Clipped-STE backward pass through the emulator's approximate forward.
//!
//! The forward ran real ACU products ([`Executor::forward_taped`]); the
//! backward differentiates the *exact* GEMM over the fake-quantized
//! operands with straight-through estimators through both quantizers —
//! the paper's fake-quant training scheme, mirroring the Python
//! `nn._ste_matmul_for` custom VJP:
//!
//! ```text
//! dX = (dY @ Ŵᵀ) · 1[|x| ≤ s_a · qmax]      (clipped STE over activations)
//! dW = X̂ᵀ @ dY                              (per-col weight scales never clip)
//! ```
//!
//! where `X̂ = dequant(quant(X))` and `Ŵ` is read straight off the
//! executor's prepared tables ([`Executor::ste_mats`]) so the backward
//! surface is exactly the forward's quantization. The transpose GEMMs are
//! the [`gemm::fp32_a_bt`] / [`gemm::fp32_at_b`] kernels; conv gradients
//! flow through im2col / [`col2im_f32_range_add`]. All workspaces live in
//! a grow-only [`Workspace`] (the trainer's scratch arena).
//!
//! **Approximate-gradient training** (ApproxTrain-style): when an
//! [`ApproxGrad`] is supplied ([`backward_with`], `--approx-backward`,
//! `ADAPT_APPROX_BACKWARD`), both transpose GEMMs instead quantize their
//! operands per-tensor (symmetric, `max|x| / qmax`) and run the ACU's
//! closed-form ([`gemm::cf_opt_i64`]) or behavioral ([`gemm::func_opt`])
//! integer kernel — the gradients themselves pass through the approximate
//! multiplier, modeling accelerators that train on approximate hardware.
//! Bias gradients are plain column sums (no products) either way.
//!
//! Determinism: every kernel computes each output row sequentially on one
//! worker, so gradients are bit-identical at any thread count.

use anyhow::{Context, Result};

use crate::emulator::{gemm, Executor, Value};
use crate::graph::{Node, Op};
use crate::mult;
use crate::quant;
use crate::tensor::{col2im_f32_range_add, conv_out, im2col_f32_range_into, Tensor};

/// Backward-pass ACU: the resolved routing target for approximate-gradient
/// training. `Copy` so [`super::TrainConfig`] stays `Copy`.
#[derive(Clone, Copy, Debug)]
pub struct ApproxGrad {
    /// Registry name (provenance / logging).
    pub name: &'static str,
    /// Operand bitwidth of the gradient quantizer.
    pub bits: u32,
    fun: mult::MulFn,
    /// `Some` for closed-form families (branchless kernel); `None` routes
    /// through the behavioral function.
    form: Option<mult::Form>,
}

impl ApproxGrad {
    /// Resolve a registry ACU name into a backward-pass routing target.
    pub fn from_acu(name: &str) -> Result<ApproxGrad> {
        let m = mult::get(name)?;
        Ok(ApproxGrad {
            name: m.name,
            bits: m.bits,
            fun: m.fun,
            form: (m.form != mult::Form::Opaque).then_some(m.form),
        })
    }
}

/// Grow-only backward workspaces: sized by the largest layer on first
/// use, reused by every later layer, batch and epoch (same grow-only
/// contract as the executor's scratch arena).
#[derive(Default)]
pub struct Workspace {
    patches: Vec<f32>,
    dyg: Vec<f32>,
    dwg: Vec<f32>,
    dpatch: Vec<f32>,
    // Approximate-backward scratch: quantized operands (transposes are
    // materialized — the integer kernels want row-major (M,K)/(K,N)) and
    // the i64 accumulator block.
    qa: Vec<i32>,
    qb: Vec<i32>,
    qacc: Vec<i64>,
}

fn grab(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

fn grab_i32(buf: &mut Vec<i32>, len: usize) -> &mut [i32] {
    if buf.len() < len {
        buf.resize(len, 0);
    }
    &mut buf[..len]
}

fn grab_i64(buf: &mut Vec<i64>, len: usize) -> &mut [i64] {
    if buf.len() < len {
        buf.resize(len, 0);
    }
    &mut buf[..len]
}

/// Per-tensor symmetric quantizer scale `max|x| / qmax` (sequential fold —
/// deterministic). `0.0` means the tensor is all-zero; callers short-cut
/// to a zero output instead of dividing by it.
fn tensor_scale(xs: &[f32], qmax: i32) -> f32 {
    let mut mx = 0.0f32;
    for &v in xs {
        mx = mx.max(v.abs());
    }
    mx / qmax as f32
}

/// Approximate twin of [`gemm::fp32_at_b`]: `out (k, n) = Aᵀ @ B` with
/// both operands per-tensor quantized and every product taken by the
/// backward ACU. The transpose is materialized (quantized) so the integer
/// kernels see their native row-major layout.
#[allow(clippy::too_many_arguments)]
fn approx_at_b(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    ag: ApproxGrad,
    threads: usize,
    qa: &mut Vec<i32>,
    qb: &mut Vec<i32>,
    qacc: &mut Vec<i64>,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let qmax = quant::qmax_for(ag.bits);
    let sa = tensor_scale(a, qmax);
    let sb = tensor_scale(b, qmax);
    if sa == 0.0 || sb == 0.0 {
        out.fill(0.0);
        return;
    }
    let at = grab_i32(qa, k * m);
    for mi in 0..m {
        for ki in 0..k {
            at[ki * m + mi] = quant::quantize_one(a[mi * k + ki], sa, qmax);
        }
    }
    let bq = grab_i32(qb, m * n);
    for (o, &v) in bq.iter_mut().zip(b) {
        *o = quant::quantize_one(v, sb, qmax);
    }
    let acc = grab_i64(qacc, k * n);
    match ag.form {
        Some(form) => gemm::cf_opt_i64(at, k, m, bq, n, form, threads, acc),
        None => gemm::func_opt(at, k, m, bq, n, ag.fun, threads, acc),
    }
    let s = sa * sb;
    for (o, &v) in out.iter_mut().zip(acc.iter()) {
        *o = v as f32 * s;
    }
}

/// Approximate twin of [`gemm::fp32_a_bt`]: `out (m, k) = A @ Bᵀ` where
/// `B` is `(k, n)` row-major — same quantize/route/dequant scheme as
/// [`approx_at_b`], with `Bᵀ` materialized.
#[allow(clippy::too_many_arguments)]
fn approx_a_bt(
    a: &[f32],
    m: usize,
    n: usize,
    b: &[f32],
    k: usize,
    ag: ApproxGrad,
    threads: usize,
    qa: &mut Vec<i32>,
    qb: &mut Vec<i32>,
    qacc: &mut Vec<i64>,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    let qmax = quant::qmax_for(ag.bits);
    let sa = tensor_scale(a, qmax);
    let sb = tensor_scale(b, qmax);
    if sa == 0.0 || sb == 0.0 {
        out.fill(0.0);
        return;
    }
    let aq = grab_i32(qa, m * n);
    for (o, &v) in aq.iter_mut().zip(a) {
        *o = quant::quantize_one(v, sa, qmax);
    }
    let bt = grab_i32(qb, n * k);
    for ki in 0..k {
        for ni in 0..n {
            bt[ni * k + ki] = quant::quantize_one(b[ki * n + ni], sb, qmax);
        }
    }
    let acc = grab_i64(qacc, m * k);
    match ag.form {
        Some(form) => gemm::cf_opt_i64(aq, m, n, bt, k, form, threads, acc),
        None => gemm::func_opt(aq, m, n, bt, k, ag.fun, threads, acc),
    }
    let s = sa * sb;
    for (o, &v) in out.iter_mut().zip(acc.iter()) {
        *o = v as f32 * s;
    }
}

fn tape_f(tape: &[Option<Value>], id: usize) -> Result<&Tensor> {
    match tape.get(id).and_then(|v| v.as_ref()) {
        Some(Value::F(t)) => Ok(t),
        _ => anyhow::bail!("tape missing f32 value {id}"),
    }
}

/// Add `t` into a gradient slot (first write moves, later writes sum —
/// the fan-out rule for values consumed by several nodes).
fn accum(slot: &mut Option<Tensor>, t: Tensor) -> Result<()> {
    match slot {
        None => *slot = Some(t),
        Some(prev) => {
            anyhow::ensure!(
                prev.shape == t.shape,
                "gradient shape mismatch: {:?} vs {:?}",
                prev.shape,
                t.shape
            );
            for (a, &b) in prev.data.iter_mut().zip(&t.data) {
                *a += b;
            }
        }
    }
    Ok(())
}

/// `x̂ = dequant(quant(x))` for quant nodes; `x` itself for fp32 nodes.
fn fake_quant_tensor(x: &Tensor, sa: Option<f32>, bits: Option<u32>) -> Tensor {
    match (sa, bits) {
        (Some(sa), Some(bits)) => {
            let mut t = x.clone();
            for v in &mut t.data {
                *v = quant::fake_quant(*v, sa, bits);
            }
            t
        }
        _ => x.clone(),
    }
}

/// Clipped-STE mask: gradients stop where the activation quantizer
/// saturated (|x| beyond the representable range). No-op for fp32 nodes.
fn apply_clip_mask(dx: &mut Tensor, x: &Tensor, sa: Option<f32>, bits: Option<u32>) {
    if let (Some(sa), Some(bits)) = (sa, bits) {
        let lim = sa * quant::qmax_for(bits) as f32;
        for (g, &v) in dx.data.iter_mut().zip(&x.data) {
            if v.abs() > lim {
                *g = 0.0;
            }
        }
    }
}

/// Gradients of one backward pass.
pub struct Gradients {
    /// One gradient tensor per model parameter (manifest order).
    pub params: Vec<Tensor>,
    /// dL/d(network input) — `None` when no gradient reached the input
    /// node (e.g. the first consumer is an embedding).
    pub input: Option<Tensor>,
}

/// Run the clipped-STE backward over one taped forward.
///
/// * `exec` — the executor that produced `tape`; its prepared (quantized)
///   weights are the fake-quant surface the STE differentiates through.
/// * `tape` — value table from [`Executor::forward_taped`].
/// * `d_out` — dL/d(output) from [`super::loss_and_grad`].
///
/// LSTM and embedding nodes are rejected — those models retrain on the
/// PJRT path.
pub fn backward(
    exec: &Executor,
    tape: &[Option<Value>],
    d_out: Tensor,
    threads: usize,
    ws: &mut Workspace,
) -> Result<Gradients> {
    backward_with(exec, tape, d_out, threads, ws, None)
}

/// [`backward`] with an optional approximate-gradient ACU: when `approx`
/// is `Some`, the weight- and input-grad transpose GEMMs run through the
/// ACU's integer kernel instead of exact fp32 (see the module docs).
pub fn backward_with(
    exec: &Executor,
    tape: &[Option<Value>],
    d_out: Tensor,
    threads: usize,
    ws: &mut Workspace,
    approx: Option<ApproxGrad>,
) -> Result<Gradients> {
    let model = exec.model;
    let threads = threads.max(1);
    let mut grads: Vec<Option<Tensor>> = Vec::new();
    grads.resize_with(tape.len(), || None);
    let last = model.nodes.last().context("empty model")?.id;
    grads[last] = Some(d_out);
    let mut pgrads: Vec<Tensor> = model
        .params
        .iter()
        .map(|s| Tensor::zeros(&s.shape))
        .collect();

    for node in model.nodes.iter().rev() {
        if matches!(node.op, Op::Input) {
            continue;
        }
        let Some(dy) = grads[node.id].take() else {
            continue; // this branch never reaches the loss
        };
        match &node.op {
            Op::Conv2d { .. } => {
                let x = tape_f(tape, node.inputs[0])?;
                let dx = conv_backward(exec, node, x, &dy, &mut pgrads, threads, ws, approx)?;
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Linear { .. } => {
                let x = tape_f(tape, node.inputs[0])?;
                let dx = linear_backward(exec, node, x, &dy, &mut pgrads, threads, ws, approx)?;
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Relu => {
                let y = tape_f(tape, node.id)?;
                let mut dx = dy;
                for (g, &v) in dx.data.iter_mut().zip(&y.data) {
                    if v <= 0.0 {
                        *g = 0.0;
                    }
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Sigmoid => {
                let y = tape_f(tape, node.id)?;
                let mut dx = dy;
                for (g, &v) in dx.data.iter_mut().zip(&y.data) {
                    *g *= v * (1.0 - v);
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Tanh => {
                let y = tape_f(tape, node.id)?;
                let mut dx = dy;
                for (g, &v) in dx.data.iter_mut().zip(&y.data) {
                    *g *= 1.0 - v * v;
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::AvgPool2 => {
                let x = tape_f(tape, node.inputs[0])?;
                let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
                let (ho, wo) = (h / 2, w / 2);
                let mut dx = Tensor::zeros(&x.shape);
                for ni in 0..n {
                    for oy in 0..ho {
                        for ox in 0..wo {
                            for ci in 0..c {
                                let g = dy.data[((ni * ho + oy) * wo + ox) * c + ci] * 0.25;
                                for py in 0..2 {
                                    for px in 0..2 {
                                        dx.data[((ni * h + oy * 2 + py) * w + ox * 2 + px) * c
                                            + ci] += g;
                                    }
                                }
                            }
                        }
                    }
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Gap => {
                let x = tape_f(tape, node.inputs[0])?;
                let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
                let inv = 1.0 / (h * w) as f32;
                let mut dx = Tensor::zeros(&x.shape);
                for ni in 0..n {
                    for yi in 0..h {
                        for xi in 0..w {
                            for ci in 0..c {
                                dx.data[((ni * h + yi) * w + xi) * c + ci] =
                                    dy.data[ni * c + ci] * inv;
                            }
                        }
                    }
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Flatten | Op::Reshape { .. } => {
                let x = tape_f(tape, node.inputs[0])?;
                accum(&mut grads[node.inputs[0]], dy.reshape(&x.shape)?)?;
            }
            Op::Add => {
                accum(&mut grads[node.inputs[0]], dy.clone())?;
                accum(&mut grads[node.inputs[1]], dy)?;
            }
            Op::Concat => {
                let mut start = 0usize;
                for &inp in &node.inputs {
                    let ci = *tape_f(tape, inp)?.shape.last().context("concat input rank")?;
                    accum(&mut grads[inp], dy.slice_last(start, start + ci))?;
                    start += ci;
                }
            }
            Op::ChannelShuffle { groups } => {
                // Forward maps src[gi*cg + ci] -> dst[ci*g + gi]; the
                // adjoint applies the inverse permutation to dY.
                let c = *dy.shape.last().context("shuffle rank")?;
                let cg = c / groups;
                let rows = dy.data.len() / c;
                let mut dx = Tensor::zeros(&dy.shape);
                for r in 0..rows {
                    let src = &dy.data[r * c..(r + 1) * c];
                    let dst = &mut dx.data[r * c..(r + 1) * c];
                    for gi in 0..*groups {
                        for ci in 0..cg {
                            dst[gi * cg + ci] = src[ci * groups + gi];
                        }
                    }
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::SliceLast { start, end } => {
                let x = tape_f(tape, node.inputs[0])?;
                let c = *x.shape.last().context("slice rank")?;
                let width = end - start;
                let rows = x.data.len() / c;
                let mut dx = Tensor::zeros(&x.shape);
                for r in 0..rows {
                    dx.data[r * c + start..r * c + end]
                        .copy_from_slice(&dy.data[r * width..(r + 1) * width]);
                }
                accum(&mut grads[node.inputs[0]], dx)?;
            }
            Op::Lstm { .. } | Op::Embedding { .. } => anyhow::bail!(
                "node {} ({:?}-family) is not supported by the emulator trainer; \
                 LSTM/text models retrain on the PJRT QAT path",
                node.id,
                node.op
            ),
            Op::Input => unreachable!(),
        }
    }
    let input = grads.first_mut().and_then(|slot| slot.take());
    Ok(Gradients {
        params: pgrads,
        input,
    })
}

/// STE backward of one conv node: per group, `dW = patchesᵀ @ dY_g`,
/// `dPatches = dY_g @ Ŵᵀ` scattered back through col2im, bias = column
/// sums of `dY_g`.
#[allow(clippy::too_many_arguments)]
fn conv_backward(
    exec: &Executor,
    node: &Node,
    x: &Tensor,
    dy: &Tensor,
    pgrads: &mut [Tensor],
    threads: usize,
    ws: &mut Workspace,
    approx: Option<ApproxGrad>,
) -> Result<Tensor> {
    let (kh, kw, cin, cout, stride, pad, groups, scale_idx) = match &node.op {
        Op::Conv2d {
            kh,
            kw,
            cin,
            cout,
            stride,
            pad,
            groups,
            scale_idx,
            ..
        } => (*kh, *kw, *cin, *cout, *stride, *pad, *groups, *scale_idx),
        _ => unreachable!(),
    };
    let (n, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    anyhow::ensure!(x.shape[3] == cin, "conv-backward input channels");
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let cin_g = cin / groups;
    let cout_g = cout / groups;
    let kf = kh * kw * cin_g;
    let m = n * ho * wo;
    anyhow::ensure!(dy.data.len() == m * cout, "conv-backward dY size");

    let (mats, bits) = exec.ste_mats(node.id).context("conv node not prepared")?;
    let sa = exec.ste_act_scale(node.id, scale_idx);
    let xhat = fake_quant_tensor(x, sa, bits);

    let mut dx = Tensor::zeros(&x.shape);
    for g in 0..groups {
        let (wg, wk, wn) = &mats[g];
        anyhow::ensure!(
            *wk == kf && *wn == cout_g,
            "conv-backward weight mat shape"
        );
        let patches = grab(&mut ws.patches, m * kf);
        im2col_f32_range_into(
            &xhat.data,
            &x.shape,
            kh,
            kw,
            stride,
            pad,
            g * cin_g,
            (g + 1) * cin_g,
            patches,
        );
        // Gather this group's dY columns into a dense (m, cout_g) block.
        let dyg = grab(&mut ws.dyg, m * cout_g);
        for mi in 0..m {
            let src = mi * cout + g * cout_g;
            dyg[mi * cout_g..(mi + 1) * cout_g].copy_from_slice(&dy.data[src..src + cout_g]);
        }
        // dW_g = patchesᵀ @ dY_g, scattered into the (kh*kw*cin_g, cout)
        // weight-parameter layout (inverse of the prepare-time flatten).
        let dwg = grab(&mut ws.dwg, kf * cout_g);
        match approx {
            Some(ag) => approx_at_b(
                patches, m, kf, dyg, cout_g, ag, threads, &mut ws.qa, &mut ws.qb, &mut ws.qacc,
                dwg,
            ),
            None => gemm::fp32_at_b(patches, m, kf, dyg, cout_g, threads, dwg),
        }
        let pw = &mut pgrads[node.params[0]];
        for row in 0..kf {
            let dst = row * cout + g * cout_g;
            let src = row * cout_g;
            for ci in 0..cout_g {
                pw.data[dst + ci] += dwg[src + ci];
            }
        }
        // Bias grads: column sums of dY_g.
        let pb = &mut pgrads[node.params[1]];
        for mi in 0..m {
            let src = mi * cout_g;
            for ci in 0..cout_g {
                pb.data[g * cout_g + ci] += dyg[src + ci];
            }
        }
        // dPatches = dY_g @ Ŵᵀ, scatter-added back onto dX.
        let dpatch = grab(&mut ws.dpatch, m * kf);
        match approx {
            Some(ag) => approx_a_bt(
                dyg, m, cout_g, wg, kf, ag, threads, &mut ws.qa, &mut ws.qb, &mut ws.qacc, dpatch,
            ),
            None => gemm::fp32_a_bt(dyg, m, cout_g, wg, kf, threads, dpatch),
        }
        col2im_f32_range_add(
            dpatch,
            &x.shape,
            kh,
            kw,
            stride,
            pad,
            g * cin_g,
            (g + 1) * cin_g,
            &mut dx.data,
        );
    }
    apply_clip_mask(&mut dx, x, sa, bits);
    Ok(dx)
}

/// STE backward of one linear node.
#[allow(clippy::too_many_arguments)]
fn linear_backward(
    exec: &Executor,
    node: &Node,
    x: &Tensor,
    dy: &Tensor,
    pgrads: &mut [Tensor],
    threads: usize,
    ws: &mut Workspace,
    approx: Option<ApproxGrad>,
) -> Result<Tensor> {
    let (din, dout, scale_idx) = match &node.op {
        Op::Linear {
            din,
            dout,
            scale_idx,
            ..
        } => (*din, *dout, *scale_idx),
        _ => unreachable!(),
    };
    let m = x.shape[0];
    anyhow::ensure!(x.data.len() == m * din, "linear-backward input shape");
    anyhow::ensure!(dy.data.len() == m * dout, "linear-backward dY shape");

    let (mats, bits) = exec.ste_mats(node.id).context("linear node not prepared")?;
    let sa = exec.ste_act_scale(node.id, scale_idx);
    let xhat = fake_quant_tensor(x, sa, bits);
    let (wg, _, _) = &mats[0];

    // dW = X̂ᵀ @ dY.
    let dwg = grab(&mut ws.dwg, din * dout);
    match approx {
        Some(ag) => approx_at_b(
            &xhat.data, m, din, &dy.data, dout, ag, threads, &mut ws.qa, &mut ws.qb,
            &mut ws.qacc, dwg,
        ),
        None => gemm::fp32_at_b(&xhat.data, m, din, &dy.data, dout, threads, dwg),
    }
    let pw = &mut pgrads[node.params[0]];
    for (o, &g) in pw.data.iter_mut().zip(dwg.iter()) {
        *o += g;
    }
    // Bias grads: column sums of dY.
    let pb = &mut pgrads[node.params[1]];
    for mi in 0..m {
        let row = &dy.data[mi * dout..(mi + 1) * dout];
        for (o, &g) in pb.data.iter_mut().zip(row) {
            *o += g;
        }
    }
    // dX = dY @ Ŵᵀ, clipped-STE-masked.
    let mut dx = Tensor::zeros(&x.shape);
    match approx {
        Some(ag) => approx_a_bt(
            &dy.data, m, dout, wg, din, ag, threads, &mut ws.qa, &mut ws.qb, &mut ws.qacc,
            &mut dx.data,
        ),
        None => gemm::fp32_a_bt(&dy.data, m, dout, wg, din, threads, &mut dx.data),
    }
    apply_clip_mask(&mut dx, x, sa, bits);
    Ok(dx)
}
