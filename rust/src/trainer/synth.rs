//! Bundled tiny model + dataset for artifact-free retraining: the
//! `adapt retrain --synthetic` CI smoke, the `table2_retrain` bench's
//! emulator rows and the trainer tests all share this one setup, so the
//! flow they exercise (pre-train → calibrate → damage with a mixed-ACU
//! plan → QAT-retrain) is identical everywhere.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::data::Dataset;
use crate::graph::{retransform, ExecutionPlan, LayerMode, Model, Node, Op, ParamSpec, Policy};
use crate::lut::LutRegistry;
use crate::quant::calib::CalibratorKind;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Tiny CNN: conv(3x3, 3→8, pad 1) → relu → avgpool2 → conv(3x3, 8→8,
/// pad 1) → relu → gap → linear(8 → 4) on 8x8x3 inputs — small enough for
/// a CI-time retrain, deep enough to exercise conv / pool / gap / linear
/// backward and heterogeneous plans.
pub fn tiny_cnn() -> Model {
    let conv = |id, cin, cout, scale_idx, name: &str, input, p0| Node {
        id,
        op: Op::Conv2d {
            kh: 3,
            kw: 3,
            cin,
            cout,
            stride: 1,
            pad: 1,
            groups: 1,
            scale_idx,
            name: name.into(),
        },
        inputs: vec![input],
        params: vec![p0, p0 + 1],
    };
    let p = |name: &str, shape: &[usize]| ParamSpec {
        name: name.into(),
        shape: shape.to_vec(),
    };
    Model {
        name: "tiny_cnn".into(),
        paper_row: "-".into(),
        kind: "cnn".into(),
        dataset: "tiny_syn".into(),
        input_shape: vec![8, 8, 3],
        input_dtype: "f32".into(),
        out_dim: 4,
        loss: "ce".into(),
        metric: "top1".into(),
        table2: false,
        n_scales: 3,
        params: vec![
            p("w1", &[3, 3, 3, 8]),
            p("b1", &[8]),
            p("w2", &[3, 3, 8, 8]),
            p("b2", &[8]),
            p("w3", &[8, 4]),
            p("b3", &[4]),
        ],
        params_count: 0,
        macs: 0,
        nodes: vec![
            Node {
                id: 0,
                op: Op::Input,
                inputs: vec![],
                params: vec![],
            },
            conv(1, 3, 8, 0, "c1", 0, 0),
            Node {
                id: 2,
                op: Op::Relu,
                inputs: vec![1],
                params: vec![],
            },
            Node {
                id: 3,
                op: Op::AvgPool2,
                inputs: vec![2],
                params: vec![],
            },
            conv(4, 8, 8, 1, "c2", 3, 2),
            Node {
                id: 5,
                op: Op::Relu,
                inputs: vec![4],
                params: vec![],
            },
            Node {
                id: 6,
                op: Op::Gap,
                inputs: vec![5],
                params: vec![],
            },
            Node {
                id: 7,
                op: Op::Linear {
                    din: 8,
                    dout: 4,
                    scale_idx: 2,
                    name: "head".into(),
                },
                inputs: vec![6],
                params: vec![4, 5],
            },
        ],
        weights_file: String::new(),
        artifacts: BTreeMap::new(),
    }
}

/// Seeded gaussian init for [`tiny_cnn`] (or any in-memory model).
pub fn tiny_params(model: &Model, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    model
        .params
        .iter()
        .map(|spec| {
            let data = (0..spec.numel()).map(|_| rng.next_gauss() * 0.35).collect();
            Tensor::from_vec(&spec.shape, data).expect("tiny param shape")
        })
        .collect()
}

/// The canonical damaged plan for the demo: every layer on a lossy ACU —
/// the shape `adapt sensitivity`'s greedy search emits.
pub fn tiny_mixed_plan(model: &Model) -> ExecutionPlan {
    retransform(
        model,
        &Policy::all(LayerMode::lut("mitchell8")).with_acu("c2", "trunc_out8_4"),
    )
}

/// Dataset bound to [`tiny_cnn`] (`data::load("tiny_syn", ..)`).
pub fn tiny_dataset(n_train: usize, n_eval: usize) -> Dataset {
    crate::data::load(
        "tiny_syn",
        &crate::data::Sizes {
            n_train,
            n_eval,
        },
    )
}

/// Pre-trained [`tiny_cnn`] with emulator-calibrated activation scales —
/// the shared setup for the retraining demo, the compensation demo
/// (`adapt compensate --synthetic`), `tests/compensate.rs` and
/// `benches/compensate.rs`. Deterministic for a fixed seed at any thread
/// count.
pub struct TinySetup {
    pub model: Model,
    /// fp32 pre-trained parameters.
    pub params: Vec<Tensor>,
    /// Per-scale activation scales from [`super::calibrate_emulator`].
    pub scales: Vec<f32>,
    pub ds: Dataset,
}

/// fp32 pre-train [`tiny_cnn`] (6 epochs, the "download a pretrained
/// model" stand-in) and calibrate the emulator's activation scales.
pub fn tiny_pretrained(seed: u64, threads: usize) -> Result<TinySetup> {
    let model = tiny_cnn();
    let ds = tiny_dataset(512, 256);
    let luts = LutRegistry::in_memory();
    let bs = 32;
    let fp32_plan = retransform(&model, &Policy::all(LayerMode::Fp32));
    let pre_cfg = super::TrainConfig {
        epochs: 6,
        lr: 0.012,
        momentum: 0.9,
        batch: bs,
        seed,
        threads,
        max_batches: None,
        log_every: 0,
        approx_backward: None,
    };
    let pre = super::fit(&model, tiny_params(&model, seed), &fp32_plan, &[], &luts, &ds.train, &pre_cfg)?;
    let params = pre.params;
    let scales = super::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        bs,
        2,
        CalibratorKind::Percentile,
        0.999,
        threads,
    )?;
    Ok(TinySetup {
        model,
        params,
        scales,
        ds,
    })
}

/// Outcome of [`demo_retrain`].
pub struct DemoOutcome {
    /// fp32 eval accuracy after pre-training.
    pub fp32_acc: f64,
    /// Mixed-ACU plan accuracy before retraining (the damage).
    pub approx_acc: f64,
    /// Mixed-ACU plan accuracy after QAT retraining (the recovery).
    pub retrained_acc: f64,
    pub fit: super::FitResult,
    pub report: String,
}

/// End-to-end artifact-free retraining demo: pre-train fp32 → calibrate
/// (emulator taps) → damage with [`tiny_mixed_plan`] → QAT-retrain on
/// that plan. Deterministic for a fixed seed at any thread count.
pub fn demo_retrain(epochs: usize, lr: f32, seed: u64, threads: usize) -> Result<DemoOutcome> {
    demo_retrain_with(epochs, lr, seed, threads, None)
}

/// [`demo_retrain`] with an optional approximate-gradient ACU for the QAT
/// phase (the fp32 pre-training always uses the exact backward).
pub fn demo_retrain_with(
    epochs: usize,
    lr: f32,
    seed: u64,
    threads: usize,
    approx: Option<super::ApproxGrad>,
) -> Result<DemoOutcome> {
    let luts = LutRegistry::in_memory();
    let bs = 32;
    let eval_batches = 8;
    let TinySetup {
        model,
        params,
        scales,
        ds,
    } = tiny_pretrained(seed, threads)?;
    let fp32_plan = retransform(&model, &Policy::all(LayerMode::Fp32));

    let fp32_acc = super::evaluate(
        &model, params.clone(), &fp32_plan, &[], &luts, &ds.eval, bs, eval_batches, threads,
    )?;
    let plan = tiny_mixed_plan(&model);
    let approx_acc = super::evaluate(
        &model, params.clone(), &plan, &scales, &luts, &ds.eval, bs, eval_batches, threads,
    )?;

    // Approximation-aware retraining on the damaged plan.
    let qat_cfg = super::TrainConfig {
        epochs: epochs.max(1),
        lr,
        momentum: 0.9,
        batch: bs,
        seed: seed ^ 0x9A7,
        threads,
        max_batches: None,
        log_every: 0,
        approx_backward: approx,
    };
    let fit = super::fit(&model, params, &plan, &scales, &luts, &ds.train, &qat_cfg)?;
    let retrained_acc = super::evaluate(
        &model, fit.params.clone(), &plan, &scales, &luts, &ds.eval, bs, eval_batches, threads,
    )?;

    let (l0, l1) = fit.improvement();
    let epoch_means: Vec<String> = fit.epoch_losses.iter().map(|l| format!("{l:.4}")).collect();
    let mut report = format!(
        "tiny_cnn emulator QAT demo (seed {seed:#x}, {} QAT epochs x {} steps, lr {lr}, batch {bs})\n\
         plan:\n{}\
         fp32 accuracy:      {:.2}%\n\
         approx (no QAT):    {:.2}%\n\
         approx (retrained): {:.2}%   ({:+.2} pts recovered)\n\
         qat loss per epoch: {}   ({:.4} -> {:.4})\n",
        qat_cfg.epochs,
        fit.steps / qat_cfg.epochs,
        plan.describe(&model),
        100.0 * fp32_acc,
        100.0 * approx_acc,
        100.0 * retrained_acc,
        100.0 * (retrained_acc - approx_acc),
        epoch_means.join(", "),
        l0,
        l1,
    );
    if let Some(ag) = approx {
        report.push_str(&format!("approx backward ACU: {} ({}-bit)\n", ag.name, ag.bits));
    }
    Ok(DemoOutcome {
        fp32_acc,
        approx_acc,
        retrained_acc,
        fit,
        report,
    })
}
