//! Emulator-native approximation-aware retraining (QAT) — §3.2.1 without
//! the PJRT artifact path.
//!
//! The paper's second headline claim is error *recovery*: after swapping
//! exact multipliers for approximate ones, a short retraining run under
//! the approximate forward wins back most of the lost accuracy. The AOT
//! train-step executables implement that on XLA, but they are dead in
//! offline builds (the vendored `xla` stub cannot create a PJRT client)
//! and they only know the single-global-LUT plan. This subsystem makes
//! retraining a first-class citizen of the Rust emulator instead:
//!
//! * **Forward** — exactly what [`Executor::forward`] computes (the same
//!   kernels run, via [`Executor::forward_taped`], which retains every
//!   node output as the tape). Heterogeneous mixed-ACU plans train as-is.
//! * **Backward** — clipped straight-through estimators through the
//!   quantizers and exact fp32 GEMM transposes over the *fake-quantized*
//!   operands ([`grad::backward`]), mirroring the Python
//!   `nn._ste_matmul_for` custom-VJP formula bit for bit in structure:
//!   `dX = (dY @ Ŵᵀ) · 1[|x| ≤ s·qmax]`, `dW = X̂ᵀ @ dY`.
//! * **Optimizer** — SGD with momentum ([`sgd::SgdMomentum`]), the same
//!   `v ← μv + g; p ← p − lr·v` update the train-step artifacts bake in.
//! * **Loop** — [`fit`]: seeded epoch shuffles ([`crate::util::rng`]),
//!   plan-aware re-quantization of the weights every step (that is what
//!   QAT means here), per-epoch loss means. Deterministic for a fixed
//!   seed at *any* thread count: every GEMM kernel (forward and backward)
//!   computes each output row sequentially on one worker.
//!
//! Everything here is artifact-free: tests, benches and the
//! `adapt retrain --synthetic` CI smoke run it with in-memory models
//! ([`synth`]); `adapt retrain` proper needs only the manifest + a
//! weights blob (no HLO artifacts, no PJRT). LSTM/text models keep using
//! the PJRT QAT path — their backward is not implemented here.

pub mod grad;
pub mod loss;
pub mod sgd;
pub mod synth;

pub use grad::{backward, backward_with, ApproxGrad, Gradients, Workspace};
pub use loss::{loss_and_grad, LossKind};
pub use sgd::SgdMomentum;

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::data::Split;
use crate::emulator::{Executor, ScratchArena, Style, Value};
use crate::graph::{retransform, ExecutionPlan, LayerMode, Model, Op, Policy};
use crate::lut::LutRegistry;
use crate::metrics;
use crate::quant::calib::{Calibrator, CalibratorKind, HistogramCalibrator};
use crate::tensor::{im2col_f32, Tensor};
use crate::util::rng::Rng;

/// Hyper-parameters of [`fit`].
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    pub batch: usize,
    /// Seed for the per-epoch shuffles (fixed seed ⇒ bit-identical run).
    pub seed: u64,
    /// GEMM threads (forward + backward kernels).
    pub threads: usize,
    /// Cap on batches per epoch (`None` = the full split).
    pub max_batches: Option<usize>,
    /// Progress line every N steps on stderr (0 = silent).
    pub log_every: usize,
    /// Approximate-gradient training (ApproxTrain-style): route the
    /// backward transpose GEMMs through this ACU's integer kernel.
    /// `None` falls back to the `ADAPT_APPROX_BACKWARD` env (an ACU
    /// registry name), and then to the exact fp32 backward.
    pub approx_backward: Option<grad::ApproxGrad>,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            epochs: 2,
            lr: 1e-3,
            momentum: 0.9,
            batch: 32,
            seed: 0x5EED,
            threads: crate::util::threadpool::default_threads(),
            max_batches: None,
            log_every: 0,
            approx_backward: None,
        }
    }
}

/// Resolve the backward-pass ACU: an explicit config wins, then the
/// `ADAPT_APPROX_BACKWARD` env (ACU registry name; bad names are an
/// error, not silently exact), then the exact fp32 backward.
fn resolve_approx_backward(cfg: &TrainConfig) -> Result<Option<grad::ApproxGrad>> {
    if cfg.approx_backward.is_some() {
        return Ok(cfg.approx_backward);
    }
    match std::env::var("ADAPT_APPROX_BACKWARD") {
        Ok(name) if !name.trim().is_empty() => {
            let ag = grad::ApproxGrad::from_acu(name.trim())
                .context("ADAPT_APPROX_BACKWARD names an unknown ACU")?;
            Ok(Some(ag))
        }
        _ => Ok(None),
    }
}

/// Outcome of a [`fit`] run.
#[derive(Clone, Debug)]
pub struct FitResult {
    /// Updated parameters (manifest order).
    pub params: Vec<Tensor>,
    pub steps: usize,
    pub wall: Duration,
    pub first_loss: f32,
    pub last_loss: f32,
    /// Per-step training losses.
    pub losses: Vec<f32>,
    /// Mean training loss per epoch.
    pub epoch_losses: Vec<f32>,
}

impl FitResult {
    /// `(first, last)` epoch-mean losses — the pair smoke checks assert
    /// decreased. Falls back to the first/last *step* losses when fewer
    /// than two epochs ran.
    pub fn improvement(&self) -> (f32, f32) {
        if self.epoch_losses.len() >= 2 {
            (
                self.epoch_losses[0],
                *self.epoch_losses.last().expect("non-empty"),
            )
        } else {
            (self.first_loss, self.last_loss)
        }
    }
}

/// Plan-aware QAT training loop: SGD-with-momentum through the emulator's
/// approximate forward and the clipped-STE backward, over any
/// [`ExecutionPlan`] — heterogeneous mixed-ACU plans included.
///
/// Weights are re-quantized from the fp32 master copy every step (the
/// executor rebuild threads one warm [`ScratchArena`] through the whole
/// run). `act_scales` may be empty iff the plan is all-fp32, which makes
/// this double as the plain fp32 pre-training loop.
pub fn fit(
    model: &Model,
    params: Vec<Tensor>,
    plan: &ExecutionPlan,
    act_scales: &[f32],
    luts: &LutRegistry,
    train: &Split,
    cfg: &TrainConfig,
) -> Result<FitResult> {
    let kind = LossKind::parse(&model.loss)?;
    anyhow::ensure!(
        !train.is_tokens,
        "emulator trainer supports f32-input models (use the PJRT QAT path for token models)"
    );
    anyhow::ensure!(cfg.epochs > 0, "fit needs at least one epoch");
    anyhow::ensure!(train.num > 0, "fit needs a non-empty training split");
    let bs = cfg.batch.max(1);
    let per: usize = train.sample_shape.iter().product();
    let nb_full = (train.num / bs).max(1);
    let nb = cfg.max_batches.map_or(nb_full, |m| m.min(nb_full)).max(1);
    let threads = cfg.threads.max(1);
    let needs_target = matches!(kind, LossKind::Vae);
    let last = model.nodes.last().context("empty model")?.id;
    let approx = resolve_approx_backward(cfg)?;
    if let Some(ag) = approx {
        crate::obs::log::info(
            "fit",
            "approx-backward",
            &[("model", model.name.clone()), ("acu", ag.name.to_string())],
        );
    }

    let mut params = params;
    let mut opt = SgdMomentum::new(cfg.lr, cfg.momentum, &params);
    let mut ws = Workspace::default();
    let mut arena = ScratchArena::new();
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..train.num).collect();

    let mut shape = vec![bs];
    shape.extend_from_slice(&train.sample_shape);

    let mut losses = Vec::with_capacity(cfg.epochs * nb);
    let mut epoch_losses = Vec::with_capacity(cfg.epochs);
    let t0 = Instant::now();
    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut esum = 0.0f64;
        for bi in 0..nb {
            // Gather the shuffled batch.
            let mut flat = Vec::with_capacity(bs * per);
            let mut labels = Vec::with_capacity(bs);
            for i in 0..bs {
                let idx = order[(bi * bs + i) % train.num];
                flat.extend_from_slice(&train.x_f[idx * per..(idx + 1) * per]);
                labels.push(train.labels[idx]);
            }
            let x = Tensor::from_vec(&shape, flat)?;
            let target: &[f32] = if needs_target { &x.data } else { &[] };

            // QAT step: re-quantize the current weights, run the
            // approximate forward with a tape, STE backward, SGD update.
            let exec = Executor::with_arena(
                model,
                params.clone(),
                plan.clone(),
                act_scales.to_vec(),
                luts,
                Style::Optimized { threads },
                arena,
            )?;
            let tape = exec.forward_taped(Value::F(x.clone()))?;
            let out = match tape.get(last).and_then(|v| v.as_ref()) {
                Some(Value::F(t)) => t,
                _ => anyhow::bail!("model output missing from tape"),
            };
            let (loss, d_out) = loss_and_grad(kind, out, &labels, target)?;
            anyhow::ensure!(
                loss.is_finite(),
                "{} diverged at epoch {epoch} step {bi} (loss {loss})",
                model.name
            );
            let pgrads = backward_with(&exec, &tape, d_out, threads, &mut ws, approx)?;
            drop(tape);
            arena = exec.into_arena();
            opt.step(&mut params, &pgrads.params);

            losses.push(loss);
            esum += loss as f64;
            if cfg.log_every > 0 && losses.len() % cfg.log_every == 0 {
                crate::obs::log::info(
                    "fit",
                    "step",
                    &[
                        ("model", model.name.clone()),
                        ("epoch", epoch.to_string()),
                        ("step", bi.to_string()),
                        ("loss", format!("{loss:.4}")),
                    ],
                );
            }
        }
        epoch_losses.push((esum / nb as f64) as f32);
    }
    Ok(FitResult {
        params,
        steps: losses.len(),
        wall: t0.elapsed(),
        first_loss: losses.first().copied().unwrap_or(f32::NAN),
        last_loss: losses.last().copied().unwrap_or(f32::NAN),
        losses,
        epoch_losses,
    })
}

/// Accuracy of `(params, plan)` over up to `max_batches` of a split — the
/// trainer-side evaluation loop (same metric dispatch as the sweep core).
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    model: &Model,
    params: Vec<Tensor>,
    plan: &ExecutionPlan,
    act_scales: &[f32],
    luts: &LutRegistry,
    split: &Split,
    batch: usize,
    max_batches: usize,
    threads: usize,
) -> Result<f64> {
    let bs = batch.max(1);
    let nb = split.n_batches(bs).max(1).min(max_batches.max(1));
    let exec = Executor::new(
        model,
        params,
        plan.clone(),
        act_scales.to_vec(),
        luts,
        Style::Optimized {
            threads: threads.max(1),
        },
    )?;
    let mut acc = 0.0f64;
    let mut samples = 0usize;
    for bi in 0..nb {
        let x = split.batch_tensor(bi, bs);
        let out = exec.forward(Value::F(x))?;
        let labels = split.batch_labels(bi, bs);
        let target = if model.metric == "pixel" {
            split.batch_f(bi, bs)
        } else {
            vec![]
        };
        let od = out.data.len() / bs;
        acc += metrics::compute(&model.metric, &out.data, od, &labels, &target) * bs as f64;
        samples += bs;
    }
    Ok(acc / samples.max(1) as f64)
}

/// Artifact-free post-training calibration: run the *fp32* forward on the
/// Rust executor and stream every quantizable GEMM's input (the im2col
/// patch matrix for convs, the activation matrix for linears) into a
/// per-scale histogram calibrator — the emulator-side mirror of the PJRT
/// `acts` tap path ([`crate::coordinator::ops::calibrate`]).
#[allow(clippy::too_many_arguments)]
pub fn calibrate_emulator(
    model: &Model,
    params: &[Tensor],
    split: &Split,
    batch: usize,
    batches: usize,
    kind: CalibratorKind,
    percentile: f64,
    threads: usize,
) -> Result<Vec<f32>> {
    let plan = retransform(model, &Policy::all(LayerMode::Fp32));
    let luts = LutRegistry::in_memory();
    let exec = Executor::new(
        model,
        params.to_vec(),
        plan,
        vec![],
        &luts,
        Style::Optimized {
            threads: threads.max(1),
        },
    )?;
    let mut calibs: Vec<HistogramCalibrator> = (0..model.n_scales)
        .map(|_| HistogramCalibrator::new(kind).with_percentile(percentile))
        .collect();
    let bs = batch.max(1);
    let tape_f = |tape: &[Option<Value>], id: usize| -> Result<Tensor> {
        match tape.get(id).and_then(|v| v.as_ref()) {
            Some(Value::F(t)) => Ok(t.clone()),
            _ => anyhow::bail!("calibration tape missing f32 value {id}"),
        }
    };
    for bi in 0..batches.max(1) {
        let tape = exec.forward_taped(Value::F(split.batch_tensor(bi, bs)))?;
        for node in &model.nodes {
            match &node.op {
                Op::Conv2d {
                    kh,
                    kw,
                    stride,
                    pad,
                    scale_idx,
                    ..
                } => {
                    let xin = tape_f(&tape, node.inputs[0])?;
                    let patches = im2col_f32(&xin, *kh, *kw, *stride, *pad);
                    calibs[*scale_idx].observe(&patches.data);
                }
                Op::Linear { scale_idx, .. } => {
                    let xin = tape_f(&tape, node.inputs[0])?;
                    calibs[*scale_idx].observe(&xin.data);
                }
                Op::Lstm { .. } => anyhow::bail!(
                    "LSTM models are not supported by the emulator calibration \
                     (use the PJRT `acts` path)"
                ),
                _ => {}
            }
        }
    }
    Ok(calibs.iter().map(|c| c.scale(8)).collect())
}
