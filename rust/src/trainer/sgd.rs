//! SGD with momentum over `runtime::weights`-style fp32 parameter lists —
//! the exact update the AOT train-step executables bake in
//! (`python/compile/train.py`): `v ← μ·v + g ; p ← p − lr·v`.

use crate::tensor::Tensor;

/// SGD-with-momentum state: one velocity buffer per parameter tensor.
pub struct SgdMomentum {
    pub lr: f32,
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl SgdMomentum {
    /// Zero-initialized velocities shaped like `params`.
    pub fn new(lr: f32, momentum: f32, params: &[Tensor]) -> SgdMomentum {
        SgdMomentum {
            lr,
            momentum,
            vel: params.iter().map(|p| vec![0.0; p.data.len()]).collect(),
        }
    }

    /// One update step. `grads` must align with `params` (same order and
    /// shapes — the `backward` contract).
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        for ((p, g), v) in params.iter_mut().zip(grads).zip(self.vel.iter_mut()) {
            debug_assert_eq!(p.data.len(), g.data.len());
            for ((pv, &gv), vv) in p.data.iter_mut().zip(&g.data).zip(v.iter_mut()) {
                *vv = self.momentum * *vv + gv;
                *pv -= self.lr * *vv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_python_update_rule() {
        let mut params = vec![Tensor::from_vec(&[2], vec![1.0, -2.0]).unwrap()];
        let grads = vec![Tensor::from_vec(&[2], vec![0.5, -1.0]).unwrap()];
        let mut opt = SgdMomentum::new(0.1, 0.9, &params);
        opt.step(&mut params, &grads);
        // v1 = g, p1 = p0 - lr*g
        assert!((params[0].data[0] - (1.0 - 0.05)).abs() < 1e-7);
        assert!((params[0].data[1] - (-2.0 + 0.1)).abs() < 1e-7);
        opt.step(&mut params, &grads);
        // v2 = 0.9*g + g = 1.9*g
        assert!((params[0].data[0] - (0.95 - 0.1 * 1.9 * 0.5)).abs() < 1e-6);
    }

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut params = vec![Tensor::from_vec(&[1], vec![0.0]).unwrap()];
        let grads = vec![Tensor::from_vec(&[1], vec![1.0]).unwrap()];
        let mut opt = SgdMomentum::new(0.5, 0.0, &params);
        for _ in 0..3 {
            opt.step(&mut params, &grads);
        }
        assert!((params[0].data[0] + 1.5).abs() < 1e-6);
    }
}
