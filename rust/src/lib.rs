//! # AdaPT-RS
//!
//! Production-grade reproduction of **"AdaPT: Fast Emulation of Approximate
//! DNN Accelerators in PyTorch"** (Danopoulos et al., IEEE TCAD 2022) on the
//! session's three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the Layer-3 coordinator: it loads the AOT-compiled XLA
//! executables produced by `python/compile/aot.py` (HLO text via the PJRT C
//! API), owns every experiment in the paper's evaluation (Tables 1–4), and
//! implements the substrates the paper depends on — approximate-multiplier
//! library, LUT engine, quantization + calibration, a scalar *baseline*
//! emulator and an optimized blocked/threaded emulator, synthetic datasets,
//! and the QAT retraining loop.
//!
//! ## Module map
//!
//! * [`util`] — dependency-free substrates: JSON, CLI, PRNG, thread pools
//!   (scoped GEMM helpers + the persistent [`util::threadpool::ThreadPool`]
//!   behind the parallel sweep), micro-benchmark harness.
//! * [`tensor`] — minimal NHWC ndarray + im2col (Fig. 3's GEMM reshape),
//!   including the allocation-free channel-range variants the executor's
//!   scratch arena feeds.
//! * [`mult`] — behavioral approximate multipliers (EvoApprox substitute),
//!   bit-exact mirrors of `python/compile/multipliers.py`.
//! * [`lut`] — product look-up tables: binary loader, generator, layouts,
//!   and the shared [`lut::LutRegistry`] resolving ACU *names* to
//!   `Arc<Lut>` tables exactly once per process.
//! * [`quant`] — affine quantizer + histogram calibrators (§3.2).
//! * [`layers`] — fp32/approx layer kernels for the Rust emulators (§3.3).
//! * [`graph`] — the shared model IR + the graph re-transform tool (§3.4).
//!   [`graph::LayerMode`] carries per-layer ACU identity;
//!   [`graph::ExecutionPlan`] serializes to/from plan JSON, making
//!   mixed-precision configurations first-class artifacts.
//! * [`emulator`] — the Table-4 engines: naive scalar *baseline* and the
//!   blocked, threaded *optimized* engine (§4). Kernels dispatch at two
//!   tiers: per layer, closed-form ACU families compile to branchless
//!   bit-op inner loops while opaque ACUs take vectorized LUT gathers;
//!   per process, [`emulator::simd`] picks AVX2/NEON/scalar once (all
//!   tiers bit-identical at any thread count). Executes heterogeneous
//!   per-layer ACU plans with a grow-only scratch arena (zero per-layer
//!   heap allocations in steady state).
//! * [`data`] — deterministic synthetic datasets (CIFAR/MNIST/IMDB stand-ins).
//! * [`runtime`] — PJRT artifact loading/execution (the AdaPT fast path;
//!   stubbed by `rust/vendor/xla` in offline builds).
//! * [`coordinator`] — the engine pool (N dynamic-batching workers over a
//!   bounded request queue with backpressure), calibration, QAT
//!   retraining, experiment harnesses for every table in the paper plus
//!   the pool-parallel per-layer ACU sensitivity sweep / greedy
//!   mixed-precision search
//!   (`coordinator::experiments::layer_sensitivity`).
//! * [`service`] — the versioned serving API over the engine pools:
//!   typed [`service::InferRequest`]/[`service::InferResponse`] +
//!   structured [`service::ServiceError`], the [`service::AdaptService`]
//!   control plane per model, the [`service::ModelRegistry`] (N named
//!   models, immutable plan versions, canary rollout, live shadow
//!   evaluation, activate/rollback), a dependency-free HTTP/1.1
//!   front-end (the `/v1` single-model shim + the `/v2/models/...`
//!   registry routes, idle-timeout + connection-cap hardened) served
//!   by a readiness-loop transport ([`service::net`]: raw-epoll/poll
//!   event loops, pipelined parsing, batched writes, a timer wheel for
//!   idle deadlines) and the worker-pool load-generating client behind
//!   `adapt serve --listen` / `adapt client`.
//! * [`trainer`] — emulator-native approximation-aware retraining (QAT):
//!   clipped-STE backward through the quantized/LUT forward
//!   ([`emulator::Executor::forward_taped`]), SGD-with-momentum, and the
//!   plan-aware [`trainer::fit`] loop — artifact-free, heterogeneous
//!   mixed-ACU plans included (`adapt retrain`).
//! * [`compensate`] — calibrated error compensation (Zervakis-style
//!   control variates): per-ACU signed error models over each layer's
//!   calibrated operand histogram fit constant + per-output-channel
//!   additive corrections ([`compensate::compensation_for`]) that ride in
//!   the plan JSON ([`graph::Compensation`]) and fold into the executor's
//!   bias epilogue at prepare time — zero hot-path cost, and the knob that
//!   makes the most aggressive ACUs usable (`adapt compensate`,
//!   `adapt search --compensate`).
//! * [`search`] — whole-plan search over the sensitivity sweep's scoring
//!   core: the MAC-weighted plan cost model ([`search::plan_cost`]) and
//!   the [`search::mcts`] Monte Carlo Tree Search planner (TransAxx-style
//!   UCT + virtual-loss parallel playouts, deterministic per seed at any
//!   thread count, optional QAT-in-the-loop leaf re-scoring) behind
//!   `adapt search` / `adapt sensitivity --search mcts`.
//! * [`metrics`] — accuracy/timing metrics.
//! * [`obs`] — serving observability: request tracing with tail-based
//!   sampling ([`obs::TraceRecorder`]), per-layer kernel profiling
//!   ([`obs::LayerProfiler`], fed by the executor and `adapt profile`),
//!   Prometheus text exposition behind `GET /metrics`, net-layer
//!   lifecycle counters ([`obs::NetStats`]), and a leveled structured
//!   logger (`ADAPT_LOG`, [`obs::log`]). Every hook is gated by one
//!   relaxed atomic (or an absent `Option`) so the GEMM hot path is
//!   unaffected when observability is off.

pub mod compensate;
pub mod coordinator;
pub mod data;
pub mod emulator;
pub mod graph;
pub mod layers;
pub mod lut;
pub mod metrics;
pub mod mult;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod service;
pub mod tensor;
pub mod trainer;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts directory (override with env `ADAPT_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("ADAPT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
