//! Layer kernels for the Rust emulators (§3.3): elementwise activations,
//! pooling, shuffles, embedding — everything around the quantizable GEMMs
//! (which live in [`crate::emulator::gemm`]).
//!
//! All functions are pure `Tensor -> Tensor`; shapes follow the NHWC
//! conventions of the shared IR.

use anyhow::Result;

use crate::tensor::{Tensor, TensorI32};

pub fn relu(x: Tensor) -> Tensor {
    x.map(|v| v.max(0.0))
}

pub fn sigmoid(x: Tensor) -> Tensor {
    x.map(|v| 1.0 / (1.0 + (-v).exp()))
}

pub fn tanh(x: Tensor) -> Tensor {
    x.map(|v| v.tanh())
}

/// 2x2 stride-2 average pool over NHWC (odd tail rows/cols dropped,
/// mirroring `nn.avgpool2`).
pub fn avgpool2(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ci in 0..c {
                    let mut s = 0.0f32;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            s += x.data
                                [((ni * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ci];
                        }
                    }
                    out.data[((ni * ho + oy) * wo + ox) * c + ci] = s / 4.0;
                }
            }
        }
    }
    out
}

/// Global average pool: (N,H,W,C) -> (N,C).
pub fn gap(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&[n, c]);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for yi in 0..h {
            for xi in 0..w {
                for ci in 0..c {
                    out.data[ni * c + ci] += x.data[((ni * h + yi) * w + xi) * c + ci];
                }
            }
        }
    }
    for v in &mut out.data {
        *v *= inv;
    }
    out
}

/// Flatten all trailing dims: (N, ...) -> (N, prod).
pub fn flatten(x: Tensor) -> Tensor {
    let n = x.shape[0];
    let rest: usize = x.shape[1..].iter().product();
    x.reshape(&[n, rest]).expect("flatten")
}

/// Channel shuffle for grouped convs: (N,H,W,g*cg) with channel c = g_i*cg + c_i
/// remapped to c_i*g + g_i (transpose of the (g, cg) index pair).
pub fn channel_shuffle(x: &Tensor, groups: usize) -> Tensor {
    let c = *x.shape.last().unwrap();
    assert_eq!(c % groups, 0);
    let cg = c / groups;
    let rows = x.data.len() / c;
    let mut out = Tensor::zeros(&x.shape);
    for r in 0..rows {
        let src = &x.data[r * c..(r + 1) * c];
        let dst = &mut out.data[r * c..(r + 1) * c];
        for gi in 0..groups {
            for ci in 0..cg {
                dst[ci * groups + gi] = src[gi * cg + ci];
            }
        }
    }
    out
}

/// Embedding lookup: tokens (N,T) i32 -> (N,T,dim) f32.
pub fn embedding(tokens: &TensorI32, table: &Tensor) -> Result<Tensor> {
    let (n, t) = (tokens.shape[0], tokens.shape[1]);
    let (vocab, dim) = (table.shape[0], table.shape[1]);
    let mut out = Tensor::zeros(&[n, t, dim]);
    for (i, &tok) in tokens.data.iter().enumerate() {
        let tok = tok as usize;
        anyhow::ensure!(tok < vocab, "token {tok} out of vocab {vocab}");
        out.data[i * dim..(i + 1) * dim]
            .copy_from_slice(&table.data[tok * dim..(tok + 1) * dim]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps() {
        let x = Tensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -0.5]).unwrap();
        assert_eq!(relu(x).data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn sigmoid_midpoint_and_saturation() {
        let x = Tensor::from_vec(&[3], vec![0.0, 10.0, -10.0]).unwrap();
        let y = sigmoid(x);
        assert!((y.data[0] - 0.5).abs() < 1e-7);
        assert!(y.data[1] > 0.9999);
        assert!(y.data[2] < 0.0001);
    }

    #[test]
    fn avgpool_averages_quads() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = avgpool2(&x);
        assert_eq!(y.shape, vec![1, 1, 1, 1]);
        assert_eq!(y.data, vec![2.5]);
    }

    #[test]
    fn gap_means_over_space() {
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.])
            .unwrap();
        let y = gap(&x);
        assert_eq!(y.shape, vec![1, 2]);
        assert_eq!(y.data, vec![2.5, 25.0]);
    }

    #[test]
    fn shuffle_transposes_groups() {
        // c = 4, groups = 2: [a0 a1 b0 b1] -> [a0 b0 a1 b1]
        let x = Tensor::from_vec(&[1, 1, 1, 4], vec![0., 1., 2., 3.]).unwrap();
        let y = channel_shuffle(&x, 2);
        assert_eq!(y.data, vec![0., 2., 1., 3.]);
        // shuffling twice with g and c/g restores order
        let z = channel_shuffle(&y, 2);
        assert_eq!(z.data, vec![0., 1., 2., 3.]);
    }

    #[test]
    fn embedding_rejects_oov() {
        let toks = TensorI32::from_vec(&[1, 1], vec![5]).unwrap();
        let table = Tensor::zeros(&[4, 2]);
        assert!(embedding(&toks, &table).is_err());
    }

    #[test]
    fn embedding_looks_up_rows() {
        let toks = TensorI32::from_vec(&[1, 2], vec![1, 0]).unwrap();
        let table = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let y = embedding(&toks, &table).unwrap();
        assert_eq!(y.data, vec![3., 4., 1., 2.]);
    }
}
