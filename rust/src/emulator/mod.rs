//! The Rust emulation engines for Table 4.
//!
//! Two engine styles over the same shared IR:
//!
//! * [`Style::Naive`] — the paper's *baseline* approximate implementation:
//!   scalar LUT lookups, no blocking, no threads.
//! * [`Style::Optimized`] — the paper's AdaPT CPU design: threadpool
//!   row-parallelism (§4.2) + hoisted-row LUT gathers with unit-stride
//!   inner loops (§4.3) + buffer reuse (§4.1).
//!
//! The third Table-4 column ("AdaPT", ours via XLA) runs through
//! [`crate::runtime`] instead: the same graph AOT-lowered with the Pallas
//! LUT kernel and executed on PJRT.
//!
//! Unlike the XLA path (one LUT literal per call), the Rust engines
//! execute *heterogeneous* plans: each quantizable node resolves its own
//! ACU through [`crate::lut::LutRegistry`], so one forward pass can mix
//! approximate multipliers per layer. All per-layer buffers live in a
//! grow-only scratch arena (see [`exec`]).

pub mod exec;
pub mod gemm;

pub use exec::{Executor, PreparedWeights, ScratchArena, Style, Value};
