//! The Rust emulation engines for Table 4.
//!
//! Two engine styles over the same shared IR:
//!
//! * [`Style::Naive`] — the paper's *baseline* approximate implementation:
//!   scalar LUT lookups, no blocking, no threads.
//! * [`Style::Optimized`] — the paper's AdaPT CPU design: threadpool
//!   row-parallelism (§4.2) + cache-blocked, SIMD-dispatched kernels
//!   (§4.3) + buffer reuse (§4.1).
//!
//! ## Kernel dispatch tiers
//!
//! The optimized engine selects its inner loops at two levels:
//!
//! 1. **Per layer (plan-time):** an ACU whose family has a closed form
//!    ([`crate::mult::Form`] — truncation, perforation, DRUM…) compiles to
//!    a *branchless bit-op kernel* that never touches a LUT
//!    ([`gemm::cf_opt_i32`]/[`gemm::cf_opt_i64`]); opaque ACUs (Mitchell,
//!    file-only LUTs) take the *vectorized-gather* LUT kernels. Mixed-ACU
//!    plans therefore pick the best kernel per node.
//! 2. **Per process (run-time):** [`simd::isa`] detects AVX2 (x86_64) or
//!    NEON (aarch64) once and every kernel dispatches to that tier, with
//!    the scalar bodies as the portable fallback (`ADAPT_NO_SIMD=1`
//!    forces them).
//!
//! **Determinism contract:** all tiers share one k-blocked reduction
//! order, so scalar/SIMD/closed-form kernels produce bit-identical
//! outputs at any `ADAPT_THREADS` value (see [`gemm`] docs and
//! `tests/kernel_equivalence.rs`). Adding a closed-form family =
//! a [`crate::mult::Form`] variant + scalar body (there) + vector body in
//! [`simd`]; the registry test and equivalence suite pin it to the
//! reference model.
//!
//! The third Table-4 column ("AdaPT", ours via XLA) runs through
//! [`crate::runtime`] instead: the same graph AOT-lowered with the Pallas
//! LUT kernel and executed on PJRT.
//!
//! Unlike the XLA path (one LUT literal per call), the Rust engines
//! execute *heterogeneous* plans: each quantizable node resolves its own
//! ACU through [`crate::lut::LutRegistry`], so one forward pass can mix
//! approximate multipliers per layer. All per-layer buffers live in a
//! grow-only scratch arena (see [`exec`]).

pub mod exec;
pub mod gemm;
pub mod simd;

pub use exec::{Executor, PreparedWeights, ScratchArena, Style, Value};
