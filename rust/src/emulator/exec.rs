//! Graph executor: runs a manifest model on the Rust GEMM engines.
//!
//! Numeric contract: identical to the L2 JAX interpreter — symmetric
//! quantization with `floor(x/s + .5)` rounding, per-tensor activation
//! scales, per-output-channel weight scales computed from the weights
//! themselves, i64 ACU accumulation, dequant `acc * (sa * sw[c]) + bias`.
//! `rust/tests/emulator_vs_xla.rs` asserts the executor and the AOT
//! artifacts agree on every model.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{ExecutionPlan, LayerMode, Model, Node, Op};
use crate::layers;
use crate::lut::Lut;
use crate::mult::MulFn;
use crate::quant;
use crate::tensor::{conv_out, im2col_f32, im2col_i32, Tensor, TensorI32};

use super::gemm;

/// Engine style (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    Naive,
    Optimized { threads: usize },
}

/// Network input (images/latents are F, token sequences are I).
#[derive(Clone, Debug)]
pub enum Value {
    F(Tensor),
    I(TensorI32),
}

impl Value {
    fn as_f(&self) -> Result<&Tensor> {
        match self {
            Value::F(t) => Ok(t),
            Value::I(_) => bail!("expected f32 value"),
        }
    }

    fn as_i(&self) -> Result<&TensorI32> {
        match self {
            Value::I(t) => Ok(t),
            Value::F(_) => bail!("expected i32 value"),
        }
    }
}

/// Functional-ACU wrappers at fixed truncation (fn-pointer friendly).
fn func_for(trunc_k: u32) -> MulFn {
    match trunc_k {
        0 => |a, b| crate::mult::exact(a, b),
        1 => |a, b| crate::mult::trunc_out(a, b, 1),
        2 => |a, b| crate::mult::trunc_out(a, b, 2),
        3 => |a, b| crate::mult::trunc_out(a, b, 3),
        4 => |a, b| crate::mult::trunc_out(a, b, 4),
        5 => |a, b| crate::mult::trunc_out(a, b, 5),
        6 => |a, b| crate::mult::trunc_out(a, b, 6),
        7 => |a, b| crate::mult::trunc_out(a, b, 7),
        _ => |a, b| crate::mult::trunc_out(a, b, 8),
    }
}

/// One pre-quantized weight matrix: (k, n) row-major + per-col scales.
/// `wq_biased` is the §Perf representation for the optimized LUT engine:
/// indices pre-offset by 2^(bits-1) so the hot loop is a bare gather.
struct QuantMat {
    wq: Vec<i32>,
    wq_biased: Vec<u16>,
    k: usize,
    n: usize,
    scales: Vec<f32>,
}

impl QuantMat {
    fn build(w: &[f32], k: usize, n: usize, bits: u32) -> QuantMat {
        let scales = quant::weight_scales_per_col(w, k, n, bits);
        let wq = quant::quantize_weights_per_col(w, k, n, bits, &scales);
        let half = 1i32 << (bits - 1);
        let wq_biased = wq.iter().map(|&v| (v + half) as u16).collect();
        QuantMat {
            wq,
            wq_biased,
            k,
            n,
            scales,
        }
    }
}

/// Prepared state for one quantizable node.
enum PreparedNode {
    Fp32 {
        /// Flattened (k, n) weight matrices, one per conv group.
        mats: Vec<(Vec<f32>, usize, usize)>,
        bias: Vec<f32>,
    },
    Quant {
        mats: Vec<QuantMat>,
        bias: Vec<f32>,
        bits: u32,
        func: Option<MulFn>, // None => LUT backend
    },
}

/// The emulator: a model + plan + scales + engine, ready to run batches.
///
/// Buffers for patches/accumulators are allocated per layer call but
/// weights are quantized exactly once at construction (§4.1's "tensors are
/// re-used without the need to copy additional data").
pub struct Executor<'m> {
    pub model: &'m Model,
    pub style: Style,
    plan: ExecutionPlan,
    act_scales: Vec<f32>,
    lut: Option<Lut>,
    params: Vec<Tensor>,
    prepared: BTreeMap<usize, PreparedNode>,
}

impl<'m> Executor<'m> {
    /// Build an executor.
    ///
    /// * `params` — fp32 parameters in manifest order.
    /// * `act_scales` — per-scale-index activation scales (calibrated);
    ///   may be empty when the plan is all-fp32.
    /// * `lut` — the ACU table for `LayerMode::ApproxLut` nodes.
    pub fn new(
        model: &'m Model,
        params: Vec<Tensor>,
        plan: ExecutionPlan,
        act_scales: Vec<f32>,
        lut: Option<Lut>,
        style: Style,
    ) -> Result<Executor<'m>> {
        if params.len() != model.params.len() {
            bail!(
                "model {} expects {} params, got {}",
                model.name,
                model.params.len(),
                params.len()
            );
        }
        let needs_scales = plan
            .modes
            .values()
            .any(|m| !matches!(m, LayerMode::Fp32));
        if needs_scales && act_scales.len() != model.n_scales {
            bail!(
                "model {} needs {} act scales, got {}",
                model.name,
                model.n_scales,
                act_scales.len()
            );
        }
        let mut ex = Executor {
            model,
            style,
            plan,
            act_scales,
            lut,
            params,
            prepared: BTreeMap::new(),
        };
        ex.prepare()?;
        Ok(ex)
    }

    /// Quantize / flatten weights per the plan (once).
    fn prepare(&mut self) -> Result<()> {
        for node in &self.model.nodes {
            if !node.op.is_quantizable() {
                continue;
            }
            let mode = *self
                .plan
                .modes
                .get(&node.id)
                .ok_or_else(|| anyhow!("plan missing node {}", node.id))?;
            let prep = match &node.op {
                Op::Conv2d {
                    kh,
                    kw,
                    cin,
                    cout,
                    groups,
                    ..
                } => {
                    let w = &self.params[node.params[0]];
                    let b = &self.params[node.params[1]];
                    let cin_g = cin / groups;
                    let cout_g = cout / groups;
                    let kf = kh * kw * cin_g;
                    // Weight tensor layout is (kh, kw, cin_g, cout): slice
                    // each group's output-channel columns.
                    let mut flats: Vec<Vec<f32>> = vec![Vec::with_capacity(kf * cout_g); *groups];
                    for row in 0..kf {
                        for g in 0..*groups {
                            let base = row * cout + g * cout_g;
                            flats[g].extend_from_slice(&w.data[base..base + cout_g]);
                        }
                    }
                    build_prepared(mode, flats, kf, cout_g, b.data.clone())
                }
                Op::Linear { din, dout, .. } => {
                    let w = &self.params[node.params[0]];
                    let b = &self.params[node.params[1]];
                    build_prepared(mode, vec![w.data.clone()], *din, *dout, b.data.clone())
                }
                Op::Lstm { din, hidden, .. } => {
                    let wx = &self.params[node.params[0]];
                    let wh = &self.params[node.params[1]];
                    let b = &self.params[node.params[2]];
                    // Two mats: index 0 = input GEMM, 1 = recurrent GEMM.
                    match mode {
                        LayerMode::Fp32 => PreparedNode::Fp32 {
                            mats: vec![
                                (wx.data.clone(), *din, 4 * hidden),
                                (wh.data.clone(), *hidden, 4 * hidden),
                            ],
                            bias: b.data.clone(),
                        },
                        LayerMode::ApproxLut => PreparedNode::Quant {
                            mats: vec![
                                QuantMat::build(&wx.data, *din, 4 * hidden, 8),
                                QuantMat::build(&wh.data, *hidden, 4 * hidden, 8),
                            ],
                            bias: b.data.clone(),
                            bits: 8,
                            func: None,
                        },
                        LayerMode::ApproxFunc { bits, trunc_k } => PreparedNode::Quant {
                            mats: vec![
                                QuantMat::build(&wx.data, *din, 4 * hidden, bits),
                                QuantMat::build(&wh.data, *hidden, 4 * hidden, bits),
                            ],
                            bias: b.data.clone(),
                            bits,
                            func: Some(func_for(trunc_k)),
                        },
                    }
                }
                _ => unreachable!(),
            };
            self.prepared.insert(node.id, prep);
        }
        Ok(())
    }

    /// GEMM dispatch honouring style + backend. x is fp32 (M, k);
    /// quantization of x happens here for quant nodes.
    fn dense(
        &self,
        node_id: usize,
        mat_idx: usize,
        x: &[f32],
        m: usize,
        scale_idx: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let prep = &self.prepared[&node_id];
        match prep {
            PreparedNode::Fp32 { mats, .. } => {
                let (w, k, n) = &mats[mat_idx];
                match self.style {
                    Style::Naive => gemm::fp32_naive(x, m, *k, w, *n, out),
                    Style::Optimized { threads } => {
                        gemm::fp32_opt(x, m, *k, w, *n, threads, out)
                    }
                }
            }
            PreparedNode::Quant {
                mats, bits, func, ..
            } => {
                let mat = &mats[mat_idx];
                // act_scales are calibrated for 8-bit; rescale the stored
                // calib_max to this node's bitwidth (mixed precision).
                let sa = self.act_scales[scale_idx]
                    * (quant::qmax_for(8) as f32 / quant::qmax_for(*bits) as f32);
                let mut xq = vec![0i32; x.len()];
                quant::quantize_slice(x, sa, *bits, &mut xq);
                self.dense_q(node_id, mat_idx, &xq, m, sa, out)?;
                let _ = (bits, func, mat);
            }
        }
        Ok(())
    }

    /// Quantized-input GEMM + dequant. The §Perf hot path: the optimized
    /// LUT engine takes the biased-u16/i32-accumulator kernel; everything
    /// else goes through the generic i64 kernels.
    fn dense_q(
        &self,
        node_id: usize,
        mat_idx: usize,
        xq: &[i32],
        m: usize,
        sa: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let PreparedNode::Quant { mats, func, .. } = &self.prepared[&node_id] else {
            bail!("dense_q on a non-quant node");
        };
        let mat = &mats[mat_idx];
        match (func, self.style) {
            (None, Style::Optimized { threads }) => {
                let lut = self.lut.as_ref().context("LUT mode without LUT")?;
                let mut acc = vec![0i32; m * mat.n];
                gemm::lut_opt_biased(
                    xq, m, mat.k, &mat.wq_biased, mat.n, lut, threads, &mut acc,
                );
                for mi in 0..m {
                    for ni in 0..mat.n {
                        out[mi * mat.n + ni] =
                            acc[mi * mat.n + ni] as f32 * (sa * mat.scales[ni]);
                    }
                }
                return Ok(());
            }
            _ => {}
        }
        let mut acc = vec![0i64; m * mat.n];
        match (func, self.style) {
            (None, Style::Naive) => {
                let lut = self.lut.as_ref().context("LUT mode without LUT")?;
                gemm::lut_naive(xq, m, mat.k, &mat.wq, mat.n, lut, &mut acc)
            }
            (Some(f), Style::Naive) => {
                gemm::func_naive(xq, m, mat.k, &mat.wq, mat.n, *f, &mut acc)
            }
            (Some(f), Style::Optimized { threads }) => {
                gemm::func_opt(xq, m, mat.k, &mat.wq, mat.n, *f, threads, &mut acc)
            }
            (None, Style::Optimized { .. }) => unreachable!(),
        }
        for mi in 0..m {
            for ni in 0..mat.n {
                out[mi * mat.n + ni] = acc[mi * mat.n + ni] as f32 * (sa * mat.scales[ni]);
            }
        }
        Ok(())
    }

    fn exec_conv(&self, node: &Node, x: &Tensor) -> Result<Tensor> {
        let (kh, kw, cin, cout, stride, pad, groups, scale_idx) = match &node.op {
            Op::Conv2d {
                kh,
                kw,
                cin,
                cout,
                stride,
                pad,
                groups,
                scale_idx,
                ..
            } => (*kh, *kw, *cin, *cout, *stride, *pad, *groups, *scale_idx),
            _ => unreachable!(),
        };
        let (n, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        anyhow::ensure!(x.shape[3] == cin, "conv input channels");
        let ho = conv_out(h, kh, stride, pad);
        let wo = conv_out(w, kw, stride, pad);
        let cin_g = cin / groups;
        let cout_g = cout / groups;
        let m = n * ho * wo;
        let bias = match &self.prepared[&node.id] {
            PreparedNode::Fp32 { bias, .. } | PreparedNode::Quant { bias, .. } => bias,
        };
        let mut out = Tensor::zeros(&[n, ho, wo, cout]);
        let mut group_out = vec![0f32; m * cout_g];

        // §Perf fast path (optimized engine, quantized node): quantize the
        // conv input ONCE (kh*kw fewer quantize ops than quantizing the
        // patch matrix) and run integer im2col. Numerically identical to
        // patch-then-quantize because q(0) == 0 (§4.1 buffer-reuse spirit).
        let quant_fast = matches!(self.style, Style::Optimized { .. })
            && matches!(&self.prepared[&node.id], PreparedNode::Quant { .. });
        if quant_fast {
            let (sa, bits) = match &self.prepared[&node.id] {
                PreparedNode::Quant { bits, .. } => (
                    self.act_scales[scale_idx]
                        * (quant::qmax_for(8) as f32 / quant::qmax_for(*bits) as f32),
                    *bits,
                ),
                _ => unreachable!(),
            };
            let mut xq = crate::tensor::TensorI32::zeros(&x.shape);
            quant::quantize_slice(&x.data, sa, bits, &mut xq.data);
            for g in 0..groups {
                let xg = if groups == 1 {
                    // no copy needed: im2col reads directly
                    im2col_i32(&xq, kh, kw, stride, pad)
                } else {
                    im2col_i32(&xq.slice_last(g * cin_g, (g + 1) * cin_g), kh, kw, stride, pad)
                };
                self.dense_q(node.id, g, &xg.data, m, sa, &mut group_out)?;
                for mi in 0..m {
                    let dst = mi * cout + g * cout_g;
                    for ci in 0..cout_g {
                        out.data[dst + ci] =
                            group_out[mi * cout_g + ci] + bias[g * cout_g + ci];
                    }
                }
            }
            return Ok(out);
        }

        for g in 0..groups {
            let xg = if groups == 1 {
                x.clone()
            } else {
                x.slice_last(g * cin_g, (g + 1) * cin_g)
            };
            // Build the fp32 patch matrix; quantization (if any) happens in
            // dense() with the layer's activation scale — numerically equal
            // to quantize-then-patch because q(0) == 0.
            let patches = im2col_f32(&xg, kh, kw, stride, pad);
            self.dense(node.id, g, &patches.data, m, scale_idx, &mut group_out)?;
            // Scatter group columns + bias into NHWC output.
            for mi in 0..m {
                let dst = mi * cout + g * cout_g;
                for ci in 0..cout_g {
                    out.data[dst + ci] = group_out[mi * cout_g + ci] + bias[g * cout_g + ci];
                }
            }
        }
        Ok(out)
    }

    fn exec_linear(&self, node: &Node, x: &Tensor) -> Result<Tensor> {
        let (dout, scale_idx) = match &node.op {
            Op::Linear {
                dout, scale_idx, ..
            } => (*dout, *scale_idx),
            _ => unreachable!(),
        };
        let m = x.shape[0];
        let bias = match &self.prepared[&node.id] {
            PreparedNode::Fp32 { bias, .. } | PreparedNode::Quant { bias, .. } => bias,
        };
        let mut out = Tensor::zeros(&[m, dout]);
        self.dense(node.id, 0, &x.data, m, scale_idx, &mut out.data)?;
        for mi in 0..m {
            for ni in 0..dout {
                out.data[mi * dout + ni] += bias[ni];
            }
        }
        Ok(out)
    }

    fn exec_lstm(&self, node: &Node, xs: &Tensor) -> Result<Tensor> {
        let (din, hidden, scale_x, scale_h) = match &node.op {
            Op::Lstm {
                din,
                hidden,
                scale_idx,
                scale_idx2,
                ..
            } => (*din, *hidden, *scale_idx, *scale_idx2),
            _ => unreachable!(),
        };
        let (n, t) = (xs.shape[0], xs.shape[1]);
        anyhow::ensure!(xs.shape[2] == din, "lstm input dim");
        let bias = match &self.prepared[&node.id] {
            PreparedNode::Fp32 { bias, .. } | PreparedNode::Quant { bias, .. } => bias,
        };
        let g4 = 4 * hidden;
        let mut h = vec![0f32; n * hidden];
        let mut c = vec![0f32; n * hidden];
        let mut x_t = vec![0f32; n * din];
        let mut gx = vec![0f32; n * g4];
        let mut gh = vec![0f32; n * g4];
        for ti in 0..t {
            for ni in 0..n {
                let src = (ni * t + ti) * din;
                x_t[ni * din..(ni + 1) * din].copy_from_slice(&xs.data[src..src + din]);
            }
            self.dense(node.id, 0, &x_t, n, scale_x, &mut gx)?;
            self.dense(node.id, 1, &h, n, scale_h, &mut gh)?;
            for ni in 0..n {
                for hi in 0..hidden {
                    let base = ni * g4;
                    let gi = gx[base + hi] + gh[base + hi] + bias[hi];
                    let gf = gx[base + hidden + hi] + gh[base + hidden + hi] + bias[hidden + hi];
                    let gg =
                        gx[base + 2 * hidden + hi] + gh[base + 2 * hidden + hi] + bias[2 * hidden + hi];
                    let go =
                        gx[base + 3 * hidden + hi] + gh[base + 3 * hidden + hi] + bias[3 * hidden + hi];
                    let i = sigmoid_s(gi);
                    let f = sigmoid_s(gf);
                    let g = gg.tanh();
                    let o = sigmoid_s(go);
                    let idx = ni * hidden + hi;
                    c[idx] = f * c[idx] + i * g;
                    h[idx] = o * c[idx].tanh();
                }
            }
        }
        Tensor::from_vec(&[n, hidden], h)
    }

    /// Run one batch through the network. Returns the output tensor.
    pub fn forward(&self, input: Value) -> Result<Tensor> {
        let mut vals: BTreeMap<usize, Value> = BTreeMap::new();
        vals.insert(0, input);
        let last = self.model.nodes.last().map(|n| n.id).unwrap_or(0);
        for node in &self.model.nodes {
            if node.id == 0 {
                continue;
            }
            let v = self.exec_node(node, &vals)?;
            // Free dead inputs eagerly? BTreeMap small; skip for clarity.
            vals.insert(node.id, Value::F(v));
        }
        match vals.remove(&last) {
            Some(Value::F(t)) => Ok(t),
            _ => bail!("model output missing"),
        }
    }

    fn exec_node(&self, node: &Node, vals: &BTreeMap<usize, Value>) -> Result<Tensor> {
        let get_f = |i: usize| -> Result<&Tensor> {
            vals.get(&node.inputs[i])
                .ok_or_else(|| anyhow!("missing input {}", node.inputs[i]))?
                .as_f()
        };
        Ok(match &node.op {
            Op::Input => unreachable!(),
            Op::Conv2d { .. } => self.exec_conv(node, get_f(0)?)?,
            Op::Linear { .. } => self.exec_linear(node, get_f(0)?)?,
            Op::Lstm { .. } => self.exec_lstm(node, get_f(0)?)?,
            Op::Embedding { .. } => {
                let toks = vals
                    .get(&node.inputs[0])
                    .ok_or_else(|| anyhow!("missing input"))?
                    .as_i()?;
                let table = &self.params[node.params[0]];
                layers::embedding(toks, table)?
            }
            Op::Relu => layers::relu(get_f(0)?.clone()),
            Op::Sigmoid => layers::sigmoid(get_f(0)?.clone()),
            Op::Tanh => layers::tanh(get_f(0)?.clone()),
            Op::AvgPool2 => layers::avgpool2(get_f(0)?),
            Op::Gap => layers::gap(get_f(0)?),
            Op::Flatten => layers::flatten(get_f(0)?.clone()),
            Op::Add => get_f(0)?.add(get_f(1)?)?,
            Op::Concat => {
                let parts: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|i| vals[i].as_f())
                    .collect::<Result<_>>()?;
                Tensor::concat_last(&parts)?
            }
            Op::ChannelShuffle { groups } => layers::channel_shuffle(get_f(0)?, *groups),
            Op::SliceLast { start, end } => get_f(0)?.slice_last(*start, *end),
            Op::Reshape { shape } => {
                let x = get_f(0)?.clone();
                let n = x.shape[0];
                let mut full = vec![n];
                full.extend_from_slice(shape);
                x.reshape(&full)?
            }
        })
    }
}

fn build_prepared(
    mode: LayerMode,
    flats: Vec<Vec<f32>>,
    k: usize,
    n: usize,
    bias: Vec<f32>,
) -> PreparedNode {
    match mode {
        LayerMode::Fp32 => PreparedNode::Fp32 {
            mats: flats.into_iter().map(|w| (w, k, n)).collect(),
            bias,
        },
        LayerMode::ApproxLut => PreparedNode::Quant {
            mats: flats
                .into_iter()
                .map(|w| QuantMat::build(&w, k, n, 8))
                .collect(),
            bias,
            bits: 8,
            func: None,
        },
        LayerMode::ApproxFunc { bits, trunc_k } => PreparedNode::Quant {
            mats: flats
                .into_iter()
                .map(|w| QuantMat::build(&w, k, n, bits))
                .collect(),
            bias,
            bits,
            func: Some(func_for(trunc_k)),
        },
    }
}

#[inline(always)]
fn sigmoid_s(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}
