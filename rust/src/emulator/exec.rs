//! Graph executor: runs a manifest model on the Rust GEMM engines.
//!
//! Numeric contract: identical to the L2 JAX interpreter — symmetric
//! quantization with `floor(x/s + .5)` rounding, per-tensor activation
//! scales, per-output-channel weight scales computed from the weights
//! themselves, i64 ACU accumulation, dequant `acc * (sa * sw[c]) + bias`.
//! `rust/tests/emulator_vs_xla.rs` asserts the executor and the AOT
//! artifacts agree on every model.
//!
//! ## Heterogeneous plans
//!
//! Every quantizable node carries its own backend identity
//! ([`LayerMode::ApproxLut`] names an ACU, [`LayerMode::ApproxFunc`] a
//! behavioral function), resolved once at construction through a shared
//! [`LutRegistry`] — so one forward pass can route different layers
//! through different approximate multipliers, and twenty layers on the
//! same ACU share one `Arc<Lut>` table.
//!
//! ## Scratch arena (§Perf)
//!
//! The seed executor allocated im2col patch matrices, quantized-activation
//! buffers and accumulators on every layer call. All of those now live in
//! a grow-only [`Scratch`] arena owned by the executor: the first forward
//! sizes each buffer to the model's largest layer, and every later layer
//! and batch reuses the same allocations. Node *output* tensors recycle
//! through a small free-list driven by static liveness (a value's storage
//! is returned to the pool right after its last consumer runs). Steady
//! state performs zero per-layer heap allocations on the GEMM hot path;
//! `benches/multiplier_ablation.rs` A/B-checks this against the seed's
//! alloc-per-call behavior via [`Executor::set_scratch_reuse`].
//!
//! The arena is also *detachable* ([`ScratchArena`]): a pool worker that
//! evaluates many plans builds one executor per plan but threads the same
//! warm arena through all of them ([`Executor::with_arena`] /
//! [`Executor::into_arena`]), so the parallel sensitivity sweep allocates
//! per worker, not per (layer, ACU) candidate.

use std::cell::{RefCell, RefMut};
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::graph::{ExecutionPlan, LayerMode, Model, Node, Op};
use crate::layers;
use crate::lut::{Lut, LutRegistry};
use crate::mult::{Form, MulFn};
use crate::obs::LayerProfiler;
use crate::quant;
use crate::tensor::{
    conv_out, im2col_f32_range_into, im2col_i32_range_into, numel, Tensor, TensorI32,
};

use super::gemm;

/// Engine style (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    Naive,
    Optimized { threads: usize },
}

/// Network input (images/latents are F, token sequences are I).
#[derive(Clone, Debug)]
pub enum Value {
    F(Tensor),
    I(TensorI32),
}

/// Functional-ACU wrappers at fixed truncation (fn-pointer friendly).
fn func_for(trunc_k: u32) -> MulFn {
    match trunc_k {
        0 => |a, b| crate::mult::exact(a, b),
        1 => |a, b| crate::mult::trunc_out(a, b, 1),
        2 => |a, b| crate::mult::trunc_out(a, b, 2),
        3 => |a, b| crate::mult::trunc_out(a, b, 3),
        4 => |a, b| crate::mult::trunc_out(a, b, 4),
        5 => |a, b| crate::mult::trunc_out(a, b, 5),
        6 => |a, b| crate::mult::trunc_out(a, b, 6),
        7 => |a, b| crate::mult::trunc_out(a, b, 7),
        _ => |a, b| crate::mult::trunc_out(a, b, 8),
    }
}

/// Closed-form descriptor matching [`func_for`] exactly (both truncate
/// the exact product by `trunc_k` bits).
fn form_for_trunc(trunc_k: u32) -> Form {
    match trunc_k {
        0 => Form::Exact,
        k => Form::TruncOut(k.min(8)),
    }
}

/// Closed-form descriptor for a LUT-backed node, when its ACU name
/// resolves to a registry model with one. File-only LUTs (names outside
/// the behavioral registry) keep the gather path; name-based selection is
/// sound because `tests/lut_cross_check.rs` pins every shipped LUT
/// artifact to its registry model. Gated to 8-bit tables: the closed
/// path accumulates in i32 (the `lut_opt_biased` contract), which wider
/// products could overflow.
fn closed_form_for(acu: &str, bits: u32) -> Option<Form> {
    if bits > 8 {
        return None;
    }
    let form = crate::mult::get(acu).ok()?.form;
    form.is_closed().then_some(form)
}

/// One pre-quantized weight matrix: (k, n) row-major + per-col scales.
/// `wq_biased` is the §Perf representation for the optimized LUT engine:
/// indices pre-offset by 2^(bits-1) so the hot loop is a bare gather.
struct QuantMat {
    wq: Vec<i32>,
    wq_biased: Vec<u16>,
    k: usize,
    n: usize,
    scales: Vec<f32>,
}

impl QuantMat {
    fn build(w: &[f32], k: usize, n: usize, bits: u32) -> QuantMat {
        let scales = quant::weight_scales_per_col(w, k, n, bits);
        let wq = quant::quantize_weights_per_col(w, k, n, bits, &scales);
        let half = 1i32 << (bits - 1);
        let wq_biased = wq.iter().map(|&v| (v + half) as u16).collect();
        QuantMat {
            wq,
            wq_biased,
            k,
            n,
            scales,
        }
    }
}

/// Resolved product backend for one quantized node. `form` is the
/// kernel-compilation handle: when the node's ACU has a closed form, the
/// optimized engine lowers it to the branchless `cf_opt_*` kernels and
/// never touches the LUT / function pointer on the hot path (the naive
/// engine always uses the table/function — it is the paper's baseline).
enum Backend {
    /// Shared ACU table (resolved from the plan's ACU name).
    Lut { lut: Arc<Lut>, form: Option<Form> },
    /// Behavioral multiplier function (large-bitwidth fallback).
    Func { f: MulFn, form: Option<Form> },
}

/// A model's weights quantized/flattened for one plan, shareable across
/// executors behind an `Arc`: an engine pool quantizes once
/// ([`Executor::prepare_weights`]) and every worker adopts the same
/// tables via [`Executor::with_prepared`] instead of re-quantizing its
/// own copy. Cheap to clone (one atomic increment).
#[derive(Clone)]
pub struct PreparedWeights(Arc<BTreeMap<usize, PreparedNode>>);

/// Prepared state for one quantizable node.
enum PreparedNode {
    Fp32 {
        /// Flattened (k, n) weight matrices, one per conv group.
        mats: Vec<(Vec<f32>, usize, usize)>,
        bias: Vec<f32>,
    },
    Quant {
        mats: Vec<QuantMat>,
        bias: Vec<f32>,
        bits: u32,
        backend: Backend,
    },
}

impl PreparedNode {
    fn bias(&self) -> &[f32] {
        match self {
            PreparedNode::Fp32 { bias, .. } | PreparedNode::Quant { bias, .. } => bias,
        }
    }
}

/// A grow-only scratch buffer with interior mutability. Distinct buffers
/// are distinct fields of [`Scratch`], so borrows never overlap.
struct Buf<T>(RefCell<Vec<T>>);

impl<T: Default + Clone> Buf<T> {
    fn new() -> Buf<T> {
        Buf(RefCell::new(Vec::new()))
    }

    /// Borrow at least `len` elements. With `reuse = false` the buffer is
    /// reallocated fresh every call — the seed's alloc-per-call behavior,
    /// kept selectable for the ablation bench's A/B comparison.
    fn grab(&self, len: usize, reuse: bool) -> RefMut<'_, Vec<T>> {
        let mut v = self.0.borrow_mut();
        if !reuse {
            *v = vec![T::default(); len];
        } else if v.len() < len {
            let grow = len - v.len();
            v.reserve(grow);
            v.resize(len, T::default());
        }
        v
    }
}

/// Max pooled output buffers retained between layers.
const POOL_CAP: usize = 32;

/// The executor's reusable buffers (see module docs).
struct Scratch {
    /// Quantized activations (conv fast path and dense quantization).
    xq: Buf<i32>,
    /// Integer im2col patch matrix (optimized quant conv).
    patches_i: Buf<i32>,
    /// f32 im2col patch matrix (fp32 / naive conv).
    patches_f: Buf<f32>,
    /// i32 accumulators (optimized biased-LUT kernel).
    acc32: Buf<i32>,
    /// i64 accumulators (generic kernels).
    acc64: Buf<i64>,
    /// Per-group conv output staging.
    group_out: Buf<f32>,
    // LSTM per-step state and gate buffers.
    lstm_h: Buf<f32>,
    lstm_c: Buf<f32>,
    lstm_x: Buf<f32>,
    lstm_gx: Buf<f32>,
    lstm_gh: Buf<f32>,
    /// Free-list of recycled node-output storage.
    pool: RefCell<Vec<Vec<f32>>>,
    /// Dense value table reused across forwards (indexed by node id).
    vals: RefCell<Vec<Option<Value>>>,
}

/// An executor's scratch arena as a detachable handle.
///
/// A long-lived worker (engine-pool worker, sensitivity-sweep pool worker)
/// builds many short-lived executors — one per plan — but wants the warm
/// grow-only buffers to survive from one executor to the next. Construct
/// with [`Executor::with_arena`] and reclaim with [`Executor::into_arena`];
/// buffer reuse across executors is behavior-neutral for the same reason
/// reuse across batches is (every buffer is fully (re)written or cleared
/// before use).
pub struct ScratchArena(Scratch);

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena(Scratch::new())
    }
}

impl Default for ScratchArena {
    fn default() -> ScratchArena {
        ScratchArena::new()
    }
}

impl Scratch {
    fn new() -> Scratch {
        Scratch {
            xq: Buf::new(),
            patches_i: Buf::new(),
            patches_f: Buf::new(),
            acc32: Buf::new(),
            acc64: Buf::new(),
            group_out: Buf::new(),
            lstm_h: Buf::new(),
            lstm_c: Buf::new(),
            lstm_x: Buf::new(),
            lstm_gx: Buf::new(),
            lstm_gh: Buf::new(),
            pool: RefCell::new(Vec::new()),
            vals: RefCell::new(Vec::new()),
        }
    }
}

fn get_f(vals: &[Option<Value>], id: usize) -> Result<&Tensor> {
    match vals.get(id).and_then(|v| v.as_ref()) {
        Some(Value::F(t)) => Ok(t),
        Some(Value::I(_)) => bail!("expected f32 value for input {id}"),
        None => bail!("missing input {id}"),
    }
}

fn get_i(vals: &[Option<Value>], id: usize) -> Result<&TensorI32> {
    match vals.get(id).and_then(|v| v.as_ref()) {
        Some(Value::I(t)) => Ok(t),
        Some(Value::F(_)) => bail!("expected i32 value for input {id}"),
        None => bail!("missing input {id}"),
    }
}

/// The emulator: a model + plan + scales + engine, ready to run batches.
///
/// Weights are quantized exactly once at construction (§4.1's "tensors are
/// re-used without the need to copy additional data"); activations, patch
/// matrices and accumulators live in the scratch arena.
pub struct Executor<'m> {
    pub model: &'m Model,
    pub style: Style,
    plan: ExecutionPlan,
    act_scales: Vec<f32>,
    params: Vec<Tensor>,
    prepared: Arc<BTreeMap<usize, PreparedNode>>,
    /// value id -> index (into `model.nodes`) of its last consumer.
    last_use: BTreeMap<usize, usize>,
    scratch: Scratch,
    reuse_scratch: bool,
    /// Optional per-layer kernel profiler. `None` (the default) keeps
    /// [`Executor::forward`] exactly on the un-instrumented path;
    /// attached-but-disabled costs one relaxed load per forward.
    profiler: Option<Arc<LayerProfiler>>,
}

impl<'m> Executor<'m> {
    /// Build an executor.
    ///
    /// * `params` — fp32 parameters in manifest order.
    /// * `act_scales` — per-scale-index activation scales (calibrated);
    ///   may be empty when the plan is all-fp32.
    /// * `luts` — the shared ACU registry; every `ApproxLut` node's ACU
    ///   name is resolved through it exactly once, here.
    pub fn new(
        model: &'m Model,
        params: Vec<Tensor>,
        plan: ExecutionPlan,
        act_scales: Vec<f32>,
        luts: &LutRegistry,
        style: Style,
    ) -> Result<Executor<'m>> {
        Executor::with_arena(
            model,
            params,
            plan,
            act_scales,
            luts,
            style,
            ScratchArena::new(),
        )
    }

    /// [`Executor::new`], but adopting an existing scratch arena (e.g. one
    /// reclaimed via [`Executor::into_arena`] from a previous plan's
    /// executor on the same worker thread).
    #[allow(clippy::too_many_arguments)]
    pub fn with_arena(
        model: &'m Model,
        params: Vec<Tensor>,
        plan: ExecutionPlan,
        act_scales: Vec<f32>,
        luts: &LutRegistry,
        style: Style,
        arena: ScratchArena,
    ) -> Result<Executor<'m>> {
        let prepared = Executor::prepare_weights(model, &params, &plan, luts)?;
        Executor::with_prepared(model, params, plan, act_scales, style, prepared, arena)
    }

    /// Quantize / flatten `params` per `plan` and resolve every node's ACU
    /// backend — once — into a shareable [`PreparedWeights`]. An engine
    /// pool calls this a single time and hands the same `Arc` to every
    /// worker's [`Executor::with_prepared`].
    pub fn prepare_weights(
        model: &Model,
        params: &[Tensor],
        plan: &ExecutionPlan,
        luts: &LutRegistry,
    ) -> Result<PreparedWeights> {
        if params.len() != model.params.len() {
            bail!(
                "model {} expects {} params, got {}",
                model.name,
                model.params.len(),
                params.len()
            );
        }
        Ok(PreparedWeights(Arc::new(prepare_nodes(
            model, params, plan, luts,
        )?)))
    }

    /// [`Executor::with_arena`], but adopting weights already quantized by
    /// [`Executor::prepare_weights`] instead of re-quantizing. `prepared`
    /// must have been built from the same (model, params, plan) triple —
    /// node coverage is re-validated here, content equality is the
    /// caller's contract.
    #[allow(clippy::too_many_arguments)]
    pub fn with_prepared(
        model: &'m Model,
        params: Vec<Tensor>,
        plan: ExecutionPlan,
        act_scales: Vec<f32>,
        style: Style,
        prepared: PreparedWeights,
        arena: ScratchArena,
    ) -> Result<Executor<'m>> {
        if params.len() != model.params.len() {
            bail!(
                "model {} expects {} params, got {}",
                model.name,
                model.params.len(),
                params.len()
            );
        }
        let needs_scales = plan.modes.values().any(|m| !matches!(m, LayerMode::Fp32));
        if needs_scales && act_scales.len() != model.n_scales {
            bail!(
                "model {} needs {} act scales, got {}",
                model.name,
                model.n_scales,
                act_scales.len()
            );
        }
        for node in &model.nodes {
            if node.op.is_quantizable() && !prepared.0.contains_key(&node.id) {
                bail!("prepared weights miss quantizable node {}", node.id);
            }
        }
        let mut last_use = BTreeMap::new();
        for (idx, node) in model.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last_use.insert(inp, idx);
            }
        }
        Ok(Executor {
            model,
            style,
            plan,
            act_scales,
            params,
            prepared: prepared.0,
            last_use,
            scratch: arena.0,
            reuse_scratch: true,
            profiler: None,
        })
    }

    /// Attach (or detach) a per-layer kernel profiler. The engine pool
    /// attaches its shared profiler to every worker's executors;
    /// `adapt profile` attaches an always-enabled one.
    pub fn set_profiler(&mut self, profiler: Option<Arc<LayerProfiler>>) {
        self.profiler = profiler;
    }

    /// The plan this executor was built from.
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Tear down the executor, reclaiming its (warm) scratch arena for the
    /// next executor on this worker.
    pub fn into_arena(self) -> ScratchArena {
        ScratchArena(self.scratch)
    }

    /// Toggle scratch reuse. `false` restores the seed's alloc-per-call
    /// behavior (every buffer reallocated fresh) — only useful for the
    /// ablation bench's before/after comparison. Default: `true`.
    pub fn set_scratch_reuse(&mut self, reuse: bool) {
        self.reuse_scratch = reuse;
        if !reuse {
            self.scratch.pool.borrow_mut().clear();
        }
    }

    /// Pop a cleared pool buffer with capacity >= `len` (best fit), if any.
    fn pool_take(&self, len: usize) -> Option<Vec<f32>> {
        if !self.reuse_scratch {
            return None;
        }
        let mut pool = self.scratch.pool.borrow_mut();
        let best = pool
            .iter()
            .enumerate()
            .filter(|(_, v)| v.capacity() >= len)
            .min_by_key(|(_, v)| v.capacity())
            .map(|(i, _)| i)?;
        let mut v = pool.swap_remove(best);
        v.clear();
        Some(v)
    }

    /// Take a pooled f32 buffer of exactly `len` (zero-initialized).
    fn pooled_vec(&self, len: usize) -> Vec<f32> {
        match self.pool_take(len) {
            Some(mut v) => {
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Take a pooled buffer initialized as a copy of `src` (no zero pass).
    fn pooled_vec_copy(&self, src: &[f32]) -> Vec<f32> {
        match self.pool_take(src.len()) {
            Some(mut v) => {
                v.extend_from_slice(src);
                v
            }
            None => src.to_vec(),
        }
    }

    /// Node-output tensor backed by the recycle pool.
    fn pooled_tensor(&self, shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: self.pooled_vec(numel(shape)),
        }
    }

    /// Return dead value storage to the pool.
    fn recycle(&self, data: Vec<f32>) {
        if !self.reuse_scratch || data.capacity() == 0 {
            return;
        }
        let mut pool = self.scratch.pool.borrow_mut();
        if pool.len() < POOL_CAP {
            pool.push(data);
        }
    }

    /// Move the input out of the value table when this node is its last
    /// consumer (elementwise ops then run in place, alloc- and copy-free);
    /// otherwise copy it into a pooled tensor. Taped forwards always copy —
    /// every intermediate must survive for the backward pass.
    fn take_or_copy_f(
        &self,
        idx: usize,
        id: usize,
        vals: &mut [Option<Value>],
        taped: bool,
    ) -> Result<Tensor> {
        if !taped && self.last_use.get(&id) == Some(&idx) {
            match vals[id].take() {
                Some(Value::F(t)) => return Ok(t),
                Some(v) => {
                    vals[id] = Some(v);
                    bail!("expected f32 value for input {id}");
                }
                None => bail!("missing input {id}"),
            }
        }
        let src = get_f(vals, id)?;
        Ok(Tensor {
            shape: src.shape.clone(),
            data: self.pooled_vec_copy(&src.data),
        })
    }

    /// GEMM dispatch honouring style + backend. x is fp32 (M, k);
    /// quantization of x happens here for quant nodes.
    fn dense(
        &self,
        node_id: usize,
        mat_idx: usize,
        x: &[f32],
        m: usize,
        scale_idx: usize,
        out: &mut [f32],
    ) -> Result<()> {
        match &self.prepared[&node_id] {
            PreparedNode::Fp32 { mats, .. } => {
                let (w, k, n) = &mats[mat_idx];
                match self.style {
                    Style::Naive => gemm::fp32_naive(x, m, *k, w, *n, out),
                    Style::Optimized { threads } => gemm::fp32_opt(x, m, *k, w, *n, threads, out),
                }
            }
            PreparedNode::Quant { bits, .. } => {
                // act_scales are calibrated for 8-bit; rescale the stored
                // calib_max to this node's bitwidth (mixed precision).
                let sa = self.act_scales[scale_idx]
                    * (quant::qmax_for(8) as f32 / quant::qmax_for(*bits) as f32);
                let bits = *bits;
                let mut xq = self.scratch.xq.grab(x.len(), self.reuse_scratch);
                let xq = &mut xq[..x.len()];
                quant::quantize_slice(x, sa, bits, xq);
                self.dense_q(node_id, mat_idx, xq, m, sa, out)?;
            }
        }
        Ok(())
    }

    /// Quantized-input GEMM + dequant. The §Perf hot path: the optimized
    /// LUT engine takes a closed-form branchless kernel when the node's
    /// ACU has one, else the biased-u16/i32-accumulator gather kernel;
    /// everything else goes through the generic i64 kernels. The LUT is
    /// the *node's own* table — different nodes may gather from
    /// different ACUs.
    fn dense_q(
        &self,
        node_id: usize,
        mat_idx: usize,
        xq: &[i32],
        m: usize,
        sa: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let PreparedNode::Quant { mats, backend, .. } = &self.prepared[&node_id] else {
            bail!("dense_q on a non-quant node");
        };
        let mat = &mats[mat_idx];
        if let (Backend::Lut { lut, form }, Style::Optimized { threads }) = (backend, self.style) {
            let mut acc = self.scratch.acc32.grab(m * mat.n, self.reuse_scratch);
            let acc = &mut acc[..m * mat.n];
            match form {
                // Kernel-compilation tier: branchless bit ops, no LUT.
                Some(f) => gemm::cf_opt_i32(xq, m, mat.k, &mat.wq, mat.n, *f, threads, acc),
                // Opaque ACU: vectorized-gather LUT kernel.
                None => {
                    gemm::lut_opt_biased(xq, m, mat.k, &mat.wq_biased, mat.n, lut, threads, acc)
                }
            }
            for mi in 0..m {
                for ni in 0..mat.n {
                    out[mi * mat.n + ni] = acc[mi * mat.n + ni] as f32 * (sa * mat.scales[ni]);
                }
            }
            return Ok(());
        }
        let mut acc = self.scratch.acc64.grab(m * mat.n, self.reuse_scratch);
        let acc = &mut acc[..m * mat.n];
        match (backend, self.style) {
            (Backend::Lut { lut, .. }, Style::Naive) => {
                gemm::lut_naive(xq, m, mat.k, &mat.wq, mat.n, lut, acc)
            }
            (Backend::Func { f, .. }, Style::Naive) => {
                gemm::func_naive(xq, m, mat.k, &mat.wq, mat.n, *f, acc)
            }
            (Backend::Func { f, form }, Style::Optimized { threads }) => match form {
                Some(cf) => gemm::cf_opt_i64(xq, m, mat.k, &mat.wq, mat.n, *cf, threads, acc),
                None => gemm::func_opt(xq, m, mat.k, &mat.wq, mat.n, *f, threads, acc),
            },
            (Backend::Lut { .. }, Style::Optimized { .. }) => unreachable!(),
        }
        for mi in 0..m {
            for ni in 0..mat.n {
                out[mi * mat.n + ni] = acc[mi * mat.n + ni] as f32 * (sa * mat.scales[ni]);
            }
        }
        Ok(())
    }

    fn exec_conv(&self, node: &Node, x: &Tensor) -> Result<Tensor> {
        let (kh, kw, cin, cout, stride, pad, groups, scale_idx) = match &node.op {
            Op::Conv2d {
                kh,
                kw,
                cin,
                cout,
                stride,
                pad,
                groups,
                scale_idx,
                ..
            } => (*kh, *kw, *cin, *cout, *stride, *pad, *groups, *scale_idx),
            _ => unreachable!(),
        };
        let (n, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        anyhow::ensure!(x.shape[3] == cin, "conv input channels");
        let ho = conv_out(h, kh, stride, pad);
        let wo = conv_out(w, kw, stride, pad);
        let cin_g = cin / groups;
        let cout_g = cout / groups;
        let kf = kh * kw * cin_g;
        let m = n * ho * wo;
        let reuse = self.reuse_scratch;
        let prep = &self.prepared[&node.id];
        let bias = prep.bias();
        let mut out = self.pooled_tensor(&[n, ho, wo, cout]);
        let mut group_out = self.scratch.group_out.grab(m * cout_g, reuse);
        let group_out = &mut group_out[..m * cout_g];

        // §Perf fast path (optimized engine, quantized node): quantize the
        // conv input ONCE (kh*kw fewer quantize ops than quantizing the
        // patch matrix) and run integer im2col. Numerically identical to
        // patch-then-quantize because q(0) == 0 (§4.1 buffer-reuse spirit).
        let quant_fast = matches!(self.style, Style::Optimized { .. })
            && matches!(prep, PreparedNode::Quant { .. });
        if quant_fast {
            let PreparedNode::Quant { bits, .. } = prep else {
                unreachable!()
            };
            let sa = self.act_scales[scale_idx]
                * (quant::qmax_for(8) as f32 / quant::qmax_for(*bits) as f32);
            let mut xq = self.scratch.xq.grab(x.data.len(), reuse);
            let xq = &mut xq[..x.data.len()];
            quant::quantize_slice(&x.data, sa, *bits, xq);
            let mut patches = self.scratch.patches_i.grab(m * kf, reuse);
            let patches = &mut patches[..m * kf];
            for g in 0..groups {
                im2col_i32_range_into(
                    xq,
                    &x.shape,
                    kh,
                    kw,
                    stride,
                    pad,
                    g * cin_g,
                    (g + 1) * cin_g,
                    patches,
                );
                self.dense_q(node.id, g, patches, m, sa, group_out)?;
                for mi in 0..m {
                    let dst = mi * cout + g * cout_g;
                    for ci in 0..cout_g {
                        out.data[dst + ci] = group_out[mi * cout_g + ci] + bias[g * cout_g + ci];
                    }
                }
            }
            return Ok(out);
        }

        // Build the fp32 patch matrix per group; quantization (if any)
        // happens in dense() with the layer's activation scale —
        // numerically equal to quantize-then-patch because q(0) == 0.
        let mut patches = self.scratch.patches_f.grab(m * kf, reuse);
        let patches = &mut patches[..m * kf];
        for g in 0..groups {
            im2col_f32_range_into(
                &x.data,
                &x.shape,
                kh,
                kw,
                stride,
                pad,
                g * cin_g,
                (g + 1) * cin_g,
                patches,
            );
            self.dense(node.id, g, patches, m, scale_idx, group_out)?;
            // Scatter group columns + bias into NHWC output.
            for mi in 0..m {
                let dst = mi * cout + g * cout_g;
                for ci in 0..cout_g {
                    out.data[dst + ci] = group_out[mi * cout_g + ci] + bias[g * cout_g + ci];
                }
            }
        }
        Ok(out)
    }

    fn exec_linear(&self, node: &Node, x: &Tensor) -> Result<Tensor> {
        let (dout, scale_idx) = match &node.op {
            Op::Linear {
                dout, scale_idx, ..
            } => (*dout, *scale_idx),
            _ => unreachable!(),
        };
        let m = x.shape[0];
        let bias = self.prepared[&node.id].bias();
        let mut out = self.pooled_tensor(&[m, dout]);
        self.dense(node.id, 0, &x.data, m, scale_idx, &mut out.data)?;
        for mi in 0..m {
            for ni in 0..dout {
                out.data[mi * dout + ni] += bias[ni];
            }
        }
        Ok(out)
    }

    fn exec_lstm(&self, node: &Node, xs: &Tensor) -> Result<Tensor> {
        let (din, hidden, scale_x, scale_h) = match &node.op {
            Op::Lstm {
                din,
                hidden,
                scale_idx,
                scale_idx2,
                ..
            } => (*din, *hidden, *scale_idx, *scale_idx2),
            _ => unreachable!(),
        };
        let (n, t) = (xs.shape[0], xs.shape[1]);
        anyhow::ensure!(xs.shape[2] == din, "lstm input dim");
        let bias = self.prepared[&node.id].bias();
        let g4 = 4 * hidden;
        let reuse = self.reuse_scratch;
        let mut h = self.scratch.lstm_h.grab(n * hidden, reuse);
        let h = &mut h[..n * hidden];
        let mut c = self.scratch.lstm_c.grab(n * hidden, reuse);
        let c = &mut c[..n * hidden];
        h.fill(0.0);
        c.fill(0.0);
        let mut x_t = self.scratch.lstm_x.grab(n * din, reuse);
        let x_t = &mut x_t[..n * din];
        let mut gx = self.scratch.lstm_gx.grab(n * g4, reuse);
        let gx = &mut gx[..n * g4];
        let mut gh = self.scratch.lstm_gh.grab(n * g4, reuse);
        let gh = &mut gh[..n * g4];
        for ti in 0..t {
            for ni in 0..n {
                let src = (ni * t + ti) * din;
                x_t[ni * din..(ni + 1) * din].copy_from_slice(&xs.data[src..src + din]);
            }
            self.dense(node.id, 0, x_t, n, scale_x, gx)?;
            self.dense(node.id, 1, h, n, scale_h, gh)?;
            for ni in 0..n {
                for hi in 0..hidden {
                    let base = ni * g4;
                    let gi = gx[base + hi] + gh[base + hi] + bias[hi];
                    let gf = gx[base + hidden + hi] + gh[base + hidden + hi] + bias[hidden + hi];
                    let gg = gx[base + 2 * hidden + hi]
                        + gh[base + 2 * hidden + hi]
                        + bias[2 * hidden + hi];
                    let go = gx[base + 3 * hidden + hi]
                        + gh[base + 3 * hidden + hi]
                        + bias[3 * hidden + hi];
                    let i = sigmoid_s(gi);
                    let f = sigmoid_s(gf);
                    let g = gg.tanh();
                    let o = sigmoid_s(go);
                    let idx = ni * hidden + hi;
                    c[idx] = f * c[idx] + i * g;
                    h[idx] = o * c[idx].tanh();
                }
            }
        }
        let mut out = self.pooled_tensor(&[n, hidden]);
        out.data.copy_from_slice(h);
        Ok(out)
    }

    /// Run one batch through the network. Returns the output tensor.
    ///
    /// When a [`LayerProfiler`] is attached *and* enabled (one relaxed
    /// load decides, once per forward), every node is additionally wall
    /// timed and recorded with its resolved kernel identity; otherwise
    /// this is the bare execution loop.
    pub fn forward(&self, input: Value) -> Result<Tensor> {
        let nvals = self.model.nodes.iter().map(|n| n.id).max().unwrap_or(0) + 1;
        let mut vals = self.scratch.vals.borrow_mut();
        vals.clear();
        vals.resize_with(nvals, || None);
        vals[0] = Some(input);
        let last = self.model.nodes.last().map(|n| n.id).unwrap_or(0);
        let prof = self.profiler.as_deref().filter(|p| p.enabled());
        for (idx, node) in self.model.nodes.iter().enumerate() {
            if node.id == 0 {
                continue;
            }
            let v = match prof {
                None => self.exec_node(idx, node, &mut vals[..], false)?,
                Some(p) => {
                    let t0 = std::time::Instant::now();
                    let v = self.exec_node(idx, node, &mut vals[..], false)?;
                    let ns = t0.elapsed().as_nanos() as u64;
                    self.record_node(p, idx, node, &vals[..], &v, ns);
                    v
                }
            };
            // Recycle inputs whose last consumer just ran: their storage
            // backs later layers' outputs instead of hitting the allocator.
            for &inp in &node.inputs {
                if self.last_use.get(&inp) == Some(&idx) {
                    if let Some(Value::F(t)) = vals[inp].take() {
                        self.recycle(t.data);
                    }
                }
            }
            vals[node.id] = Some(Value::F(v));
        }
        match vals[last].take() {
            Some(Value::F(t)) => Ok(t),
            _ => bail!("model output missing"),
        }
    }

    /// Profile one executed node: key `"{idx:03}:{name}"` (execution
    /// order), kernel identity (SIMD tier + product backend + bits), and
    /// the batch's MAC count derived from the op and output shape.
    fn record_node(
        &self,
        p: &LayerProfiler,
        idx: usize,
        node: &Node,
        vals: &[Option<Value>],
        out: &Tensor,
        ns: u64,
    ) {
        let tier = match super::simd::isa() {
            super::simd::Isa::Scalar => "scalar",
            super::simd::Isa::Avx2 => "avx2",
            super::simd::Isa::Neon => "neon",
        };
        let kind = op_kind(&node.op);
        let (backend, bits) = self.node_backend(node);
        let macs = node_macs(node, vals, out);
        let name = node.op.layer_name().unwrap_or(kind);
        p.record(
            &format!("{idx:03}:{name}"),
            kind,
            tier,
            backend,
            bits,
            macs,
            ns,
        );
    }

    /// Resolved product backend label + bitwidth for a node. Closed-form
    /// lowering only happens on the optimized engine; the naive engine
    /// always walks the table / function (the paper's baseline).
    fn node_backend(&self, node: &Node) -> (&'static str, u32) {
        let lowered = matches!(self.style, Style::Optimized { .. });
        match self.prepared.get(&node.id) {
            Some(PreparedNode::Fp32 { .. }) => ("fp32", 0),
            Some(PreparedNode::Quant { bits, backend, .. }) => {
                let label = match backend {
                    Backend::Lut { form, .. } => {
                        if lowered && form.is_some() {
                            "closed-form"
                        } else {
                            "lut"
                        }
                    }
                    Backend::Func { form, .. } => {
                        if lowered && form.is_some() {
                            "closed-form"
                        } else {
                            "func"
                        }
                    }
                };
                (label, *bits)
            }
            None => ("none", 0),
        }
    }

    /// Training-mode forward: node-by-node identical to [`forward`] (same
    /// kernels, same quantization, same scratch buffers), but every node's
    /// output is retained — no in-place moves, no output recycling — and
    /// the whole value table is returned as the backward pass's tape
    /// (index = node id; see [`crate::trainer::grad::backward`]).
    pub fn forward_taped(&self, input: Value) -> Result<Vec<Option<Value>>> {
        let nvals = self.model.nodes.iter().map(|n| n.id).max().unwrap_or(0) + 1;
        let mut vals: Vec<Option<Value>> = Vec::new();
        vals.resize_with(nvals, || None);
        vals[0] = Some(input);
        for (idx, node) in self.model.nodes.iter().enumerate() {
            if node.id == 0 {
                continue;
            }
            let v = self.exec_node(idx, node, &mut vals[..], true)?;
            vals[node.id] = Some(Value::F(v));
        }
        Ok(vals)
    }

    /// The STE backward surface of one prepared quantizable node: per-mat
    /// `(weights, k, n)` as the straight-through estimator sees them — the
    /// raw fp32 mats for `Fp32` nodes, the *dequantized* quantized mats
    /// (`wq * per-col scale`, i.e. fake-quant weights) for quant nodes —
    /// plus the node's activation bitwidth (`None` for fp32).
    #[allow(clippy::type_complexity)]
    pub(crate) fn ste_mats(
        &self,
        node_id: usize,
    ) -> Option<(Vec<(Vec<f32>, usize, usize)>, Option<u32>)> {
        match self.prepared.get(&node_id)? {
            PreparedNode::Fp32 { mats, .. } => Some((mats.clone(), None)),
            PreparedNode::Quant { mats, bits, .. } => {
                let dq = mats
                    .iter()
                    .map(|m| {
                        let mut w = vec![0f32; m.k * m.n];
                        for ki in 0..m.k {
                            for (ni, o) in w[ki * m.n..(ki + 1) * m.n].iter_mut().enumerate() {
                                *o = m.wq[ki * m.n + ni] as f32 * m.scales[ni];
                            }
                        }
                        (w, m.k, m.n)
                    })
                    .collect();
                Some((dq, Some(*bits)))
            }
        }
    }

    /// The effective activation scale a quant node's forward used for
    /// `scale_idx` (calibrated 8-bit scale rescaled to the node's
    /// bitwidth); `None` for fp32 nodes.
    pub(crate) fn ste_act_scale(&self, node_id: usize, scale_idx: usize) -> Option<f32> {
        match self.prepared.get(&node_id)? {
            PreparedNode::Fp32 { .. } => None,
            PreparedNode::Quant { bits, .. } => Some(
                self.act_scales[scale_idx]
                    * (quant::qmax_for(8) as f32 / quant::qmax_for(*bits) as f32),
            ),
        }
    }

    fn exec_node(
        &self,
        idx: usize,
        node: &Node,
        vals: &mut [Option<Value>],
        taped: bool,
    ) -> Result<Tensor> {
        Ok(match &node.op {
            Op::Input => unreachable!(),
            Op::Conv2d { .. } => self.exec_conv(node, get_f(vals, node.inputs[0])?)?,
            Op::Linear { .. } => self.exec_linear(node, get_f(vals, node.inputs[0])?)?,
            Op::Lstm { .. } => self.exec_lstm(node, get_f(vals, node.inputs[0])?)?,
            Op::Embedding { .. } => {
                let toks = get_i(vals, node.inputs[0])?;
                let table = &self.params[node.params[0]];
                layers::embedding(toks, table)?
            }
            Op::Relu => layers::relu(self.take_or_copy_f(idx, node.inputs[0], vals, taped)?),
            Op::Sigmoid => {
                layers::sigmoid(self.take_or_copy_f(idx, node.inputs[0], vals, taped)?)
            }
            Op::Tanh => layers::tanh(self.take_or_copy_f(idx, node.inputs[0], vals, taped)?),
            Op::AvgPool2 => layers::avgpool2(get_f(vals, node.inputs[0])?),
            Op::Gap => layers::gap(get_f(vals, node.inputs[0])?),
            Op::Flatten => {
                layers::flatten(self.take_or_copy_f(idx, node.inputs[0], vals, taped)?)
            }
            Op::Add => {
                let a = get_f(vals, node.inputs[0])?;
                let b = get_f(vals, node.inputs[1])?;
                anyhow::ensure!(a.shape == b.shape, "add shape mismatch");
                let mut out = self.pooled_tensor(&a.shape);
                for ((o, &x), &y) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
                    *o = x + y;
                }
                out
            }
            Op::Concat => {
                let vr: &[Option<Value>] = vals;
                let parts: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|&i| get_f(vr, i))
                    .collect::<Result<_>>()?;
                Tensor::concat_last(&parts)?
            }
            Op::ChannelShuffle { groups } => {
                layers::channel_shuffle(get_f(vals, node.inputs[0])?, *groups)
            }
            Op::SliceLast { start, end } => get_f(vals, node.inputs[0])?.slice_last(*start, *end),
            Op::Reshape { shape } => {
                let x = self.take_or_copy_f(idx, node.inputs[0], vals, taped)?;
                let n = x.shape[0];
                let mut full = vec![n];
                full.extend_from_slice(shape);
                x.reshape(&full)?
            }
        })
    }
}

/// Short op-kind label for profiling keys / tables.
fn op_kind(op: &Op) -> &'static str {
    match op {
        Op::Input => "input",
        Op::Conv2d { .. } => "conv2d",
        Op::Linear { .. } => "linear",
        Op::Lstm { .. } => "lstm",
        Op::Embedding { .. } => "embedding",
        Op::Relu => "relu",
        Op::Sigmoid => "sigmoid",
        Op::Tanh => "tanh",
        Op::AvgPool2 => "avgpool2",
        Op::Gap => "gap",
        Op::Flatten => "flatten",
        Op::Add => "add",
        Op::Concat => "concat",
        Op::ChannelShuffle { .. } => "channel_shuffle",
        Op::SliceLast { .. } => "slice_last",
        Op::Reshape { .. } => "reshape",
    }
}

/// Multiply-accumulates one node executed for this batch (GEMM-bearing
/// ops only; everything else reports 0). Derived from op parameters plus
/// the realized output/input shapes, so it reflects the actual batch.
fn node_macs(node: &Node, vals: &[Option<Value>], out: &Tensor) -> u64 {
    match &node.op {
        Op::Conv2d {
            kh,
            kw,
            cin,
            groups,
            ..
        } => {
            // out.data.len() = n*ho*wo*cout; per output element the
            // kernel reads kh*kw*(cin/groups) inputs.
            out.data.len() as u64 * (*kh as u64) * (*kw as u64) * (*cin as u64)
                / (*groups as u64).max(1)
        }
        Op::Linear { din, .. } => out.data.len() as u64 * *din as u64,
        Op::Lstm { din, hidden, .. } => {
            let t = match vals.get(node.inputs[0]).and_then(|v| v.as_ref()) {
                Some(Value::F(x)) if x.shape.len() >= 2 => x.shape[1] as u64,
                _ => 1,
            };
            let n = out.shape.first().copied().unwrap_or(1) as u64;
            n * t * 4 * (*hidden as u64) * (*din as u64 + *hidden as u64)
        }
        _ => 0,
    }
}

/// Quantize / flatten weights per the plan and resolve every node's ACU
/// backend — the once-per-(model, plan, params) construction behind
/// [`Executor::prepare_weights`].
fn prepare_nodes(
    model: &Model,
    params: &[Tensor],
    plan: &ExecutionPlan,
    luts: &LutRegistry,
) -> Result<BTreeMap<usize, PreparedNode>> {
    let mut prepared = BTreeMap::new();
    for node in &model.nodes {
        if !node.op.is_quantizable() {
            continue;
        }
        let mode = plan
            .modes
            .get(&node.id)
            .ok_or_else(|| anyhow!("plan missing node {}", node.id))?
            .clone();
        // Calibrated error compensation folds into the bias vector here,
        // at prepare time: the GEMM epilogue already adds bias per output
        // channel, so a compensated plan runs the identical hot path on
        // every SIMD tier at zero extra cost (and an absent/zero block is
        // bit-identical to no compensation at all).
        let comp = plan.compensation.get(&node.id);
        let fold_comp = |bias: Vec<f32>| -> Result<Vec<f32>> {
            let Some(comp) = comp else { return Ok(bias) };
            anyhow::ensure!(
                !matches!(mode, LayerMode::Fp32),
                "node {} carries compensation but runs fp32",
                node.id
            );
            anyhow::ensure!(
                comp.channels.is_empty() || comp.channels.len() == bias.len(),
                "node {} compensation has {} channel terms, layer has {} output channels",
                node.id,
                comp.channels.len(),
                bias.len()
            );
            let mut bias = bias;
            for (n, b) in bias.iter_mut().enumerate() {
                *b += comp.term(n);
            }
            Ok(bias)
        };
        let prep = match &node.op {
            Op::Conv2d {
                kh,
                kw,
                cin,
                cout,
                groups,
                ..
            } => {
                let w = &params[node.params[0]];
                let b = &params[node.params[1]];
                let cin_g = cin / groups;
                let cout_g = cout / groups;
                let kf = kh * kw * cin_g;
                // Weight tensor layout is (kh, kw, cin_g, cout): slice
                // each group's output-channel columns.
                let mut flats: Vec<Vec<f32>> = vec![Vec::with_capacity(kf * cout_g); *groups];
                for row in 0..kf {
                    for g in 0..*groups {
                        let base = row * cout + g * cout_g;
                        flats[g].extend_from_slice(&w.data[base..base + cout_g]);
                    }
                }
                build_prepared(&mode, luts, flats, kf, cout_g, fold_comp(b.data.clone())?)?
            }
            Op::Linear { din, dout, .. } => {
                let w = &params[node.params[0]];
                let b = &params[node.params[1]];
                build_prepared(
                    &mode,
                    luts,
                    vec![w.data.clone()],
                    *din,
                    *dout,
                    fold_comp(b.data.clone())?,
                )?
            }
            Op::Lstm { din, hidden, .. } => {
                anyhow::ensure!(
                    comp.is_none(),
                    "node {} (LSTM) does not support compensation",
                    node.id
                );
                let wx = &params[node.params[0]];
                let wh = &params[node.params[1]];
                let b = &params[node.params[2]];
                // Two mats: index 0 = input GEMM, 1 = recurrent GEMM.
                match &mode {
                    LayerMode::Fp32 => PreparedNode::Fp32 {
                        mats: vec![
                            (wx.data.clone(), *din, 4 * hidden),
                            (wh.data.clone(), *hidden, 4 * hidden),
                        ],
                        bias: b.data.clone(),
                    },
                    LayerMode::ApproxLut { acu } => {
                        let lut = luts.get(acu)?;
                        let bits = lut.bits;
                        PreparedNode::Quant {
                            mats: vec![
                                QuantMat::build(&wx.data, *din, 4 * hidden, bits),
                                QuantMat::build(&wh.data, *hidden, 4 * hidden, bits),
                            ],
                            bias: b.data.clone(),
                            bits,
                            backend: Backend::Lut {
                                form: closed_form_for(acu, bits),
                                lut,
                            },
                        }
                    }
                    LayerMode::ApproxFunc { bits, trunc_k } => PreparedNode::Quant {
                        mats: vec![
                            QuantMat::build(&wx.data, *din, 4 * hidden, *bits),
                            QuantMat::build(&wh.data, *hidden, 4 * hidden, *bits),
                        ],
                        bias: b.data.clone(),
                        bits: *bits,
                        backend: Backend::Func {
                            f: func_for(*trunc_k),
                            form: Some(form_for_trunc(*trunc_k)),
                        },
                    },
                }
            }
            _ => unreachable!(),
        };
        prepared.insert(node.id, prep);
    }
    Ok(prepared)
}

fn build_prepared(
    mode: &LayerMode,
    luts: &LutRegistry,
    flats: Vec<Vec<f32>>,
    k: usize,
    n: usize,
    bias: Vec<f32>,
) -> Result<PreparedNode> {
    Ok(match mode {
        LayerMode::Fp32 => PreparedNode::Fp32 {
            mats: flats.into_iter().map(|w| (w, k, n)).collect(),
            bias,
        },
        LayerMode::ApproxLut { acu } => {
            let lut = luts.get(acu)?;
            let bits = lut.bits;
            PreparedNode::Quant {
                mats: flats
                    .into_iter()
                    .map(|w| QuantMat::build(&w, k, n, bits))
                    .collect(),
                bias,
                bits,
                backend: Backend::Lut {
                    form: closed_form_for(acu, bits),
                    lut,
                },
            }
        }
        LayerMode::ApproxFunc { bits, trunc_k } => PreparedNode::Quant {
            mats: flats
                .into_iter()
                .map(|w| QuantMat::build(&w, k, n, *bits))
                .collect(),
            bias,
            bits: *bits,
            backend: Backend::Func {
                f: func_for(*trunc_k),
                form: Some(form_for_trunc(*trunc_k)),
            },
        },
    })
}

#[inline(always)]
fn sigmoid_s(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}
