//! ACU GEMM kernels — the hot path of the emulation (§4).
//!
//! Loop nests live here; the innermost steps live in
//! [`simd`](crate::emulator::simd) and are runtime-dispatched across three
//! tiers (AVX2 → NEON → scalar, see that module). Product backends:
//!
//! * **Naive** — the Table-4 "Baseline Approx." column: textbook m/n/k
//!   loop nest, column-strided weight access, one scalar table lookup (or
//!   behavioral-function call) per product, no threads. Deliberately the
//!   unoptimized emulation the paper compares against; never dispatched.
//! * **LUT gather** (`lut_opt`, `lut_opt_biased`) — the paper's §4
//!   design: row-parallel over the threadpool, loop order m-k-n with the
//!   LUT *row for x[m,k] hoisted out of the inner loop*, unit stride over
//!   both the weight row and the accumulator. On AVX2 the inner step is a
//!   real `vpgatherdd`; `lut_opt_biased` additionally pre-biases weight
//!   indices at plan-build time and pairs 4 output rows per weight stream.
//! * **Closed-form** (`cf_opt_i32`, `cf_opt_i64`) — the kernel-compilation
//!   tier: ACU families with a [`Form`] descriptor (truncation,
//!   perforation, DRUM…) lower to branchless bit-op inner loops that
//!   never touch a LUT — TFApprox's "functional" trick. Selected
//!   per-layer by the executor from the plan.
//!
//! **Determinism contract.** All optimized kernels share one reduction
//! order: k-blocked by [`BLOCK_K`] with each output element accumulated by
//! exactly one worker. Integer kernels are order-insensitive
//! (associative adds ⇒ bit-identical across tiers and thread counts); the
//! f32 kernels pin the order explicitly — per-element accumulation chains
//! for `fp32_opt`/`fp32_at_b`, the fixed 8-lane striped reduction of
//! [`simd::dot_f32`] for `fp32_a_bt` — so every kernel is bit-identical
//! across `Isa` tiers and `ADAPT_THREADS` values (enforced by
//! `tests/kernel_equivalence.rs`). Each public kernel has a `*_with`
//! variant taking an explicit [`Isa`] for A/B tests and benches; the
//! plain entry points dispatch on [`simd::isa`].
//!
//! Accumulators: `lut_opt_biased`/`cf_opt_i32` use i32 (safe at 8-bit:
//! |product| ≤ 2^14, K < 2^17 in the zoo); `lut_opt`/`func_opt`/
//! `cf_opt_i64` use i64, the correct contract for 12-bit ACUs (|p|max ≈
//! 2^22 overflows i32 sums at K ≥ ~1k).

use crate::lut::Lut;
use crate::mult::{Form, MulFn};
use crate::util::threadpool;

use super::simd::{self, Isa};

/// K-block size for the optimized engines: keeps the active x block and
/// accumulator row in L1 while streaming weight rows. Every optimized
/// kernel uses the same blocking — one reduction-order story.
const BLOCK_K: usize = 64;

// ---------------------------------------------------------------------------
// fp32
// ---------------------------------------------------------------------------

/// Naive fp32 GEMM (reference / "native rust" path in tests).
pub fn fp32_naive(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0.0f32;
            for ki in 0..k {
                acc += x[mi * k + ki] * w[ki * n + ni];
            }
            out[mi * n + ni] = acc;
        }
    }
}

/// Blocked + threaded fp32 GEMM.
pub fn fp32_opt(
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    fp32_opt_with(x, m, k, w, n, threads, simd::isa(), out);
}

/// [`fp32_opt`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn fp32_opt_with(
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    threads: usize,
    isa: Isa,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    let rows: Vec<&mut [f32]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        row.fill(0.0);
        let xrow = &x[mi * k..(mi + 1) * k];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for ki in k0..k1 {
                simd::axpy_f32(isa, xrow[ki], &w[ki * n..(ki + 1) * n], row);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// LUT gather
// ---------------------------------------------------------------------------

/// Baseline LUT GEMM: the unoptimized emulation (scalar `lut.mul` per
/// product, n-inner loop ⇒ strided weight reads, single thread).
pub fn lut_naive(xq: &[i32], m: usize, k: usize, wq: &[i32], n: usize, lut: &Lut, out: &mut [i64]) {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0i64;
            for ki in 0..k {
                acc += lut.mul(xq[mi * k + ki], wq[ki * n + ni]) as i64;
            }
            out[mi * n + ni] = acc;
        }
    }
}

/// Optimized LUT GEMM: threaded over rows, LUT row hoisted per (m,k),
/// vectorized gather + i64-widening accumulation in the inner step.
pub fn lut_opt(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    lut: &Lut,
    threads: usize,
    out: &mut [i64],
) {
    lut_opt_with(xq, m, k, wq, n, lut, threads, simd::isa(), out);
}

/// [`lut_opt`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn lut_opt_with(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    lut: &Lut,
    threads: usize,
    isa: Isa,
    out: &mut [i64],
) {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    let half = (lut.n / 2) as i32;
    let rows: Vec<&mut [i64]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        row.fill(0);
        let xrow = &xq[mi * k..(mi + 1) * k];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for ki in k0..k1 {
                // One LUT row per (m, k): the gather base the paper keeps
                // in a register for vpgatherdd.
                let lrow = lut.row(xrow[ki]);
                simd::lut_row1_i64(isa, lrow, half, &wq[ki * n..(ki + 1) * n], row);
            }
        }
    });
}

/// Fastest LUT GEMM: weights pre-converted to *biased* u16 LUT indices at
/// plan-build time (one add removed from every product), i32 accumulators
/// (safe: |product| <= 2^14 at 8-bit, K < 2^17 in the zoo), row-paired so
/// each weight index is loaded once and used for four output rows.
///
/// This is the §Perf-pass kernel; `lut_opt` is kept for the generic i64
/// path and as the before/after comparison point.
pub fn lut_opt_biased(
    xq: &[i32],
    m: usize,
    k: usize,
    wq_biased: &[u16],
    n: usize,
    lut: &Lut,
    threads: usize,
    out: &mut [i32],
) {
    lut_opt_biased_with(xq, m, k, wq_biased, n, lut, threads, simd::isa(), out);
}

/// [`lut_opt_biased`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn lut_opt_biased_with(
    xq: &[i32],
    m: usize,
    k: usize,
    wq_biased: &[u16],
    n: usize,
    lut: &Lut,
    threads: usize,
    isa: Isa,
    out: &mut [i32],
) {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq_biased.len(), k * n);
    assert_eq!(out.len(), m * n);
    const ROWS: usize = 4; // m-rows sharing one weight-index stream
    let blocks: Vec<&mut [i32]> = out.chunks_mut(ROWS * n).collect();
    let mut blocks = blocks;
    threadpool::parallel_map_into(&mut blocks, threads, |bi, chunk| {
        chunk.fill(0);
        let m0 = bi * ROWS;
        let rows = chunk.len() / n;
        if rows == ROWS {
            let (r0, rest) = chunk.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            let x0 = &xq[m0 * k..(m0 + 1) * k];
            let x1 = &xq[(m0 + 1) * k..(m0 + 2) * k];
            let x2 = &xq[(m0 + 2) * k..(m0 + 3) * k];
            let x3 = &xq[(m0 + 3) * k..(m0 + 4) * k];
            for k0 in (0..k).step_by(BLOCK_K) {
                let k1 = (k0 + BLOCK_K).min(k);
                for ki in k0..k1 {
                    // One LUT row per x value; the shared weight-index
                    // stream is loaded once and feeds four accumulator
                    // rows (ILP / one gather-index widen per 4 rows).
                    let l0 = lut.row(x0[ki]);
                    let l1 = lut.row(x1[ki]);
                    let l2 = lut.row(x2[ki]);
                    let l3 = lut.row(x3[ki]);
                    let wrow = &wq_biased[ki * n..(ki + 1) * n];
                    simd::lut_rows4(isa, l0, l1, l2, l3, wrow, r0, r1, r2, r3);
                }
            }
        } else {
            // Tail block (< ROWS rows): same k-blocking as the main path,
            // so biased/unbiased kernels share one reduction-order story.
            for r in 0..rows {
                let xrow = &xq[(m0 + r) * k..(m0 + r + 1) * k];
                let orow = &mut chunk[r * n..(r + 1) * n];
                for k0 in (0..k).step_by(BLOCK_K) {
                    let k1 = (k0 + BLOCK_K).min(k);
                    for ki in k0..k1 {
                        let lrow = lut.row(xrow[ki]);
                        let wrow = &wq_biased[ki * n..(ki + 1) * n];
                        simd::lut_row1_i32(isa, lrow, wrow, orow);
                    }
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Closed-form ACU (kernel-compilation tier)
// ---------------------------------------------------------------------------

/// Closed-form ACU GEMM with i32 accumulation: the branchless bit-op
/// lowering of a [`Form`] family — no LUT touched, no function-pointer
/// call per product. Bit-identical to `lut_naive`/`lut_opt*` over the
/// same ACU (the LUT is generated from the same model). 8-bit operands
/// only (i32 accumulator contract, as `lut_opt_biased`).
#[allow(clippy::too_many_arguments)]
pub fn cf_opt_i32(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    form: Form,
    threads: usize,
    out: &mut [i32],
) {
    cf_opt_i32_with(xq, m, k, wq, n, form, threads, simd::isa(), out);
}

/// [`cf_opt_i32`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn cf_opt_i32_with(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    form: Form,
    threads: usize,
    isa: Isa,
    out: &mut [i32],
) {
    assert!(form.is_closed(), "opaque ACU has no closed-form kernel");
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    let rows: Vec<&mut [i32]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        row.fill(0);
        let xrow = &xq[mi * k..(mi + 1) * k];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for ki in k0..k1 {
                simd::cf_row_i32(isa, form, xrow[ki], &wq[ki * n..(ki + 1) * n], row);
            }
        }
    });
}

/// Closed-form ACU GEMM with i64 accumulation — the wide-operand twin of
/// [`cf_opt_i32`] used for 12-bit functional plans. The inner body is the
/// branchless scalar [`Form::mul_i64`] (i64 lanes halve SIMD width and
/// the win over the bit-op scalar body is marginal; correctness first).
pub fn cf_opt_i64(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    form: Form,
    threads: usize,
    out: &mut [i64],
) {
    assert!(form.is_closed(), "opaque ACU has no closed-form kernel");
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    let rows: Vec<&mut [i64]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        row.fill(0);
        let xrow = &xq[mi * k..(mi + 1) * k];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for ki in k0..k1 {
                let xv = xrow[ki] as i64;
                let wrow = &wq[ki * n..(ki + 1) * n];
                for (o, &wv) in row.iter_mut().zip(wrow) {
                    *o += form.mul_i64(xv, wv as i64);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Backward (STE retraining) fp32 kernels
// ---------------------------------------------------------------------------

/// C (m, k) = A (m, n) @ Bᵀ where B is (k, n) row-major — the input-grad
/// GEMM of the STE backward (`dX = dY @ Ŵᵀ`) without materializing the
/// transpose. Both inner operands stream with unit stride. Row-parallel
/// over m; each dot product uses the fixed 8-lane striped reduction of
/// [`simd::dot_f32`], so outputs are bit-identical at any thread count
/// and ISA tier.
pub fn fp32_a_bt(
    a: &[f32],
    m: usize,
    n: usize,
    b: &[f32],
    k: usize,
    threads: usize,
    out: &mut [f32],
) {
    fp32_a_bt_with(a, m, n, b, k, threads, simd::isa(), out);
}

/// [`fp32_a_bt`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn fp32_a_bt_with(
    a: &[f32],
    m: usize,
    n: usize,
    b: &[f32],
    k: usize,
    threads: usize,
    isa: Isa,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * k);
    let rows: Vec<&mut [f32]> = out.chunks_mut(k).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        let arow = &a[mi * n..(mi + 1) * n];
        for (ki, o) in row.iter_mut().enumerate() {
            *o = simd::dot_f32(isa, arow, &b[ki * n..(ki + 1) * n]);
        }
    });
}

/// C (k, n) = Aᵀ @ B where A is (m, k) and B is (m, n), both row-major —
/// the weight-grad GEMM of the STE backward (`dW = X̂ᵀ @ dY`) without
/// materializing the transpose. Row-parallel over k with the shared
/// m-blocking; per-element accumulation chains keep the scalar order, so
/// outputs are bit-identical at any thread count and ISA tier.
pub fn fp32_at_b(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    fp32_at_b_with(a, m, k, b, n, threads, simd::isa(), out);
}

/// [`fp32_at_b`] with an explicit ISA tier (A/B tests, benches).
#[allow(clippy::too_many_arguments)]
pub fn fp32_at_b_with(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    threads: usize,
    isa: Isa,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(out.len(), k * n);
    let rows: Vec<&mut [f32]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |ki, row| {
        row.fill(0.0);
        for m0 in (0..m).step_by(BLOCK_K) {
            let m1 = (m0 + BLOCK_K).min(m);
            for mi in m0..m1 {
                simd::axpy_f32(isa, a[mi * k + ki], &b[mi * n..(mi + 1) * n], row);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Functional ACU (large-bitwidth fallback, §3.4)
// ---------------------------------------------------------------------------

/// Baseline functional GEMM: scalar behavioral-multiplier call per product.
pub fn func_naive(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    f: MulFn,
    out: &mut [i64],
) {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0i64;
            for ki in 0..k {
                acc += f(xq[mi * k + ki] as i64, wq[ki * n + ni] as i64);
            }
            out[mi * n + ni] = acc;
        }
    }
}

/// Optimized functional GEMM: threaded, k-blocked, unit-stride inner loop
/// over an opaque [`MulFn`]. Closed-form families should use
/// [`cf_opt_i64`] instead (no indirect call per product).
pub fn func_opt(
    xq: &[i32],
    m: usize,
    k: usize,
    wq: &[i32],
    n: usize,
    f: MulFn,
    threads: usize,
    out: &mut [i64],
) {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    assert_eq!(out.len(), m * n);
    let rows: Vec<&mut [i64]> = out.chunks_mut(n).collect();
    let mut rows = rows;
    threadpool::parallel_map_into(&mut rows, threads, |mi, row| {
        row.fill(0);
        let xrow = &xq[mi * k..(mi + 1) * k];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for ki in k0..k1 {
                let xv = xrow[ki] as i64;
                let wrow = &wq[ki * n..(ki + 1) * n];
                for (o, &wv) in row.iter_mut().zip(wrow) {
                    *o += f(xv, wv as i64);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult;
    use crate::util::rng::Rng;

    fn rand_q(rng: &mut Rng, len: usize, half: i64) -> Vec<i32> {
        (0..len).map(|_| rng.range_i64(-half, half) as i32).collect()
    }

    #[test]
    fn lut_naive_equals_opt() {
        let lut = Lut::generate(mult::get("mitchell8").unwrap());
        let mut rng = Rng::new(9);
        let (m, k, n) = (7, 33, 12);
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
        lut_opt(&xq, m, k, &wq, n, &lut, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn lut_exact_equals_integer_matmul() {
        let lut = Lut::generate(mult::get("exact8").unwrap());
        let mut rng = Rng::new(10);
        let (m, k, n) = (5, 17, 9);
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let mut got = vec![0i64; m * n];
        lut_opt(&xq, m, k, &wq, n, &lut, 2, &mut got);
        for mi in 0..m {
            for ni in 0..n {
                let want: i64 = (0..k)
                    .map(|ki| xq[mi * k + ki] as i64 * wq[ki * n + ni] as i64)
                    .sum();
                assert_eq!(got[mi * n + ni], want);
            }
        }
    }

    #[test]
    fn lut_opt_biased_matches_naive_over_shapes() {
        let lut = Lut::generate(mult::get("mul8s_1l2h_like").unwrap());
        let mut rng = Rng::new(77);
        for _ in 0..12 {
            let m = 1 + rng.below(33) as usize;
            let k = 1 + rng.below(70) as usize;
            let n = 1 + rng.below(40) as usize;
            let xq = rand_q(&mut rng, m * k, 128);
            let wq = rand_q(&mut rng, k * n, 128);
            let wb: Vec<u16> = wq.iter().map(|&v| (v + 128) as u16).collect();
            let mut a = vec![0i64; m * n];
            let mut b = vec![0i32; m * n];
            lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
            lut_opt_biased(&xq, m, k, &wb, n, &lut, 2, &mut b);
            assert_eq!(a, b.iter().map(|&v| v as i64).collect::<Vec<_>>(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn cf_opt_matches_lut_naive() {
        // The closed-form tier must agree bit-for-bit with the LUT of the
        // same model, for both symmetric and floor-trunc families.
        let mut rng = Rng::new(78);
        for acu in ["drum8_4", "mul8s_1l2h_like", "comp_trunc_out8_6"] {
            let m8 = mult::get(acu).unwrap();
            let lut = Lut::generate(m8);
            let (m, k, n) = (9, 41, 14);
            let xq = rand_q(&mut rng, m * k, 128);
            let wq = rand_q(&mut rng, k * n, 128);
            let mut a = vec![0i64; m * n];
            let mut b = vec![0i32; m * n];
            lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
            cf_opt_i32(&xq, m, k, &wq, n, m8.form, 2, &mut b);
            assert_eq!(a, b.iter().map(|&v| v as i64).collect::<Vec<_>>(), "{acu}");
        }
    }

    #[test]
    fn cf_opt_i64_matches_func_opt_at_12bit() {
        let m12 = mult::get("mul12s_2km_like").unwrap();
        let mut rng = Rng::new(79);
        let (m, k, n) = (4, 70, 6);
        let xq = rand_q(&mut rng, m * k, 2048);
        let wq = rand_q(&mut rng, k * n, 2048);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        func_opt(&xq, m, k, &wq, n, m12.fun, 2, &mut a);
        cf_opt_i64(&xq, m, k, &wq, n, m12.form, 2, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn func_naive_equals_opt_at_12bit() {
        let f = mult::get("mul12s_2km_like").unwrap().fun;
        let mut rng = Rng::new(11);
        let (m, k, n) = (4, 70, 6);
        let xq = rand_q(&mut rng, m * k, 2048);
        let wq = rand_q(&mut rng, k * n, 2048);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        func_naive(&xq, m, k, &wq, n, f, &mut a);
        func_opt(&xq, m, k, &wq, n, f, 2, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn func_matches_lut_at_8bit() {
        // The LUT and functional paths of the same ACU must agree exactly.
        let m8 = mult::get("drum8_4").unwrap();
        let lut = Lut::generate(m8);
        let mut rng = Rng::new(12);
        let (m, k, n) = (3, 21, 5);
        let xq = rand_q(&mut rng, m * k, 128);
        let wq = rand_q(&mut rng, k * n, 128);
        let mut a = vec![0i64; m * n];
        let mut b = vec![0i64; m * n];
        lut_naive(&xq, m, k, &wq, n, &lut, &mut a);
        func_naive(&xq, m, k, &wq, n, m8.fun, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn a_bt_matches_materialized_transpose() {
        let mut rng = Rng::new(21);
        let (m, n, k) = (5, 9, 13);
        let a: Vec<f32> = (0..m * n).map(|_| rng.next_gauss()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_gauss()).collect();
        // Reference: materialize Bᵀ (n, k) and run the naive GEMM.
        let mut bt = vec![0f32; n * k];
        for ki in 0..k {
            for ni in 0..n {
                bt[ni * k + ki] = b[ki * n + ni];
            }
        }
        let mut want = vec![0f32; m * k];
        fp32_naive(&a, m, n, &bt, k, &mut want);
        for threads in [1usize, 3] {
            let mut got = vec![0f32; m * k];
            fp32_a_bt(&a, m, n, &b, k, threads, &mut got);
            for (u, v) in want.iter().zip(&got) {
                assert!((u - v).abs() < 1e-4 * (1.0 + u.abs()), "{u} vs {v}");
            }
        }
    }

    #[test]
    fn at_b_matches_materialized_transpose() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (7, 6, 11);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_gauss()).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.next_gauss()).collect();
        let mut at = vec![0f32; k * m];
        for mi in 0..m {
            for ki in 0..k {
                at[ki * m + mi] = a[mi * k + ki];
            }
        }
        let mut want = vec![0f32; k * n];
        fp32_naive(&at, k, m, &b, n, &mut want);
        for threads in [1usize, 4] {
            let mut got = vec![0f32; k * n];
            fp32_at_b(&a, m, k, &b, n, threads, &mut got);
            for (u, v) in want.iter().zip(&got) {
                assert!((u - v).abs() < 1e-4 * (1.0 + u.abs()), "{u} vs {v}");
            }
        }
    }

    #[test]
    fn backward_kernels_deterministic_across_threads() {
        let mut rng = Rng::new(23);
        let (m, k, n) = (13, 10, 8);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_gauss()).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.next_gauss()).collect();
        let mut one = vec![0f32; k * n];
        fp32_at_b(&a, m, k, &b, n, 1, &mut one);
        let mut four = vec![0f32; k * n];
        fp32_at_b(&a, m, k, &b, n, 4, &mut four);
        assert_eq!(one, four, "at_b must be bit-identical at any thread count");
        let g: Vec<f32> = (0..m * n).map(|_| rng.next_gauss()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_gauss()).collect();
        let mut one = vec![0f32; m * k];
        fp32_a_bt(&g, m, n, &w, k, 1, &mut one);
        let mut four = vec![0f32; m * k];
        fp32_a_bt(&g, m, n, &w, k, 4, &mut four);
        assert_eq!(one, four, "a_bt must be bit-identical at any thread count");
    }

    #[test]
    fn fp32_naive_equals_opt() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (6, 40, 11);
        let x: Vec<f32> = (0..m * k).map(|_| rng.next_gauss()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_gauss()).collect();
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        fp32_naive(&x, m, k, &w, n, &mut a);
        fp32_opt(&x, m, k, &w, n, 2, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-4 * (1.0 + u.abs()), "{u} vs {v}");
        }
    }
}
