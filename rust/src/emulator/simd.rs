//! Runtime-dispatched SIMD inner loops for the GEMM kernels (§4).
//!
//! [`gemm`](crate::emulator::gemm) owns the loop nests (row-parallelism,
//! k-blocking, row-pairing); this module owns the innermost step, in three
//! tiers selected once per process by [`isa`]:
//!
//! * **AVX2** (x86_64, runtime-detected): 8-lane `vpgatherdd` into the
//!   LUT rows with i32/i64-lane accumulation — the instruction the paper's
//!   §4 vectorization is built around — plus 8-lane branchless bodies for
//!   the closed-form ACU families and 8-lane f32 axpy/dot.
//! * **NEON** (aarch64 baseline): no vector gather exists, so the LUT
//!   kernels keep the scalar body there; the closed-form and f32 loops get
//!   4-lane vector bodies.
//! * **Scalar**: the portable fallback, also forced by `ADAPT_NO_SIMD=1`
//!   (the CI portability leg).
//!
//! **Determinism contract:** for the integer kernels every tier performs
//! the same adds in a different order only — integer addition is
//! associative, so outputs are bit-identical by construction. For the f32
//! kernels order matters, so the scalar bodies here mirror the vector
//! schedule exactly: `axpy_f32` keeps one accumulation chain per output
//! element (order-preserving under lane-splitting), and `dot_f32` uses a
//! fixed 8-lane striped reduction in *all* tiers (8 partial sums over k,
//! folded left, then the tail). Every helper takes its [`Isa`] explicitly
//! so A/B tests and benches can force tiers; production callers pass
//! [`isa()`].

use std::sync::OnceLock;

use crate::mult::Form;

/// Instruction set the dispatched inner loops run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar bodies (always available).
    Scalar,
    /// AVX2 8-lane integer/f32 bodies with `vpgatherdd` LUT gathers.
    Avx2,
    /// NEON 4-lane closed-form/f32 bodies (LUT stays scalar: no gather).
    Neon,
}

static ISA: OnceLock<Isa> = OnceLock::new();

/// The tier the kernels dispatch to, detected once per process.
/// `ADAPT_NO_SIMD=1` forces [`Isa::Scalar`].
pub fn isa() -> Isa {
    *ISA.get_or_init(detect)
}

fn detect() -> Isa {
    if std::env::var("ADAPT_NO_SIMD").as_deref() == Ok("1") {
        return Isa::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Isa {
    Isa::Scalar
}

/// Per-family `(x-mask, w-mask, product-mask, compensation)` constants for
/// the masked sign-magnitude families (`-1` = identity mask). Shared by
/// the vector bodies; the scalar tier inlines the same arithmetic via
/// [`Form::mul_i32`].
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn masked_consts(form: Form) -> (i32, i32, i32, i32) {
    match form {
        Form::TruncIn(k) => {
            let m = !((1i32 << k) - 1);
            (m, m, -1, 0)
        }
        Form::PerfPp(k) => (-1, !((1i32 << k) - 1), -1, 0),
        Form::TruncOut(k) => (-1, -1, !((1i32 << k) - 1), 0),
        Form::CompTruncOut(k) => (-1, -1, !((1i32 << k) - 1), 1i32 << (k - 1)),
        _ => unreachable!("not a masked family"),
    }
}

/// Scalar tail for the vector closed-form bodies, from element `from`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn cf_tail(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32], from: usize) {
    for (o, &wv) in acc[from..].iter_mut().zip(&wrow[from..]) {
        *o += form.mul_i32(xv, wv);
    }
}

// ---------------------------------------------------------------------------
// Scalar tier
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn scalar_lut_rows4(
    l0: &[i32],
    l1: &[i32],
    l2: &[i32],
    l3: &[i32],
    wrow: &[u16],
    r0: &mut [i32],
    r1: &mut [i32],
    r2: &mut [i32],
    r3: &mut [i32],
) {
    for (j, &wi) in wrow.iter().enumerate() {
        let wi = wi as usize;
        // SAFETY: caller contract (see `lut_rows4`) — wi < LUT row length
        // by quantization clamping, j < n == accumulator row length.
        unsafe {
            *r0.get_unchecked_mut(j) += *l0.get_unchecked(wi);
            *r1.get_unchecked_mut(j) += *l1.get_unchecked(wi);
            *r2.get_unchecked_mut(j) += *l2.get_unchecked(wi);
            *r3.get_unchecked_mut(j) += *l3.get_unchecked(wi);
        }
    }
}

fn scalar_lut_row1_i32(lrow: &[i32], wrow: &[u16], acc: &mut [i32]) {
    for (o, &wi) in acc.iter_mut().zip(wrow) {
        // SAFETY: caller contract — biased index < LUT row length.
        *o += unsafe { *lrow.get_unchecked(wi as usize) };
    }
}

fn scalar_lut_row1_i64(lrow: &[i32], half: i32, wrow: &[i32], acc: &mut [i64]) {
    for (o, &wv) in acc.iter_mut().zip(wrow) {
        // SAFETY: caller contract — wv in [-half, half-1] by quantization
        // clamping, so wv + half indexes inside the LUT row.
        *o += unsafe { *lrow.get_unchecked((wv + half) as usize) } as i64;
    }
}

fn scalar_cf_row(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
    for (o, &wv) in acc.iter_mut().zip(wrow) {
        *o += form.mul_i32(xv, wv);
    }
}

fn scalar_axpy(a: f32, src: &[f32], dst: &mut [f32]) {
    for (o, &s) in dst.iter_mut().zip(src) {
        *o += a * s;
    }
}

/// Fixed 8-lane striped dot product — the canonical reduction order every
/// tier reproduces exactly: lane `l` accumulates elements `c*8 + l`, the
/// eight lane sums fold left, then the sub-8 tail adds in order.
fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let n8 = n - n % 8;
    let mut lanes = [0f32; 8];
    let mut j = 0;
    while j < n8 {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += a[j + l] * b[j + l];
        }
        j += 8;
    }
    let mut s = 0f32;
    for lane in lanes {
        s += lane;
    }
    while j < n {
        s += a[j] * b[j];
        j += 1;
    }
    s
}

// ---------------------------------------------------------------------------
// AVX2 tier
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! 8-lane AVX2 bodies. Every fn here requires AVX2 to be detected at
    //! runtime plus the same index/length contracts as the scalar bodies;
    //! fused multiply-add is deliberately never used (it would change f32
    //! rounding vs the scalar tier and break bit-exactness).

    use super::{cf_tail, masked_consts};
    use crate::mult::Form;
    use std::arch::x86_64::*;

    /// Widen 8 biased u16 LUT indices to i32 gather lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_idx8(p: *const u16) -> __m256i {
        _mm256_cvtepu16_epi32(_mm_loadu_si128(p as *const __m128i))
    }

    /// `acc[0..8] += lrow[idx[0..8]]` via vpgatherdd.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn gather_add(lrow: *const i32, idx: __m256i, accp: *mut i32) {
        let g = _mm256_i32gather_epi32::<4>(lrow, idx);
        let a = _mm256_loadu_si256(accp as *const __m256i);
        _mm256_storeu_si256(accp as *mut __m256i, _mm256_add_epi32(a, g));
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_rows4(
        l0: &[i32],
        l1: &[i32],
        l2: &[i32],
        l3: &[i32],
        wrow: &[u16],
        r0: &mut [i32],
        r1: &mut [i32],
        r2: &mut [i32],
        r3: &mut [i32],
    ) {
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let idx = load_idx8(wrow.as_ptr().add(j));
            gather_add(l0.as_ptr(), idx, r0.as_mut_ptr().add(j));
            gather_add(l1.as_ptr(), idx, r1.as_mut_ptr().add(j));
            gather_add(l2.as_ptr(), idx, r2.as_mut_ptr().add(j));
            gather_add(l3.as_ptr(), idx, r3.as_mut_ptr().add(j));
            j += 8;
        }
        while j < n {
            let wi = *wrow.get_unchecked(j) as usize;
            *r0.get_unchecked_mut(j) += *l0.get_unchecked(wi);
            *r1.get_unchecked_mut(j) += *l1.get_unchecked(wi);
            *r2.get_unchecked_mut(j) += *l2.get_unchecked(wi);
            *r3.get_unchecked_mut(j) += *l3.get_unchecked(wi);
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_row1_i32(lrow: &[i32], wrow: &[u16], acc: &mut [i32]) {
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let idx = load_idx8(wrow.as_ptr().add(j));
            gather_add(lrow.as_ptr(), idx, acc.as_mut_ptr().add(j));
            j += 8;
        }
        while j < n {
            *acc.get_unchecked_mut(j) += *lrow.get_unchecked(*wrow.get_unchecked(j) as usize);
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_row1_i64(lrow: &[i32], half: i32, wrow: &[i32], acc: &mut [i64]) {
        let vhalf = _mm256_set1_epi32(half);
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let w = _mm256_loadu_si256(wrow.as_ptr().add(j) as *const __m256i);
            let idx = _mm256_add_epi32(w, vhalf);
            let g = _mm256_i32gather_epi32::<4>(lrow.as_ptr(), idx);
            // Widen the 8 gathered i32 products into 2x4 i64 lanes.
            let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(g));
            let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(g));
            let p0 = acc.as_mut_ptr().add(j);
            let p1 = acc.as_mut_ptr().add(j + 4);
            let a0 = _mm256_loadu_si256(p0 as *const __m256i);
            let a1 = _mm256_loadu_si256(p1 as *const __m256i);
            _mm256_storeu_si256(p0 as *mut __m256i, _mm256_add_epi64(a0, lo));
            _mm256_storeu_si256(p1 as *mut __m256i, _mm256_add_epi64(a1, hi));
            j += 8;
        }
        while j < n {
            let wi = (*wrow.get_unchecked(j) + half) as usize;
            *acc.get_unchecked_mut(j) += *lrow.get_unchecked(wi) as i64;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn cf_row(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        match form {
            Form::Exact => cf_exact(xv, wrow, acc),
            Form::TruncIn(_) | Form::PerfPp(_) | Form::TruncOut(_) | Form::CompTruncOut(_) => {
                cf_masked(form, xv, wrow, acc)
            }
            Form::FloorTrunc(k) => cf_floor_trunc(k, xv, wrow, acc),
            Form::Drum(k) => cf_drum(k, xv, wrow, acc),
            Form::Opaque => unreachable!("opaque ACU has no closed form"),
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn cf_exact(xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let va = _mm256_set1_epi32(xv);
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let w = _mm256_loadu_si256(wrow.as_ptr().add(j) as *const __m256i);
            let p = _mm256_mullo_epi32(va, w);
            let ap = acc.as_mut_ptr().add(j);
            let a = _mm256_loadu_si256(ap as *const __m256i);
            _mm256_storeu_si256(ap as *mut __m256i, _mm256_add_epi32(a, p));
            j += 8;
        }
        cf_tail(Form::Exact, xv, wrow, acc, j);
    }

    /// TruncIn / PerfPp / TruncOut / CompTruncOut: masked magnitude
    /// product with the exact sign re-applied per lane via
    /// `(p ^ neg) - neg`. (`_mm256_sign_epi32` is NOT usable here: it
    /// zeroes lanes where the control is zero.)
    #[target_feature(enable = "avx2")]
    unsafe fn cf_masked(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let (a_mask, b_mask, out_mask, comp) = masked_consts(form);
        let va = _mm256_set1_epi32(xv.wrapping_abs() & a_mask);
        let vxneg = _mm256_set1_epi32(xv >> 31);
        let vbmask = _mm256_set1_epi32(b_mask);
        let vomask = _mm256_set1_epi32(out_mask);
        let vcomp = _mm256_set1_epi32(comp);
        let zero = _mm256_setzero_si256();
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let w = _mm256_loadu_si256(wrow.as_ptr().add(j) as *const __m256i);
            let wabs = _mm256_and_si256(_mm256_abs_epi32(w), vbmask);
            let praw = _mm256_mullo_epi32(va, wabs);
            let pmask = _mm256_and_si256(praw, vomask);
            // Compensation keys off the untruncated product (praw >= 0).
            let nz = _mm256_cmpgt_epi32(praw, zero);
            let p = _mm256_add_epi32(pmask, _mm256_and_si256(nz, vcomp));
            let wneg = _mm256_cmpgt_epi32(zero, w);
            let neg = _mm256_xor_si256(wneg, vxneg);
            let signed = _mm256_sub_epi32(_mm256_xor_si256(p, neg), neg);
            let ap = acc.as_mut_ptr().add(j);
            let a = _mm256_loadu_si256(ap as *const __m256i);
            _mm256_storeu_si256(ap as *mut __m256i, _mm256_add_epi32(a, signed));
            j += 8;
        }
        cf_tail(form, xv, wrow, acc, j);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn cf_floor_trunc(k: u32, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let va = _mm256_set1_epi32(xv);
        let cnt = _mm_cvtsi32_si128(k as i32);
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let w = _mm256_loadu_si256(wrow.as_ptr().add(j) as *const __m256i);
            let p = _mm256_mullo_epi32(va, w);
            // Two's-complement floor: arithmetic shift right then left.
            let t = _mm256_sll_epi32(_mm256_sra_epi32(p, cnt), cnt);
            let ap = acc.as_mut_ptr().add(j);
            let a = _mm256_loadu_si256(ap as *const __m256i);
            _mm256_storeu_si256(ap as *mut __m256i, _mm256_add_epi32(a, t));
            j += 8;
        }
        cf_tail(Form::FloorTrunc(k), xv, wrow, acc, j);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn cf_drum(k: u32, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        // The x operand reduces once per row (scalar); the weight lanes
        // reduce vectorized: floor_log2 via the f32 exponent field (exact
        // for magnitudes < 2^24), per-lane variable shifts for the
        // keep-top-k + trailing-one reduction.
        let va = _mm256_set1_epi32(crate::mult::drum_reduce_i32(xv.wrapping_abs(), k));
        let vxneg = _mm256_set1_epi32(xv >> 31);
        let ones = _mm256_set1_epi32(1);
        let vkm1 = _mm256_set1_epi32(k as i32 - 1);
        let bias = _mm256_set1_epi32(127);
        let zero = _mm256_setzero_si256();
        let n = wrow.len();
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let w = _mm256_loadu_si256(wrow.as_ptr().add(j) as *const __m256i);
            let wabs = _mm256_abs_epi32(w);
            let f = _mm256_cvtepi32_ps(_mm256_or_si256(wabs, ones));
            let ex = _mm256_sub_epi32(_mm256_srli_epi32::<23>(_mm256_castps_si256(f)), bias);
            let t = _mm256_max_epi32(_mm256_sub_epi32(ex, vkm1), zero);
            let top = _mm256_sllv_epi32(_mm256_srlv_epi32(wabs, t), t);
            let half = _mm256_srli_epi32::<1>(_mm256_sllv_epi32(ones, t));
            let rb = _mm256_or_si256(top, half);
            let p = _mm256_mullo_epi32(va, rb);
            let wneg = _mm256_cmpgt_epi32(zero, w);
            let neg = _mm256_xor_si256(wneg, vxneg);
            let signed = _mm256_sub_epi32(_mm256_xor_si256(p, neg), neg);
            let ap = acc.as_mut_ptr().add(j);
            let a = _mm256_loadu_si256(ap as *const __m256i);
            _mm256_storeu_si256(ap as *mut __m256i, _mm256_add_epi32(a, signed));
            j += 8;
        }
        cf_tail(Form::Drum(k), xv, wrow, acc, j);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(a: f32, src: &[f32], dst: &mut [f32]) {
        let va = _mm256_set1_ps(a);
        let n = src.len().min(dst.len());
        let n8 = n - n % 8;
        let mut j = 0;
        while j < n8 {
            let s = _mm256_loadu_ps(src.as_ptr().add(j));
            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
            // mul then add (never fmadd): matches scalar rounding exactly.
            _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(d, _mm256_mul_ps(va, s)));
            j += 8;
        }
        while j < n {
            *dst.get_unchecked_mut(j) += a * *src.get_unchecked(j);
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let n8 = n - n % 8;
        let mut vacc = _mm256_setzero_ps();
        let mut j = 0;
        while j < n8 {
            let x = _mm256_loadu_ps(a.as_ptr().add(j));
            let y = _mm256_loadu_ps(b.as_ptr().add(j));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(x, y));
            j += 8;
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        let mut s = 0f32;
        for lane in lanes {
            s += lane;
        }
        while j < n {
            s += *a.get_unchecked(j) * *b.get_unchecked(j);
            j += 1;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// NEON tier
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 4-lane NEON bodies (closed-form + f32 only; no vector gather on
    //! NEON, so the LUT kernels stay scalar on aarch64). `dot` keeps the
    //! canonical 8-lane stripe as two 4-lane accumulators so all tiers
    //! reduce in the same order.

    use super::{cf_tail, masked_consts};
    use crate::mult::Form;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn cf_row(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        match form {
            Form::Exact => cf_exact(xv, wrow, acc),
            Form::TruncIn(_) | Form::PerfPp(_) | Form::TruncOut(_) | Form::CompTruncOut(_) => {
                cf_masked(form, xv, wrow, acc)
            }
            Form::FloorTrunc(k) => cf_floor_trunc(k, xv, wrow, acc),
            Form::Drum(k) => cf_drum(k, xv, wrow, acc),
            Form::Opaque => unreachable!("opaque ACU has no closed form"),
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn cf_exact(xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let va = vdupq_n_s32(xv);
        let n = wrow.len();
        let n4 = n - n % 4;
        let mut j = 0;
        while j < n4 {
            let w = vld1q_s32(wrow.as_ptr().add(j));
            let a = vld1q_s32(acc.as_ptr().add(j));
            vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a, vmulq_s32(va, w)));
            j += 4;
        }
        cf_tail(Form::Exact, xv, wrow, acc, j);
    }

    #[target_feature(enable = "neon")]
    unsafe fn cf_masked(form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let (a_mask, b_mask, out_mask, comp) = masked_consts(form);
        let va = vdupq_n_s32(xv.wrapping_abs() & a_mask);
        let vxneg = vdupq_n_s32(xv >> 31);
        let vbmask = vdupq_n_s32(b_mask);
        let vomask = vdupq_n_s32(out_mask);
        let vcomp = vdupq_n_s32(comp);
        let zero = vdupq_n_s32(0);
        let n = wrow.len();
        let n4 = n - n % 4;
        let mut j = 0;
        while j < n4 {
            let w = vld1q_s32(wrow.as_ptr().add(j));
            let wabs = vandq_s32(vabsq_s32(w), vbmask);
            let praw = vmulq_s32(va, wabs);
            let pmask = vandq_s32(praw, vomask);
            let nz = vreinterpretq_s32_u32(vcgtq_s32(praw, zero));
            let p = vaddq_s32(pmask, vandq_s32(nz, vcomp));
            let wneg = vreinterpretq_s32_u32(vcltq_s32(w, zero));
            let neg = veorq_s32(wneg, vxneg);
            let signed = vsubq_s32(veorq_s32(p, neg), neg);
            let a = vld1q_s32(acc.as_ptr().add(j));
            vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a, signed));
            j += 4;
        }
        cf_tail(form, xv, wrow, acc, j);
    }

    #[target_feature(enable = "neon")]
    unsafe fn cf_floor_trunc(k: u32, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let va = vdupq_n_s32(xv);
        // Negative shift count = arithmetic shift right for signed lanes.
        let down = vdupq_n_s32(-(k as i32));
        let up = vdupq_n_s32(k as i32);
        let n = wrow.len();
        let n4 = n - n % 4;
        let mut j = 0;
        while j < n4 {
            let w = vld1q_s32(wrow.as_ptr().add(j));
            let p = vmulq_s32(va, w);
            let t = vshlq_s32(vshlq_s32(p, down), up);
            let a = vld1q_s32(acc.as_ptr().add(j));
            vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a, t));
            j += 4;
        }
        cf_tail(Form::FloorTrunc(k), xv, wrow, acc, j);
    }

    #[target_feature(enable = "neon")]
    unsafe fn cf_drum(k: u32, xv: i32, wrow: &[i32], acc: &mut [i32]) {
        let va = vdupq_n_s32(crate::mult::drum_reduce_i32(xv.wrapping_abs(), k));
        let vxneg = vdupq_n_s32(xv >> 31);
        let ones = vdupq_n_s32(1);
        let vkm1 = vdupq_n_s32(k as i32 - 1);
        let bias = vdupq_n_s32(127);
        let zero = vdupq_n_s32(0);
        let n = wrow.len();
        let n4 = n - n % 4;
        let mut j = 0;
        while j < n4 {
            let w = vld1q_s32(wrow.as_ptr().add(j));
            let wabs = vabsq_s32(w);
            // floor_log2 via the f32 exponent (exact for |w| < 2^24).
            let f = vcvtq_f32_s32(vorrq_s32(wabs, ones));
            let ex = vsubq_s32(vshrq_n_s32::<23>(vreinterpretq_s32_f32(f)), bias);
            let t = vmaxq_s32(vsubq_s32(ex, vkm1), zero);
            let top = vshlq_s32(vshlq_s32(wabs, vnegq_s32(t)), t);
            let half = vshrq_n_s32::<1>(vshlq_s32(ones, t));
            let rb = vorrq_s32(top, half);
            let p = vmulq_s32(va, rb);
            let wneg = vreinterpretq_s32_u32(vcltq_s32(w, zero));
            let neg = veorq_s32(wneg, vxneg);
            let signed = vsubq_s32(veorq_s32(p, neg), neg);
            let a = vld1q_s32(acc.as_ptr().add(j));
            vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a, signed));
            j += 4;
        }
        cf_tail(Form::Drum(k), xv, wrow, acc, j);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(a: f32, src: &[f32], dst: &mut [f32]) {
        let va = vdupq_n_f32(a);
        let n = src.len().min(dst.len());
        let n4 = n - n % 4;
        let mut j = 0;
        while j < n4 {
            let s = vld1q_f32(src.as_ptr().add(j));
            let d = vld1q_f32(dst.as_ptr().add(j));
            // mul then add (never fma): matches scalar rounding exactly.
            vst1q_f32(dst.as_mut_ptr().add(j), vaddq_f32(d, vmulq_f32(va, s)));
            j += 4;
        }
        while j < n {
            *dst.get_unchecked_mut(j) += a * *src.get_unchecked(j);
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let n8 = n - n % 8;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut j = 0;
        while j < n8 {
            let x0 = vld1q_f32(a.as_ptr().add(j));
            let y0 = vld1q_f32(b.as_ptr().add(j));
            acc0 = vaddq_f32(acc0, vmulq_f32(x0, y0));
            let x1 = vld1q_f32(a.as_ptr().add(j + 4));
            let y1 = vld1q_f32(b.as_ptr().add(j + 4));
            acc1 = vaddq_f32(acc1, vmulq_f32(x1, y1));
            j += 8;
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        let mut s = 0f32;
        for lane in lanes {
            s += lane;
        }
        while j < n {
            s += *a.get_unchecked(j) * *b.get_unchecked(j);
            j += 1;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Four output rows accumulate LUT gathers off one shared biased
/// weight-index stream (the inner step of `gemm::lut_opt_biased`).
///
/// Caller contract (unchecked, as throughout the hot path): every index in
/// `wrow` is inside all four LUT rows and `r0..r3` are at least
/// `wrow.len()` long — guaranteed by plan-build quantization, which clamps
/// to ±qmax before biasing.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn lut_rows4(
    isa: Isa,
    l0: &[i32],
    l1: &[i32],
    l2: &[i32],
    l3: &[i32],
    wrow: &[u16],
    r0: &mut [i32],
    r1: &mut [i32],
    r2: &mut [i32],
    r3: &mut [i32],
) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only produced after runtime feature
        // detection; index/length contract is the caller's (doc above).
        unsafe { avx2::lut_rows4(l0, l1, l2, l3, wrow, r0, r1, r2, r3) };
        return;
    }
    let _ = isa;
    scalar_lut_rows4(l0, l1, l2, l3, wrow, r0, r1, r2, r3);
}

/// Single-row variant of [`lut_rows4`] (tail rows). Same contract.
#[inline]
pub fn lut_row1_i32(isa: Isa, lrow: &[i32], wrow: &[u16], acc: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: as in `lut_rows4`.
        unsafe { avx2::lut_row1_i32(lrow, wrow, acc) };
        return;
    }
    let _ = isa;
    scalar_lut_row1_i32(lrow, wrow, acc);
}

/// i64-accumulating gather step over *unbiased* quantized weights
/// (`gemm::lut_opt`): gathers `lrow[wv + half]`, widens, accumulates.
/// Contract: every `wv + half` is inside `lrow`, `acc.len() >= wrow.len()`.
#[inline]
pub fn lut_row1_i64(isa: Isa, lrow: &[i32], half: i32, wrow: &[i32], acc: &mut [i64]) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: as in `lut_rows4`.
        unsafe { avx2::lut_row1_i64(lrow, half, wrow, acc) };
        return;
    }
    let _ = isa;
    scalar_lut_row1_i64(lrow, half, wrow, acc);
}

/// Closed-form inner step: `acc[j] += form.mul(xv, wrow[j])` with the
/// branchless family bodies vectorized. `form` must satisfy
/// [`Form::is_closed`].
#[inline]
pub fn cf_row_i32(isa: Isa, form: Form, xv: i32, wrow: &[i32], acc: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only produced after runtime detection; the
        // body only touches the overlapping prefix of wrow/acc.
        unsafe { avx2::cf_row(form, xv, wrow, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::cf_row(form, xv, wrow, acc) };
        return;
    }
    let _ = isa;
    scalar_cf_row(form, xv, wrow, acc);
}

/// `dst[j] += a * src[j]` — the fp32 GEMM inner step. Per-element
/// accumulation chains are independent, so lane-splitting preserves the
/// scalar order exactly (bit-identical across tiers).
#[inline]
pub fn axpy_f32(isa: Isa, a: f32, src: &[f32], dst: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only produced after runtime detection; the
        // body only touches the overlapping prefix of src/dst.
        unsafe { avx2::axpy(a, src, dst) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::axpy(a, src, dst) };
        return;
    }
    let _ = isa;
    scalar_axpy(a, src, dst);
}

/// Dot product in the fixed 8-lane striped reduction order (see module
/// docs) — bit-identical across all tiers by construction.
#[inline]
pub fn dot_f32(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only produced after runtime detection.
        return unsafe { avx2::dot(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot(a, b) };
    }
    let _ = isa;
    scalar_dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult;
    use crate::util::rng::Rng;

    #[test]
    fn detect_returns_some_tier() {
        // Smoke: detection is stable and cached.
        assert_eq!(isa(), isa());
    }

    #[test]
    fn cf_row_all_tiers_match_scalar_for_every_closed_form() {
        let mut rng = Rng::new(3);
        let active = isa();
        for m in mult::REGISTRY {
            if !m.form.is_closed() {
                continue;
            }
            let half = 1i64 << (m.bits - 1);
            for n in [1usize, 5, 8, 17, 64, 100] {
                let wrow: Vec<i32> = (0..n).map(|_| rng.range_i64(-half, half) as i32).collect();
                for xv in [-half as i32, -37, -1, 0, 1, 19, half as i32 - 1] {
                    let mut a = vec![0i32; n];
                    let mut b = vec![0i32; n];
                    cf_row_i32(active, m.form, xv, &wrow, &mut a);
                    cf_row_i32(Isa::Scalar, m.form, xv, &wrow, &mut b);
                    assert_eq!(a, b, "{} n={n} xv={xv} isa={active:?}", m.name);
                    // And the scalar body is the Form reference itself.
                    for (j, &wv) in wrow.iter().enumerate() {
                        assert_eq!(b[j], m.form.mul_i32(xv, wv), "{} {xv}*{wv}", m.name);
                    }
                }
            }
        }
    }

    #[test]
    fn lut_helpers_match_scalar_tier() {
        let m8 = mult::get("mitchell8").unwrap();
        let lut = crate::lut::Lut::generate(m8);
        let mut rng = Rng::new(4);
        let active = isa();
        for n in [1usize, 7, 8, 33, 256] {
            let wq: Vec<i32> = (0..n).map(|_| rng.range_i64(-128, 128) as i32).collect();
            let wb: Vec<u16> = wq.iter().map(|&v| (v + 128) as u16).collect();
            let rows: Vec<&[i32]> = (0..4i32).map(|i| lut.row(-61 + 40 * i)).collect();
            let mut g0 = vec![0i32; n];
            let mut g1 = vec![0i32; n];
            let mut g2 = vec![0i32; n];
            let mut g3 = vec![0i32; n];
            lut_rows4(
                active, rows[0], rows[1], rows[2], rows[3], &wb, &mut g0, &mut g1, &mut g2,
                &mut g3,
            );
            let got = [g0, g1, g2, g3];
            for (i, row) in rows.iter().enumerate() {
                let mut want = vec![0i32; n];
                lut_row1_i32(Isa::Scalar, row, &wb, &mut want);
                assert_eq!(got[i], want, "rows4 row {i} n={n}");
                let mut one = vec![0i32; n];
                lut_row1_i32(active, row, &wb, &mut one);
                assert_eq!(one, want, "row1_i32 n={n}");
            }
            let mut a64 = vec![0i64; n];
            let mut b64 = vec![0i64; n];
            lut_row1_i64(active, rows[0], 128, &wq, &mut a64);
            lut_row1_i64(Isa::Scalar, rows[0], 128, &wq, &mut b64);
            assert_eq!(a64, b64, "row1_i64 n={n}");
        }
    }

    #[test]
    fn f32_helpers_bit_identical_across_tiers() {
        let mut rng = Rng::new(5);
        let active = isa();
        for n in [1usize, 7, 8, 9, 64, 129] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_gauss()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_gauss()).collect();
            let mut d0: Vec<f32> = (0..n).map(|_| rng.next_gauss()).collect();
            let mut d1 = d0.clone();
            axpy_f32(active, 1.75, &a, &mut d0);
            axpy_f32(Isa::Scalar, 1.75, &a, &mut d1);
            assert_eq!(d0, d1, "axpy n={n}");
            let s0 = dot_f32(active, &a, &b);
            let s1 = dot_f32(Isa::Scalar, &a, &b);
            assert_eq!(s0.to_bits(), s1.to_bits(), "dot n={n}");
        }
    }
}
