//! Prometheus text-format (exposition format 0.0.4) writer.
//!
//! Just enough of the format for `GET /metrics`: `# HELP` / `# TYPE`
//! headers, counters, gauges, and cumulative histogram series
//! (`_bucket{le=...}` + `_sum` + `_count`). Label values are escaped
//! per the spec (backslash, quote, newline). Metric names are the
//! caller's contract — CI lints that everything exposed matches
//! `adapt_[a-z0-9_]+`.

use std::fmt::Write as _;

/// Streaming builder for one `/metrics` response body.
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter {
            out: String::with_capacity(4096),
        }
    }

    /// `# HELP` + `# TYPE` header for a metric family.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    fn write_labels(&mut self, labels: &[(&str, &str)]) {
        if labels.is_empty() {
            return;
        }
        self.out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                self.out.push(',');
            }
            self.out.push_str(k);
            self.out.push_str("=\"");
            for c in v.chars() {
                match c {
                    '\\' => self.out.push_str("\\\\"),
                    '"' => self.out.push_str("\\\""),
                    '\n' => self.out.push_str("\\n"),
                    c => self.out.push(c),
                }
            }
            self.out.push('"');
        }
        self.out.push('}');
    }

    /// One sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        self.write_labels(labels);
        self.out.push(' ');
        if value.fract() == 0.0 && value.abs() < 9.0e15 {
            let _ = write!(self.out, "{}", value as i64);
        } else {
            let _ = write!(self.out, "{value}");
        }
        self.out.push('\n');
    }

    /// A full cumulative histogram family from per-bucket counts.
    ///
    /// * `uppers` — inclusive upper edge of each bucket (same length as
    ///   `counts`); the last bucket is additionally exposed as `+Inf`.
    /// * `counts` — per-bucket (non-cumulative) observation counts.
    /// * `sum` — total of all observed values, in the metric's unit.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        uppers: &[u64],
        counts: &[u64],
        sum: f64,
    ) {
        debug_assert_eq!(uppers.len(), counts.len());
        let mut cumulative = 0u64;
        let bucket_name = format!("{name}_bucket");
        // The `le` string only lives one iteration, so each bucket line
        // assembles its own label vec rather than reusing one across
        // the loop (this is the cold exposition path).
        for (upper, &c) in uppers.iter().zip(counts) {
            cumulative += c;
            let le = upper.to_string();
            let mut lab = labels.to_vec();
            lab.push(("le", &le));
            self.sample(&bucket_name, &lab, cumulative as f64);
        }
        let mut lab = labels.to_vec();
        lab.push(("le", "+Inf"));
        self.sample(&bucket_name, &lab, cumulative as f64);
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, cumulative as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for PromWriter {
    fn default() -> PromWriter {
        PromWriter::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_and_labels() {
        let mut w = PromWriter::new();
        w.header("adapt_requests_total", "Requests admitted.", "counter");
        w.sample("adapt_requests_total", &[("model", "alpha")], 42.0);
        w.sample("adapt_padding_ratio", &[], 0.125);
        let text = w.finish();
        assert!(text.contains("# TYPE adapt_requests_total counter\n"));
        assert!(text.contains("adapt_requests_total{model=\"alpha\"} 42\n"));
        assert!(text.contains("adapt_padding_ratio 0.125\n"));
    }

    #[test]
    fn label_escaping() {
        let mut w = PromWriter::new();
        w.sample("adapt_x", &[("m", "a\"b\\c\nd")], 1.0);
        assert_eq!(w.finish(), "adapt_x{m=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn histogram_is_cumulative_with_inf() {
        let mut w = PromWriter::new();
        w.histogram(
            "adapt_queue_wait_us",
            &[("model", "m")],
            &[1, 2, 4],
            &[5, 3, 2],
            123.0,
        );
        let text = w.finish();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines,
            vec![
                "adapt_queue_wait_us_bucket{model=\"m\",le=\"1\"} 5",
                "adapt_queue_wait_us_bucket{model=\"m\",le=\"2\"} 8",
                "adapt_queue_wait_us_bucket{model=\"m\",le=\"4\"} 10",
                "adapt_queue_wait_us_bucket{model=\"m\",le=\"+Inf\"} 10",
                "adapt_queue_wait_us_sum{model=\"m\"} 123",
                "adapt_queue_wait_us_count{model=\"m\"} 10",
            ]
        );
    }
}
