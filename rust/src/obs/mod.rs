//! Observability: tracing, profiling, metrics exposition, logging.
//!
//! A dependency-free observability layer threaded through the serving
//! stack. Everything here is built to be cheap-when-off: every hook is
//! gated by one relaxed atomic load (or an `Option` that is `None`), so
//! the GEMM hot path, `tests/kernel_equivalence.rs` and the
//! `BENCH_gemm.json` numbers are unaffected unless a knob is turned on.
//!
//! ## The four surfaces
//!
//! * **Request tracing** ([`trace`]) — every engine owns a
//!   [`trace::TraceRecorder`]. When sampling is on (`ADAPT_TRACE_SAMPLE`
//!   in `(0, 1]`), a request picks up an `Arc<TraceCtx>` at submit time
//!   and the batching loop records `queue` → `batch` → `execute` spans
//!   against it with shared boundary instants (so the intervals are
//!   monotone and non-overlapping by construction). Retention is
//!   *tail-based*: the keep/drop decision happens at finish time, and
//!   errors, deadline misses and overload rejections are always kept
//!   regardless of the sample rate. Retrieval: `GET /v1/trace/{id}` and
//!   `GET /v2/models/{m}/traces`.
//!
//! * **Per-layer kernel profiling** ([`profile`]) — the emulator
//!   executor times each node when a [`profile::LayerProfiler`] is
//!   attached *and* enabled (one relaxed load per forward, then one
//!   `Instant` pair per node), aggregating per-layer call counts, total
//!   ns, an EMA, MAC counts and the resolved kernel tier
//!   (Scalar/Avx2/Neon × LUT/closed-form/fp32). `adapt profile` runs N
//!   batches against a plan and dumps the table as JSON — the per-layer
//!   cost model a plan search can consume; a serving engine exposes the
//!   same table under its model stats when `ADAPT_PROFILE=1`.
//!
//! * **Metrics exposition** (`GET /metrics`, rendered with [`prom`]) —
//!   Prometheus text format: engine counters (requests, batches, padded
//!   slots, queue depth, queue-wait/compute histograms as cumulative
//!   buckets), net-layer counters ([`net_stats::NetStats`]: accepted /
//!   live / refused / idle-closed / pipelined / partial-flush resumes)
//!   and rollout gauges (active version, canary fraction, shadow
//!   disagreement rate). Every name is `adapt_`-prefixed snake_case;
//!   CI's metrics smoke lints the surface and checks counter
//!   monotonicity across scrapes.
//!
//! * **Structured logging** ([`log`]) — a tiny leveled logger
//!   (`ADAPT_LOG=error|warn|info|debug`, default `warn`) writing
//!   `key=value` lines — or JSON lines with `ADAPT_LOG_JSON=1` — to
//!   stderr, replacing the ad-hoc `eprintln!` calls.

pub mod log;
pub mod net_stats;
pub mod profile;
pub mod prom;
pub mod trace;

pub use net_stats::NetStats;
pub use profile::LayerProfiler;
pub use trace::{TraceCtx, TraceOutcome, TraceRecorder};
