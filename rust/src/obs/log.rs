//! Leveled structured logging to stderr, no dependencies.
//!
//! Configuration is read from the environment once, on first use:
//!
//! * `ADAPT_LOG` — minimum level: `error`, `warn` (default), `info`,
//!   `debug`. Anything below the threshold is one relaxed-ish
//!   `OnceLock` read and an integer compare — no formatting, no I/O.
//! * `ADAPT_LOG_JSON=1` — emit one JSON object per line instead of the
//!   human `key=value` form (machine-ingestable; field values are
//!   JSON-escaped strings).
//!
//! Lines carry a unix-microsecond timestamp, the level, a `target`
//! (subsystem tag like `serve` or `engine`), the message, and any
//! structured fields:
//!
//! ```text
//! ts=1754650000123456 level=info target=serve msg="listening" addr=127.0.0.1:8080
//! {"ts":1754650000123456,"level":"info","target":"serve","msg":"listening","addr":"127.0.0.1:8080"}
//! ```

use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered: `Error < Warn < Info < Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

struct Config {
    max: Level,
    json: bool,
}

static CONFIG: OnceLock<Config> = OnceLock::new();

fn config() -> &'static Config {
    CONFIG.get_or_init(|| {
        let max = match std::env::var("ADAPT_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            // `warn`, unset, or anything unrecognized: the quiet default
            // that still surfaces problems (matches the old eprintln!s).
            _ => Level::Warn,
        };
        let json = std::env::var("ADAPT_LOG_JSON").as_deref() == Ok("1");
        Config { max, json }
    })
}

/// Is `level` currently emitted? Callers building expensive field sets
/// can gate on this first.
pub fn enabled(level: Level) -> bool {
    level <= config().max
}

fn unix_us() -> u128 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros())
        .unwrap_or(0)
}

/// Escape a value for the JSON line form.
fn json_escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Quote a `key=value` value only when it needs it.
fn kv_value(s: &str, out: &mut String) {
    let plain = !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-' | ':' | '/' | '+'));
    if plain {
        out.push_str(s);
    } else {
        json_escape(s, out);
    }
}

/// Emit one log line (the work happens only if `level` is enabled).
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let cfg = config();
    let mut line = String::with_capacity(96);
    if cfg.json {
        line.push_str("{\"ts\":");
        line.push_str(&unix_us().to_string());
        line.push_str(",\"level\":\"");
        line.push_str(level.name());
        line.push_str("\",\"target\":");
        json_escape(target, &mut line);
        line.push_str(",\"msg\":");
        json_escape(msg, &mut line);
        for (k, v) in fields {
            line.push(',');
            json_escape(k, &mut line);
            line.push(':');
            json_escape(v, &mut line);
        }
        line.push('}');
    } else {
        line.push_str("ts=");
        line.push_str(&unix_us().to_string());
        line.push_str(" level=");
        line.push_str(level.name());
        line.push_str(" target=");
        line.push_str(target);
        line.push_str(" msg=");
        kv_value(msg, &mut line);
        for (k, v) in fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            kv_value(v, &mut line);
        }
    }
    eprintln!("{line}");
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_values_quote_only_when_needed() {
        let mut out = String::new();
        kv_value("127.0.0.1:8080", &mut out);
        assert_eq!(out, "127.0.0.1:8080");
        let mut out = String::new();
        kv_value("two words", &mut out);
        assert_eq!(out, "\"two words\"");
        let mut out = String::new();
        kv_value("", &mut out);
        assert_eq!(out, "\"\"");
    }

    #[test]
    fn json_escaping_is_valid_json() {
        let mut out = String::new();
        json_escape("a\"b\\c\nd\u{1}", &mut out);
        let parsed = crate::util::json::Json::parse(&out).unwrap();
        assert_eq!(parsed.str().unwrap(), "a\"b\\c\nd\u{1}");
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
