//! Request tracing: per-request span recording with tail-based sampling.
//!
//! Every inference engine owns one [`TraceRecorder`]. At submit time the
//! engine asks the recorder to [`begin`](TraceRecorder::begin) a trace
//! for the request's id; when sampling is off this returns `None` and
//! the request carries no trace state at all. When sampling is on, the
//! request carries a cheap `Arc<TraceCtx>` and the batching loop records
//! spans against it:
//!
//! * `queue` — submit → worker pickup,
//! * `batch` — pickup → batch launch (gather + padding + quantize prep),
//! * `execute` — the batch forward itself, tagged with worker / plan
//!   version / generation.
//!
//! Consecutive spans share their boundary instants, so a trace's
//! intervals are monotone and non-overlapping by construction.
//!
//! **Tail-based sampling**: the keep/drop decision happens at *finish*
//! time, when the outcome is known. Failed requests (engine errors,
//! deadline misses, overload rejections) are always retained; successes
//! are retained when a deterministic hash of the request id falls under
//! the sample rate (`ADAPT_TRACE_SAMPLE` in `(0, 1]`). Retained traces
//! live in a bounded ring (newest win) served by `GET /v1/trace/{id}`
//! and `GET /v2/models/{m}/traces`.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Traces retained per engine.
const RING_CAP: usize = 256;

/// Current wall-clock time as µs since the UNIX epoch (0 if the clock
/// is before it). The net layer stamps its span boundaries with this.
pub fn unix_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// One timed interval inside a request's lifetime. Times are µs offsets
/// from the trace's start.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: &'static str,
    pub start_us: u64,
    pub end_us: u64,
    /// Pool worker that ran this span (execute spans).
    pub worker: Option<usize>,
    /// Plan version the span ran under (execute spans).
    pub version: Option<u64>,
    /// Plan generation the span ran under (execute spans).
    pub generation: Option<u64>,
    /// Batch size the request shared (batch/execute spans).
    pub batch: Option<usize>,
}

impl Span {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.into()));
        m.insert("start_us".into(), Json::Num(self.start_us as f64));
        m.insert("end_us".into(), Json::Num(self.end_us as f64));
        if let Some(w) = self.worker {
            m.insert("worker".into(), Json::Num(w as f64));
        }
        if let Some(v) = self.version {
            m.insert("version".into(), Json::Num(v as f64));
        }
        if let Some(g) = self.generation {
            m.insert("generation".into(), Json::Num(g as f64));
        }
        if let Some(b) = self.batch {
            m.insert("batch".into(), Json::Num(b as f64));
        }
        Json::Obj(m)
    }
}

/// Live (in-flight) trace state carried by a request through the engine.
pub struct TraceCtx {
    pub id: u64,
    /// Submit instant — every span offset is relative to this.
    t0: Instant,
    started_unix_us: u64,
    spans: Mutex<Vec<Span>>,
}

impl TraceCtx {
    fn new(id: u64) -> TraceCtx {
        TraceCtx {
            id,
            t0: Instant::now(),
            started_unix_us: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_micros() as u64)
                .unwrap_or(0),
            spans: Mutex::new(Vec::with_capacity(4)),
        }
    }

    /// µs offset of `at` from the trace start (0 if `at` precedes it).
    pub fn offset_us(&self, at: Instant) -> u64 {
        at.checked_duration_since(self.t0)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    /// Record one finished span.
    pub fn push(&self, span: Span) {
        self.spans.lock().unwrap().push(span);
    }

    /// Plain interval span.
    pub fn span(&self, name: &'static str, start_us: u64, end_us: u64) {
        self.push(Span {
            name,
            start_us,
            end_us,
            worker: None,
            version: None,
            generation: None,
            batch: None,
        });
    }
}

/// How a traced request ended; decides tail-sampling retention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOutcome {
    Ok,
    /// Stable error code (`ServiceError::code()`); always retained.
    Error(&'static str),
}

/// One net-layer interval attached to a retained trace after the fact.
/// Unlike [`Span`] offsets these are absolute unix-µs instants: the net
/// layer's clock starts before the engine trace exists (parse precedes
/// submit), so offsets from the trace start would clamp to zero.
#[derive(Clone, Debug)]
struct NetSpan {
    name: &'static str,
    start_unix_us: u64,
    end_unix_us: u64,
}

/// One retained (finished) trace.
struct FinishedTrace {
    id: u64,
    started_unix_us: u64,
    outcome: &'static str,
    total_us: u64,
    spans: Vec<Span>,
    /// Net-layer accept-to-flush intervals ([`TraceRecorder::annotate`]).
    net: Vec<NetSpan>,
}

impl FinishedTrace {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert(
            "started_unix_us".into(),
            Json::Num(self.started_unix_us as f64),
        );
        m.insert("outcome".into(), Json::Str(self.outcome.into()));
        m.insert("total_us".into(), Json::Num(self.total_us as f64));
        m.insert(
            "spans".into(),
            Json::Arr(self.spans.iter().map(|s| s.to_json()).collect()),
        );
        if !self.net.is_empty() {
            m.insert(
                "net".into(),
                Json::Arr(
                    self.net
                        .iter()
                        .map(|n| {
                            let mut s = BTreeMap::new();
                            s.insert("name".into(), Json::Str(n.name.into()));
                            s.insert(
                                "start_unix_us".into(),
                                Json::Num(n.start_unix_us as f64),
                            );
                            s.insert("end_unix_us".into(), Json::Num(n.end_unix_us as f64));
                            Json::Obj(s)
                        })
                        .collect(),
                ),
            );
        }
        Json::Obj(m)
    }
}

/// Per-engine trace recorder: sampling decision + bounded retention ring.
pub struct TraceRecorder {
    /// Sample rate as f32 bits (atomic so tests and ops can retune a
    /// live engine without racing the submit path).
    sample_bits: AtomicU32,
    ring: Mutex<VecDeque<FinishedTrace>>,
}

impl TraceRecorder {
    /// Recorder with an explicit sample rate (clamped to `[0, 1]`).
    pub fn with_sample(rate: f32) -> TraceRecorder {
        TraceRecorder {
            sample_bits: AtomicU32::new(rate.clamp(0.0, 1.0).to_bits()),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Read `ADAPT_TRACE_SAMPLE` (a rate in `[0, 1]`; unset or
    /// unparseable means 0 = tracing off).
    pub fn from_env() -> TraceRecorder {
        let rate = std::env::var("ADAPT_TRACE_SAMPLE")
            .ok()
            .and_then(|s| s.parse::<f32>().ok())
            .unwrap_or(0.0);
        TraceRecorder::with_sample(rate)
    }

    pub fn sample(&self) -> f32 {
        f32::from_bits(self.sample_bits.load(Ordering::Relaxed))
    }

    /// Retune the sample rate on a live engine.
    pub fn set_sample(&self, rate: f32) {
        self.sample_bits
            .store(rate.clamp(0.0, 1.0).to_bits(), Ordering::Relaxed);
    }

    /// Is tracing on at all? One relaxed load.
    pub fn enabled(&self) -> bool {
        self.sample() > 0.0
    }

    /// Start a trace for request `id`. `None` when tracing is off — the
    /// request then carries no trace state whatsoever.
    pub fn begin(&self, id: u64) -> Option<Arc<TraceCtx>> {
        if !self.enabled() {
            return None;
        }
        Some(Arc::new(TraceCtx::new(id)))
    }

    /// Deterministic per-id sampling hash in `[0, 1)`.
    fn id_hash(id: u64) -> f64 {
        let h = (id ^ 0xD6E8_FEB8_6659_FD93).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> 40) as f64 / (1u64 << 24) as f64
    }

    /// Finish a trace: decide retention (tail-based) and store it.
    pub fn finish(&self, ctx: &TraceCtx, outcome: TraceOutcome) {
        let keep = match outcome {
            // Errors / deadline misses / 503s are always worth keeping.
            TraceOutcome::Error(_) => true,
            TraceOutcome::Ok => Self::id_hash(ctx.id) < self.sample() as f64,
        };
        if !keep {
            return;
        }
        let spans = ctx.spans.lock().unwrap().clone();
        let total_us = spans.iter().map(|s| s.end_us).max().unwrap_or(0);
        let outcome = match outcome {
            TraceOutcome::Ok => "ok",
            TraceOutcome::Error(code) => code,
        };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= RING_CAP {
            ring.pop_front();
        }
        ring.push_back(FinishedTrace {
            id: ctx.id,
            started_unix_us: ctx.started_unix_us,
            outcome,
            total_us,
            spans,
            net: Vec::new(),
        });
    }

    /// Attach a net-layer interval to an already-retained trace (newest
    /// match wins). The net layer only learns a request's trace id after
    /// routing finishes — by which time the engine has finished the trace
    /// — so these arrive post-retention. Returns whether a trace with
    /// that id was found (unsampled/evicted ids are a silent no-op:
    /// sampling stays tail-based, the net layer never forces retention).
    pub fn annotate(
        &self,
        id: u64,
        name: &'static str,
        start_unix_us: u64,
        end_unix_us: u64,
    ) -> bool {
        let mut ring = self.ring.lock().unwrap();
        match ring.iter_mut().rev().find(|t| t.id == id) {
            Some(t) => {
                t.net.push(NetSpan {
                    name,
                    start_unix_us,
                    end_unix_us: end_unix_us.max(start_unix_us),
                });
                true
            }
            None => false,
        }
    }

    /// Look up a retained trace by request id (newest match wins).
    pub fn get(&self, id: u64) -> Option<Json> {
        let ring = self.ring.lock().unwrap();
        ring.iter().rev().find(|t| t.id == id).map(|t| t.to_json())
    }

    /// The newest `limit` retained traces, newest first.
    pub fn recent(&self, limit: usize) -> Json {
        let ring = self.ring.lock().unwrap();
        Json::Arr(ring.iter().rev().take(limit).map(|t| t.to_json()).collect())
    }

    /// Retained trace count (tests).
    pub fn retained(&self) -> usize {
        self.ring.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_off_records_nothing() {
        let rec = TraceRecorder::with_sample(0.0);
        assert!(!rec.enabled());
        assert!(rec.begin(7).is_none());
        assert_eq!(rec.retained(), 0);
    }

    #[test]
    fn sample_one_keeps_everything() {
        let rec = TraceRecorder::with_sample(1.0);
        for id in 0..20 {
            let ctx = rec.begin(id).unwrap();
            ctx.span("queue", 0, 5);
            rec.finish(&ctx, TraceOutcome::Ok);
        }
        assert_eq!(rec.retained(), 20);
        let t = rec.get(13).unwrap();
        assert_eq!(t.get("outcome").unwrap().str().unwrap(), "ok");
        assert_eq!(t.get("id").unwrap().i64().unwrap(), 13);
    }

    #[test]
    fn errors_always_kept_under_low_sampling() {
        let rec = TraceRecorder::with_sample(1.0e-9);
        let mut ok_kept = 0;
        for id in 0..200 {
            let ctx = rec.begin(id).unwrap();
            rec.finish(&ctx, TraceOutcome::Ok);
            ok_kept = rec.retained();
        }
        // At a ~1e-9 rate no success should survive...
        assert_eq!(ok_kept, 0, "successes must be dropped at tiny rates");
        // ...but every error does.
        for id in 200..210 {
            let ctx = rec.begin(id).unwrap();
            ctx.span("queue", 0, 3);
            rec.finish(&ctx, TraceOutcome::Error("deadline_exceeded"));
        }
        assert_eq!(rec.retained(), 10);
        let t = rec.get(205).unwrap();
        assert_eq!(
            t.get("outcome").unwrap().str().unwrap(),
            "deadline_exceeded"
        );
    }

    #[test]
    fn ring_is_bounded_newest_win() {
        let rec = TraceRecorder::with_sample(1.0);
        for id in 0..(RING_CAP as u64 + 50) {
            let ctx = rec.begin(id).unwrap();
            rec.finish(&ctx, TraceOutcome::Ok);
        }
        assert_eq!(rec.retained(), RING_CAP);
        assert!(rec.get(0).is_none(), "oldest evicted");
        assert!(rec.get(RING_CAP as u64 + 49).is_some(), "newest kept");
    }

    #[test]
    fn recent_lists_newest_first() {
        let rec = TraceRecorder::with_sample(1.0);
        for id in 0..5 {
            let ctx = rec.begin(id).unwrap();
            rec.finish(&ctx, TraceOutcome::Ok);
        }
        let arr = rec.recent(3);
        let ids: Vec<i64> = arr
            .arr()
            .unwrap()
            .iter()
            .map(|t| t.get("id").unwrap().i64().unwrap())
            .collect();
        assert_eq!(ids, vec![4, 3, 2]);
    }

    #[test]
    fn annotate_attaches_net_spans_to_retained_trace() {
        let rec = TraceRecorder::with_sample(1.0);
        let ctx = rec.begin(9).unwrap();
        ctx.span("queue", 0, 5);
        rec.finish(&ctx, TraceOutcome::Ok);
        assert!(rec.annotate(9, "net_dispatch_wait", 1_000, 1_200));
        assert!(rec.annotate(9, "net_flush", 1_500, 1_480), "end clamps to start");
        let t = rec.get(9).unwrap();
        let net = t.get("net").unwrap().arr().unwrap().clone();
        assert_eq!(net.len(), 2);
        assert_eq!(net[0].get("name").unwrap().str().unwrap(), "net_dispatch_wait");
        assert_eq!(net[0].get("start_unix_us").unwrap().i64().unwrap(), 1_000);
        assert_eq!(net[1].get("end_unix_us").unwrap().i64().unwrap(), 1_500);
        // Engine spans are untouched.
        assert_eq!(t.get("spans").unwrap().arr().unwrap().len(), 1);
    }

    #[test]
    fn annotate_unretained_id_is_noop() {
        let rec = TraceRecorder::with_sample(1.0);
        assert!(!rec.annotate(404, "net_flush", 0, 1));
        let ctx = rec.begin(1).unwrap();
        rec.finish(&ctx, TraceOutcome::Ok);
        assert!(!rec.annotate(2, "net_flush", 0, 1));
        assert!(rec.get(1).unwrap().get("net").is_err(), "no net key when empty");
    }

    #[test]
    fn id_hash_is_deterministic_and_uniformish() {
        let a = TraceRecorder::id_hash(42);
        assert_eq!(a, TraceRecorder::id_hash(42));
        assert!((0.0..1.0).contains(&a));
        // At rate 0.5, roughly half of sequential ids stay.
        let kept = (0..1000)
            .filter(|&id| TraceRecorder::id_hash(id) < 0.5)
            .count();
        assert!((300..700).contains(&kept), "kept {kept} of 1000 at 0.5");
    }
}
