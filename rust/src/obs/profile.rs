//! Per-layer kernel profiling for the emulator executor.
//!
//! A [`LayerProfiler`] hangs off an [`Executor`] as an
//! `Option<Arc<LayerProfiler>>`; when absent (benches, equivalence
//! tests, the trainer) the forward loop pays nothing, and when attached
//! but disabled it pays one relaxed atomic load per *forward*, not per
//! node. When enabled, each node's wall time is recorded under its
//! layer key along with the resolved kernel identity — SIMD tier
//! (Scalar/Avx2/Neon), product backend (LUT gather / closed-form /
//! fp32 / behavioral function), bitwidth — and the node's MAC count for
//! that batch, aggregated into per-layer counts, totals and an EMA.
//!
//! Two consumers: `adapt profile` (run N batches, dump the table as a
//! JSON cost model) and the serving stats path (`ADAPT_PROFILE=1`
//! attaches an enabled profiler to every engine worker and exposes the
//! table under the model's stats).
//!
//! [`Executor`]: crate::emulator::exec::Executor

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// EMA smoothing for per-call ns (newest sample weight).
const EMA_ALPHA: f64 = 0.2;

/// Aggregated timing for one layer (one node id).
#[derive(Clone, Debug)]
pub struct LayerStat {
    /// Op kind (`conv2d`, `linear`, ...).
    pub op: String,
    /// SIMD tier the kernels dispatched to (`scalar`/`avx2`/`neon`).
    pub tier: String,
    /// Product backend (`lut`, `closed-form`, `func`, `fp32`, `none`).
    pub backend: String,
    /// Quantization bitwidth (0 = fp32 / not a GEMM node).
    pub bits: u32,
    /// Multiply-accumulates in the most recent recorded batch.
    pub macs: u64,
    /// Calls recorded.
    pub count: u64,
    /// Total wall ns across calls.
    pub total_ns: u64,
    /// Exponential moving average of per-call ns.
    pub ema_ns: f64,
}

/// Per-layer profile aggregator. Keys order layers by node index so the
/// dumped table reads in execution order.
pub struct LayerProfiler {
    enabled: AtomicBool,
    layers: Mutex<BTreeMap<String, LayerStat>>,
}

impl LayerProfiler {
    pub fn new(enabled: bool) -> LayerProfiler {
        LayerProfiler {
            enabled: AtomicBool::new(enabled),
            layers: Mutex::new(BTreeMap::new()),
        }
    }

    /// Enabled iff `ADAPT_PROFILE=1` (serving-path construction).
    pub fn from_env() -> LayerProfiler {
        LayerProfiler::new(std::env::var("ADAPT_PROFILE").as_deref() == Ok("1"))
    }

    /// The per-forward gate: one relaxed load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Record one node execution. `key` must sort in execution order
    /// (the executor uses `"{idx:03}:{name}"`).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        key: &str,
        op: &str,
        tier: &str,
        backend: &str,
        bits: u32,
        macs: u64,
        ns: u64,
    ) {
        let mut layers = self.layers.lock().unwrap();
        match layers.get_mut(key) {
            Some(s) => {
                s.count += 1;
                s.total_ns += ns;
                s.macs = macs;
                s.ema_ns = EMA_ALPHA * ns as f64 + (1.0 - EMA_ALPHA) * s.ema_ns;
            }
            None => {
                layers.insert(
                    key.to_string(),
                    LayerStat {
                        op: op.to_string(),
                        tier: tier.to_string(),
                        backend: backend.to_string(),
                        bits,
                        macs,
                        count: 1,
                        total_ns: ns,
                        ema_ns: ns as f64,
                    },
                );
            }
        }
    }

    /// Sum of all recorded per-layer wall ns.
    pub fn total_ns(&self) -> u64 {
        self.layers.lock().unwrap().values().map(|s| s.total_ns).sum()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.layers.lock().unwrap().is_empty()
    }

    /// Drop all aggregates (keeps the enable flag).
    pub fn clear(&self) {
        self.layers.lock().unwrap().clear();
    }

    /// Merge another profiler's aggregates into this one (pool workers
    /// each own a profiler; stats reporting folds them together).
    pub fn merge_into(&self, other: &LayerProfiler) {
        let src = self.layers.lock().unwrap();
        let mut dst = other.layers.lock().unwrap();
        for (k, s) in src.iter() {
            match dst.get_mut(k) {
                Some(d) => {
                    d.count += s.count;
                    d.total_ns += s.total_ns;
                    d.macs = d.macs.max(s.macs);
                    // Weighted blend keeps the EMA meaningful post-merge.
                    d.ema_ns = (d.ema_ns + s.ema_ns) / 2.0;
                }
                None => {
                    dst.insert(k.clone(), s.clone());
                }
            }
        }
    }

    /// The per-layer cost table:
    /// `{"layers": [{name, op, tier, backend, bits, macs, count,
    ///   total_ns, mean_ns, ema_ns}...], "layer_total_ns": N}`.
    pub fn to_json(&self) -> Json {
        let layers = self.layers.lock().unwrap();
        let mut rows = Vec::with_capacity(layers.len());
        let mut total = 0u64;
        for (name, s) in layers.iter() {
            total += s.total_ns;
            let mut m = BTreeMap::new();
            m.insert("name".into(), Json::Str(name.clone()));
            m.insert("op".into(), Json::Str(s.op.clone()));
            m.insert("tier".into(), Json::Str(s.tier.clone()));
            m.insert("backend".into(), Json::Str(s.backend.clone()));
            m.insert("bits".into(), Json::Num(s.bits as f64));
            m.insert("macs".into(), Json::Num(s.macs as f64));
            m.insert("count".into(), Json::Num(s.count as f64));
            m.insert("total_ns".into(), Json::Num(s.total_ns as f64));
            m.insert(
                "mean_ns".into(),
                Json::Num(if s.count > 0 {
                    s.total_ns as f64 / s.count as f64
                } else {
                    0.0
                }),
            );
            m.insert("ema_ns".into(), Json::Num(s.ema_ns));
            rows.push(Json::Obj(m));
        }
        let mut m = BTreeMap::new();
        m.insert("layers".into(), Json::Arr(rows));
        m.insert("layer_total_ns".into(), Json::Num(total as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_gate() {
        let p = LayerProfiler::new(false);
        assert!(!p.enabled());
        p.set_enabled(true);
        assert!(p.enabled());
    }

    #[test]
    fn record_aggregates_and_dumps() {
        let p = LayerProfiler::new(true);
        p.record("001:conv1", "conv2d", "scalar", "lut", 8, 1000, 500);
        p.record("001:conv1", "conv2d", "scalar", "lut", 8, 1000, 700);
        p.record("002:fc", "linear", "scalar", "closed-form", 8, 64, 100);
        assert_eq!(p.total_ns(), 1300);
        let j = p.to_json();
        assert_eq!(j.get("layer_total_ns").unwrap().i64().unwrap(), 1300);
        let rows = j.get("layers").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").unwrap().str().unwrap(), "001:conv1");
        assert_eq!(rows[0].get("count").unwrap().i64().unwrap(), 2);
        assert_eq!(rows[0].get("mean_ns").unwrap().i64().unwrap(), 600);
        assert_eq!(
            rows[1].get("backend").unwrap().str().unwrap(),
            "closed-form"
        );
    }

    #[test]
    fn ema_tracks_recent_cost() {
        let p = LayerProfiler::new(true);
        for _ in 0..50 {
            p.record("000:l", "linear", "scalar", "fp32", 0, 10, 100);
        }
        for _ in 0..50 {
            p.record("000:l", "linear", "scalar", "fp32", 0, 10, 1000);
        }
        let j = p.to_json();
        let ema = j.get("layers").unwrap().arr().unwrap()[0]
            .get("ema_ns")
            .unwrap()
            .f64()
            .unwrap();
        assert!(ema > 900.0, "EMA should converge to recent cost, got {ema}");
    }

    #[test]
    fn merge_folds_counts() {
        let a = LayerProfiler::new(true);
        let b = LayerProfiler::new(true);
        a.record("000:l", "linear", "scalar", "fp32", 0, 10, 100);
        b.record("000:l", "linear", "scalar", "fp32", 0, 10, 300);
        b.record("001:m", "conv2d", "scalar", "lut", 8, 20, 50);
        a.merge_into(&b);
        assert_eq!(b.total_ns(), 450);
        let j = b.to_json();
        let rows = j.get("layers").unwrap().arr().unwrap();
        assert_eq!(rows[0].get("count").unwrap().i64().unwrap(), 2);
    }
}
