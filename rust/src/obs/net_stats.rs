//! Network-layer counters for the readiness-loop front-end.
//!
//! One [`NetStats`] per server process, owned by the `ModelRegistry` so
//! the `/metrics` renderer (which sees the registry) and the event
//! loops (which see it via `NetServer::start`) share the same atomics.
//! Everything is a relaxed counter touched on connection lifecycle
//! edges, never on the per-byte path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::json::Json;

/// Lifecycle counters for the net layer. All monotone except `live`.
#[derive(Default)]
pub struct NetStats {
    /// Connections accepted and registered on an event loop.
    pub accepted: AtomicU64,
    /// Connections refused with 503 at the `max_conns` cap.
    pub refused: AtomicU64,
    /// Connections reaped by the idle-timeout wheel.
    pub idle_closed: AtomicU64,
    /// Requests parsed while the connection already had one in flight
    /// or queued (HTTP/1.1 pipelining depth beyond 1).
    pub pipelined: AtomicU64,
    /// Partial flushes resumed via write-interest (slow readers).
    pub flush_resumes: AtomicU64,
    /// Currently-open connections (gauge).
    pub live: AtomicUsize,
}

impl NetStats {
    pub fn new() -> NetStats {
        NetStats::default()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "accepted".into(),
            Json::Num(self.accepted.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "refused".into(),
            Json::Num(self.refused.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "idle_closed".into(),
            Json::Num(self.idle_closed.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "pipelined".into(),
            Json::Num(self.pipelined.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "flush_resumes".into(),
            Json::Num(self.flush_resumes.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "live".into(),
            Json::Num(self.live.load(Ordering::Relaxed) as f64),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_round_trip_to_json() {
        let s = NetStats::new();
        s.accepted.fetch_add(3, Ordering::Relaxed);
        s.refused.fetch_add(1, Ordering::Relaxed);
        s.live.fetch_add(2, Ordering::Relaxed);
        let j = s.to_json();
        assert_eq!(j.get("accepted").unwrap().i64().unwrap(), 3);
        assert_eq!(j.get("refused").unwrap().i64().unwrap(), 1);
        assert_eq!(j.get("live").unwrap().i64().unwrap(), 2);
        assert_eq!(j.get("pipelined").unwrap().i64().unwrap(), 0);
    }
}
