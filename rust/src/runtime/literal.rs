//! Literal marshalling between the Rust tensors and PJRT.

use anyhow::Result;

/// f32 literal with the given dims.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal with the given dims.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar f32 literal (shape ()).
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Copy a literal out as f32.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Copy a literal out as i32.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
