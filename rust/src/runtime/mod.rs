//! PJRT runtime: loads the AOT artifacts and runs them (the AdaPT fast path).
//!
//! Python lowers every model variant to HLO *text* once (`make artifacts`);
//! this module is the only bridge back: parse text → `XlaComputation` →
//! `PjRtClient::compile` → `execute`. Executables are compiled lazily and
//! cached for the life of the process; parameters can be kept resident as
//! device buffers across train steps (see [`coordinator::retrain`]).
//!
//! Python is never on this path — the `adapt` binary is self-contained
//! given `artifacts/`.

pub mod literal;
pub mod weights;

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::graph::Manifest;

pub use literal::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32, to_vec_i32};

/// Compiled-executable cache keyed by `model/variant`.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative compile time (reported by `adapt table4 --verbose`).
    pub compile_time: Duration,
}

impl Runtime {
    /// Open the artifacts directory and start a CPU PJRT client.
    pub fn open(root: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(root)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
            compile_time: Duration::ZERO,
        })
    }

    /// Compile (or fetch) the executable for a model variant.
    pub fn prepare(&mut self, model: &str, variant: &str) -> Result<()> {
        let key = format!("{model}/{variant}");
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(model, variant)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        self.compile_time += t0.elapsed();
        self.cache.insert(key, exe);
        Ok(())
    }

    /// Execute a prepared variant on literals; returns the decomposed
    /// output tuple (all variants lower with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        model: &str,
        variant: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.prepare(model, variant)?;
        let key = format!("{model}/{variant}");
        let exe = self.cache.get(&key).expect("prepared above");
        let out = exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {key}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
