//! Parameter I/O: flat little-endian f32 blobs + the manifest param specs.
//!
//! `aot.py` writes the deterministic initial weights; the Rust training
//! loops (fp32 pre-training, QAT retraining) write snapshots back under
//! `artifacts/trained/` so experiments can resume without retraining.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::Model;
use crate::tensor::Tensor;

/// Load a parameter list for `model` from a flat f32 blob.
pub fn load_params(model: &Model, path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening weights {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let total: usize = model.params.iter().map(|p| p.numel()).sum();
    if bytes.len() != total * 4 {
        bail!(
            "weights {}: {} bytes != {} params * 4",
            path.display(),
            bytes.len(),
            total
        );
    }
    let mut out = Vec::with_capacity(model.params.len());
    let mut off = 0usize;
    for spec in &model.params {
        let n = spec.numel();
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
            data.push(f32::from_le_bytes(b.try_into().unwrap()));
        }
        off += n;
        out.push(Tensor::from_vec(&spec.shape, data)?);
    }
    Ok(out)
}

/// Save a parameter list as a flat f32 blob (inverse of [`load_params`]).
pub fn save_params(params: &[Tensor], path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut bytes = Vec::new();
    for p in params {
        for &v in &p.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Initial-weights path for a model (as written by aot.py).
pub fn initial_path(root: &Path, model: &Model) -> std::path::PathBuf {
    root.join(&model.weights_file)
}

/// Snapshot path for trained weights (written by the Rust training loop).
pub fn trained_path(root: &Path, model: &Model) -> std::path::PathBuf {
    root.join("trained").join(format!("{}.bin", model.name))
}

/// Snapshot path for QAT-retrained weights (written by `adapt retrain` —
/// plan-specific, so kept separate from the fp32 [`trained_path`]).
pub fn retrained_path(root: &Path, model: &Model) -> std::path::PathBuf {
    root.join("trained").join(format!("{}_qat.bin", model.name))
}
