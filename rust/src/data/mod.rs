//! Deterministic synthetic datasets (DESIGN.md §Substitutions).
//!
//! The paper's datasets (CIFAR10, ImageNet, IMDB, MNIST, Fashion-MNIST)
//! are replaced by generators that preserve what the experiments actually
//! measure: a *learnable* task with the same tensor shapes and class
//! arity. Image classes are smooth random prototype fields + per-sample
//! noise; text classes are token-motif mixtures; recon tasks use the image
//! generator's samples.
//!
//! Everything is seeded through [`crate::util::rng`], so the Rust-driven
//! training runs (Table 2) are exactly reproducible.

use crate::tensor::{Tensor, TensorI32};
use crate::util::rng::Rng;

/// A supervised split: inputs + integer labels.
pub struct Split {
    /// (num, *input_shape) f32, or empty when the input is tokens.
    pub x_f: Vec<f32>,
    /// (num, seq) i32 token inputs (text models), else empty.
    pub x_i: Vec<i32>,
    pub labels: Vec<i32>,
    pub num: usize,
    pub sample_shape: Vec<usize>,
    pub is_tokens: bool,
}

impl Split {
    /// Copy batch `bi` (of size `bs`, padded by wrapping) as a flat buffer.
    pub fn batch_f(&self, bi: usize, bs: usize) -> Vec<f32> {
        let per: usize = self.sample_shape.iter().product();
        let mut out = Vec::with_capacity(bs * per);
        for i in 0..bs {
            let idx = (bi * bs + i) % self.num;
            out.extend_from_slice(&self.x_f[idx * per..(idx + 1) * per]);
        }
        out
    }

    pub fn batch_i(&self, bi: usize, bs: usize) -> Vec<i32> {
        let per: usize = self.sample_shape.iter().product();
        let mut out = Vec::with_capacity(bs * per);
        for i in 0..bs {
            let idx = (bi * bs + i) % self.num;
            out.extend_from_slice(&self.x_i[idx * per..(idx + 1) * per]);
        }
        out
    }

    pub fn batch_labels(&self, bi: usize, bs: usize) -> Vec<i32> {
        (0..bs)
            .map(|i| self.labels[(bi * bs + i) % self.num])
            .collect()
    }

    /// Batch as a Tensor (images) with batch dim prepended.
    pub fn batch_tensor(&self, bi: usize, bs: usize) -> Tensor {
        let mut shape = vec![bs];
        shape.extend_from_slice(&self.sample_shape);
        Tensor::from_vec(&shape, self.batch_f(bi, bs)).expect("batch shape")
    }

    pub fn batch_tensor_i(&self, bi: usize, bs: usize) -> TensorI32 {
        let mut shape = vec![bs];
        shape.extend_from_slice(&self.sample_shape);
        TensorI32::from_vec(&shape, self.batch_i(bi, bs)).expect("batch shape")
    }

    pub fn n_batches(&self, bs: usize) -> usize {
        self.num / bs
    }
}

/// Train + eval pair.
pub struct Dataset {
    pub name: String,
    pub train: Split,
    pub eval: Split,
    pub classes: usize,
}

/// Bilinear-upsample a coarse (gh, gw, c) grid to (h, w, c) — gives each
/// class prototype large-scale spatial structure a CNN can key on.
fn upsample_bilinear(grid: &[f32], gh: usize, gw: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0f32; h * w * c];
    for y in 0..h {
        let fy = y as f32 / h as f32 * (gh - 1) as f32;
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(gh - 1);
        let ty = fy - y0 as f32;
        for x in 0..w {
            let fx = x as f32 / w as f32 * (gw - 1) as f32;
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(gw - 1);
            let tx = fx - x0 as f32;
            for ci in 0..c {
                let g = |yy: usize, xx: usize| grid[(yy * gw + xx) * c + ci];
                let top = g(y0, x0) * (1.0 - tx) + g(y0, x1) * tx;
                let bot = g(y1, x0) * (1.0 - tx) + g(y1, x1) * tx;
                out[(y * w + x) * c + ci] = top * (1.0 - ty) + bot * ty;
            }
        }
    }
    out
}

/// Smooth-prototype image classification generator.
fn gen_images(
    name: &str,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    n_train: usize,
    n_eval: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut root = Rng::new(seed);
    let mut protos: Vec<Vec<f32>> = Vec::with_capacity(classes);
    for k in 0..classes {
        let mut r = root.fork(k as u64 + 1);
        let (gh, gw) = (6, 6);
        let grid: Vec<f32> = (0..gh * gw * c).map(|_| r.next_gauss()).collect();
        protos.push(upsample_bilinear(&grid, gh, gw, c, h, w));
    }
    let per = h * w * c;
    // Samples are prototype *mixtures*: x = a*proto_label + (1-a)*proto_other
    // + noise, a ~ U[MIX_LO, 1]. High-dimensional prototypes are otherwise
    // linearly separable at any pixel noise (the aggregate SNR grows with
    // sqrt(pixels)), which would pin every Table-2 column at 100%. The
    // mixture puts a controllable fraction of samples near the decision
    // boundary, landing fp32 accuracy in the paper's 80–95% band and making
    // ACU error visible.
    const MIX_LO: f32 = 0.44;
    let mut make_split = |n: usize, tag: u64| -> Split {
        let mut r = root.fork(1000 + tag);
        let mut x = Vec::with_capacity(n * per);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let k = i % classes; // balanced
            labels.push(k as i32);
            let other = {
                let o = r.below(classes as u64 - 1) as usize;
                if o >= k {
                    o + 1
                } else {
                    o
                }
            };
            let a = MIX_LO + (1.0 - MIX_LO) * r.next_f32();
            let p = &protos[k];
            let q = &protos[other];
            for j in 0..per {
                x.push((a * p[j] + (1.0 - a) * q[j] + noise * r.next_gauss()).clamp(-3.0, 3.0));
            }
        }
        Split {
            x_f: x,
            x_i: vec![],
            labels,
            num: n,
            sample_shape: vec![h, w, c],
            is_tokens: false,
        }
    };
    Dataset {
        name: name.to_string(),
        train: make_split(n_train, 1),
        eval: make_split(n_eval, 2),
        classes,
    }
}

/// Token-motif text classification (IMDB stand-in, binary).
fn gen_text(
    name: &str,
    seq: usize,
    vocab: usize,
    n_train: usize,
    n_eval: usize,
    seed: u64,
) -> Dataset {
    let mut root = Rng::new(seed);
    // Two sentiment lexicons; class = which lexicon *dominates*. Sentiment
    // tokens are sparse (12% of positions) and noisy (25% drawn from the
    // opposite lexicon), so a handful of ambiguous sequences per batch put
    // accuracy in the paper's ~83% LSTM band instead of a trivial 100%.
    let pos: Vec<i32> = (0..24).map(|i| 8 + i).collect();
    let neg: Vec<i32> = (0..24).map(|i| 40 + i).collect();
    let mut make_split = |n: usize, tag: u64| -> Split {
        let mut r = root.fork(tag);
        let mut x = Vec::with_capacity(n * seq);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let k = (i % 2) as i32;
            labels.push(k);
            for _ in 0..seq {
                if r.next_f32() < 0.20 {
                    let own = r.next_f32() >= 0.18;
                    let lex = if (k == 1) == own { &pos } else { &neg };
                    x.push(lex[r.below(lex.len() as u64) as usize]);
                } else {
                    x.push(r.range_i64(64, vocab as i64) as i32);
                }
            }
        }
        Split {
            x_f: vec![],
            x_i: x,
            labels,
            num: n,
            sample_shape: vec![seq],
            is_tokens: true,
        }
    };
    Dataset {
        name: name.to_string(),
        train: make_split(n_train, 11),
        eval: make_split(n_eval, 12),
        classes: 2,
    }
}

/// Latent-noise dataset for the GAN generator timing workload.
fn gen_noise(name: &str, dim: usize, n: usize, seed: u64) -> Dataset {
    let mut r = Rng::new(seed);
    let mut make = |num: usize| -> Split {
        let x: Vec<f32> = (0..num * dim).map(|_| r.next_gauss()).collect();
        Split {
            x_f: x,
            x_i: vec![],
            labels: vec![0; num],
            num,
            sample_shape: vec![dim],
            is_tokens: false,
        }
    };
    Dataset {
        name: name.to_string(),
        train: make(n),
        eval: make(n),
        classes: 1,
    }
}

/// Dataset sizes: ~10x the paper's "10% retrain subset" spirit scaled to
/// this testbed; eval sized so Table-2 accuracies have ~±1% resolution.
#[derive(Clone, Copy, Debug)]
pub struct Sizes {
    pub n_train: usize,
    pub n_eval: usize,
}

impl Default for Sizes {
    fn default() -> Self {
        Sizes {
            n_train: 2048,
            n_eval: 512,
        }
    }
}

impl Sizes {
    pub fn small() -> Sizes {
        Sizes {
            n_train: 256,
            n_eval: 128,
        }
    }
}

/// Build the dataset a manifest model binds to (by `dataset` name).
pub fn load(dataset: &str, sizes: &Sizes) -> Dataset {
    let (nt, ne) = (sizes.n_train, sizes.n_eval);
    match dataset {
        // Noise levels tuned so fp32 accuracy lands in the paper's 80–95%
        // band — low enough to be learnable, high enough that approximate
        // multiplication visibly costs accuracy (Table 2's middle columns).
        "cifar_syn" => gen_images("cifar_syn", 32, 32, 3, 10, nt, ne, 0.8, 0xC1FA),
        "imagenet_syn32" => gen_images("imagenet_syn32", 32, 32, 3, 10, nt, ne, 0.9, 0x1A6E),
        "mnist_syn" => {
            let mut d = gen_images("mnist_syn", 28, 28, 1, 10, nt, ne, 0.35, 0x3157);
            // Reconstruction target wants near-binary [0,1] pixels (MNIST
            // digits are mostly ink-or-background): sharp sigmoid squash.
            for v in d.train.x_f.iter_mut().chain(d.eval.x_f.iter_mut()) {
                *v = 1.0 / (1.0 + (-*v * 4.0).exp());
            }
            d
        }
        "imdb_syn" => gen_text("imdb_syn", 48, 512, nt, ne, 0x1DB0),
        // Tiny 4-class task bound to `trainer::synth::tiny_cnn` (the
        // artifact-free retraining smoke / bench workload).
        "tiny_syn" => gen_images("tiny_syn", 8, 8, 3, 4, nt, ne, 0.45, 0x7119),
        "noise64" => gen_noise("noise64", 64, ne.max(256), 0x6064),
        other => panic!("unknown dataset {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let s = Sizes::small();
        let a = load("cifar_syn", &s);
        let b = load("cifar_syn", &s);
        assert_eq!(a.train.x_f, b.train.x_f);
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn balanced_labels() {
        let d = load("cifar_syn", &Sizes::small());
        let mut counts = [0usize; 10];
        for &l in &d.train.labels {
            counts[l as usize] += 1;
        }
        let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(mx - mn <= 1, "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // Same-class samples must be closer on average than cross-class.
        let d = load("cifar_syn", &Sizes::small());
        let per: usize = d.train.sample_shape.iter().product();
        let sample = |i: usize| &d.train.x_f[i * per..(i + 1) * per];
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        // samples 0 and 10 share class 0; samples 0 and 1 differ.
        let same = dist(sample(0), sample(10));
        let diff = dist(sample(0), sample(1));
        assert!(same < diff, "same {same} diff {diff}");
    }

    #[test]
    fn mnist_pixels_are_unit_interval() {
        let d = load("mnist_syn", &Sizes::small());
        assert!(d.train.x_f.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn text_lexicons_differ_by_class() {
        let d = load("imdb_syn", &Sizes::small());
        let seq = 48;
        let mut pos_hits = [0usize; 2];
        for i in 0..d.train.num {
            let label = d.train.labels[i] as usize;
            for t in 0..seq {
                let tok = d.train.x_i[i * seq + t];
                if (8..32).contains(&tok) {
                    pos_hits[label] += 1;
                }
            }
        }
        // 75/25 own/opposite lexicon draws => ~3x asymmetry expected.
        assert!(pos_hits[1] > pos_hits[0] * 2, "{pos_hits:?}");
    }

    #[test]
    fn batches_wrap() {
        let d = load("noise64", &Sizes::small());
        let n = d.eval.num;
        let b = d.eval.batch_f(n, 4); // far past the end -> wraps
        assert_eq!(b.len(), 4 * 64);
    }
}
