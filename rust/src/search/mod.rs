//! Plan-search subsystem: cost models and search strategies for mixed-ACU
//! execution plans.
//!
//! The coordinator's sensitivity sweep produces a per-(layer, ACU) accuracy
//! prior; this module turns that prior plus the shared
//! [`SweepCtx::eval_plan`](crate::coordinator::experiments::SweepCtx::eval_plan)
//! scoring path into whole-plan search:
//!
//! - **Greedy** (`coordinator::experiments::greedy_mixed`): sorts layers by
//!   sensitivity and first-fits the cheapest feasible ACU per layer. Fast,
//!   but sequential-by-construction — an early aggressive assignment can
//!   lock later layers out of better joint plans.
//! - **MCTS** ([`mcts`]): Monte Carlo Tree Search under a UCT policy, the
//!   TransAxx (arXiv:2402.07545) approach. Tree nodes are *partial* plans:
//!   depth d fixes the ACU choice for the d-th most sensitive layer
//!   (ascending worst-case accuracy drop from the pairwise sweep, ties by
//!   node id). Expansion at each depth is ordered by a per-candidate prior
//!   (shaped single-layer reward, see [`mcts::SearchSpace::build`]), leaf
//!   rollouts complete the remaining layers uniformly at random from a
//!   per-playout RNG stream and the finished plan is scored on the
//!   calibration batches.
//!
//! ## Cost model: MACs vs accuracy
//!
//! Plan cost is the MAC-weighted mean of per-layer relative multiplier
//! power ([`plan_cost`]): `cost = Σ_l macs_l · power(mode_l) / Σ_l macs_l`,
//! where `power` is the ACU's normalized energy (exact multiplier = 1.0)
//! and `macs_l` comes from static shape propagation ([`layer_macs`]).
//! Savings of a plan relative to the reference single-ACU plan is
//! `(ref_cost − cost) / ref_cost`. A completed plan's reward in `[0, 1]`
//! combines feasibility and savings: plans whose accuracy drop stays within
//! the budget score `0.5 + 0.5·savings` (so every feasible plan beats every
//! infeasible one), while infeasible plans score below `0.4`, shaped by how
//! far they overshoot the budget so the tree still learns *which* subtrees
//! are merely borderline.
//!
//! ## Determinism contract
//!
//! `mcts::search` is bit-deterministic given a seed, at any `ADAPT_THREADS`
//! and any sweep-worker pool size — the same discipline as `sweep_pairs`:
//! playouts are planned sequentially in waves of a *fixed* size (never the
//! thread count) with virtual loss making concurrent playouts diverge,
//! each playout draws from its own RNG stream derived from
//! `seed ⊕ mix(playout_index)`, evaluations fold back through
//! `ThreadPool::run_ordered`, and backpropagation commits in playout-index
//! order. Plan evaluation itself (`SweepCtx::eval_plan_threads`) is
//! bit-deterministic at any thread count, so per-job GEMM thread splits
//! cannot perturb scores.

pub mod mcts;

use std::collections::BTreeMap;

use crate::graph::{ExecutionPlan, LayerMode, Model, Op};

/// Relative power of an ACU (exact multiplier = 1.0). Unknown names fall
/// back to 1.0 so cost never rewards a typo.
pub fn acu_power(acu: &str) -> f64 {
    crate::mult::get(acu).map(|m| m.power).unwrap_or(1.0)
}

/// Relative power of a layer mode: LUT-backed modes look up the ACU's
/// power; Fp32 and closed-form-without-ACU modes count as exact.
pub fn mode_power(mode: &LayerMode) -> f64 {
    match mode {
        LayerMode::ApproxLut { acu } => acu_power(acu),
        LayerMode::Fp32 | LayerMode::ApproxFunc { .. } => 1.0,
    }
}

/// Static per-layer MAC counts for every quantizable node, from shape
/// propagation over the graph (no execution needed). Mirrors the dynamic
/// `node_macs` accounting in the executor's profiler.
pub fn layer_macs(model: &Model) -> BTreeMap<usize, u64> {
    static_counts(model).0
}

/// Static per-layer *output-element* counts (per sample) for every
/// quantizable node — the add count an error-compensation epilogue pays
/// on that layer ([`plan_cost_comp`]).
pub fn layer_outputs(model: &Model) -> BTreeMap<usize, u64> {
    static_counts(model).1
}

/// Shared shape walk behind [`layer_macs`] / [`layer_outputs`].
fn static_counts(model: &Model) -> (BTreeMap<usize, u64>, BTreeMap<usize, u64>) {
    // Track (h, w, c) per node id; (1, 1, features) for flat tensors.
    let mut shapes: BTreeMap<usize, (usize, usize, usize)> = BTreeMap::new();
    let mut macs = BTreeMap::new();
    let mut outs = BTreeMap::new();
    let input_hwc = match model.input_shape.as_slice() {
        [h, w, c] => (*h, *w, *c),
        [n] => (1usize, 1usize, *n),
        _ => (1, 1, 1),
    };
    // Token/sequence models feed an i32 id sequence; treat the flattened
    // input length as the sequence length for LSTM MAC accounting.
    let seq_len: usize = model.input_shape.iter().product::<usize>().max(1);
    for node in &model.nodes {
        let inp = |i: usize| -> (usize, usize, usize) {
            node.inputs
                .get(i)
                .and_then(|id| shapes.get(id).copied())
                .unwrap_or((1, 1, 1))
        };
        let shape = match &node.op {
            Op::Input => input_hwc,
            Op::Conv2d { kh, kw, cin, cout, stride, pad, groups, .. } => {
                let (h, w, _) = inp(0);
                let ho = (h + 2 * pad).saturating_sub(*kh) / stride + 1;
                let wo = (w + 2 * pad).saturating_sub(*kw) / stride + 1;
                let m = (ho * wo * cout) as u64 * (*kh as u64) * (*kw as u64) * (*cin as u64)
                    / (*groups).max(1) as u64;
                macs.insert(node.id, m);
                outs.insert(node.id, (ho * wo * cout) as u64);
                (ho, wo, *cout)
            }
            Op::Linear { din, dout, .. } => {
                macs.insert(node.id, (*din as u64) * (*dout as u64));
                outs.insert(node.id, *dout as u64);
                (1, 1, *dout)
            }
            Op::Lstm { din, hidden, .. } => {
                let m = (seq_len as u64) * 4 * (*hidden as u64) * (*din as u64 + *hidden as u64);
                macs.insert(node.id, m);
                outs.insert(node.id, (seq_len as u64) * (*hidden as u64));
                (1, 1, *hidden)
            }
            Op::AvgPool2 => {
                let (h, w, c) = inp(0);
                (h / 2, w / 2, c)
            }
            Op::Gap => {
                let (_, _, c) = inp(0);
                (1, 1, c)
            }
            Op::Flatten => {
                let (h, w, c) = inp(0);
                (1, 1, h * w * c)
            }
            Op::Concat => {
                let (h, w, c0) = inp(0);
                let (_, _, c1) = inp(1);
                (h, w, c0 + c1)
            }
            Op::Reshape { shape } => match shape.as_slice() {
                [h, w, c] => (*h, *w, *c),
                [n] => (1, 1, *n),
                _ => inp(0),
            },
            Op::Embedding { dim, .. } => (1, 1, *dim),
            Op::Relu
            | Op::Sigmoid
            | Op::Tanh
            | Op::Add
            | Op::ChannelShuffle { .. }
            | Op::SliceLast { .. } => inp(0),
        };
        shapes.insert(node.id, shape);
    }
    (macs, outs)
}

/// MAC-weighted mean relative power of a plan over `model`'s quantizable
/// layers. Layers without a static MAC estimate weigh 1 MAC; a model with
/// no quantizable layers costs 1.0 (exact).
pub fn plan_cost(model: &Model, plan: &ExecutionPlan) -> f64 {
    plan_cost_macs(&layer_macs(model), plan)
}

/// [`plan_cost`] with precomputed MAC weights (hot loop in search).
pub fn plan_cost_macs(macs: &BTreeMap<usize, u64>, plan: &ExecutionPlan) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (id, mode) in &plan.modes {
        let w = macs.get(id).copied().unwrap_or(1).max(1) as f64;
        num += w * mode_power(mode);
        den += w;
    }
    if den > 0.0 {
        num / den
    } else {
        1.0
    }
}

/// Relative power of one compensation *add* vs one exact MAC. The
/// correction is a single add folded into the bias epilogue, so it is far
/// cheaper than a multiply-accumulate; 0.05 matches the adder/multiplier
/// energy ratio the Zervakis control-variate papers assume.
pub const COMP_ADD_POWER: f64 = 0.05;

/// [`plan_cost_macs`] plus the compensation surcharge: every layer that
/// carries a [`crate::graph::Compensation`] block pays
/// `outputs · COMP_ADD_POWER` extra adds (MAC-normalized). With no
/// compensation anywhere this is exactly [`plan_cost_macs`].
pub fn plan_cost_comp(
    macs: &BTreeMap<usize, u64>,
    outs: &BTreeMap<usize, u64>,
    plan: &ExecutionPlan,
) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (id, mode) in &plan.modes {
        let w = macs.get(id).copied().unwrap_or(1).max(1) as f64;
        num += w * mode_power(mode);
        den += w;
        if plan.compensation.contains_key(id) {
            num += outs.get(id).copied().unwrap_or(1).max(1) as f64 * COMP_ADD_POWER;
        }
    }
    if den > 0.0 {
        num / den
    } else {
        1.0
    }
}

/// Which whole-plan search strategy drives `adapt sensitivity` / `adapt
/// search`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMethod {
    /// Sensitivity-ordered first-fit descent (`greedy_mixed`).
    Greedy,
    /// Monte Carlo Tree Search with UCT + virtual loss ([`mcts`]).
    Mcts,
}

impl SearchMethod {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Ok(SearchMethod::Greedy),
            "mcts" => Ok(SearchMethod::Mcts),
            other => anyhow::bail!("unknown search method '{other}' (expected greedy|mcts)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SearchMethod::Greedy => "greedy",
            SearchMethod::Mcts => "mcts",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Policy;

    #[test]
    fn layer_macs_tiny_cnn() {
        let model = crate::trainer::synth::tiny_cnn();
        let macs = layer_macs(&model);
        // tiny_cnn: 8x8x3 input; c1 conv3x3 3->8 pad1 (node 1): 8*8*8*3*3*3;
        // AvgPool2 halves to 4x4; c2 conv3x3 8->8 pad1 (node 4): 4*4*8*3*3*8;
        // Gap -> 1x1x8; head linear 8->4 (node 7): 8*4.
        assert_eq!(macs.get(&1), Some(&13824));
        assert_eq!(macs.get(&4), Some(&9216));
        assert_eq!(macs.get(&7), Some(&32));
        assert_eq!(macs.len(), 3);
    }

    #[test]
    fn plan_cost_weighs_macs() {
        let model = crate::trainer::synth::tiny_cnn();
        let exact = crate::graph::retransform(&model, &Policy::all(LayerMode::lut("exact8")));
        let cost = plan_cost(&model, &exact);
        assert!((cost - 1.0).abs() < 1e-12, "exact plan costs 1.0, got {cost}");

        // Approximating only the biggest layer must move cost more than
        // approximating only the smallest.
        let p_small = acu_power("drum8_6");
        assert!(p_small < 1.0, "drum8_6 must be cheaper than exact");
        let mut big = exact.clone();
        big.modes.insert(1, LayerMode::lut("drum8_6"));
        let mut small = exact.clone();
        small.modes.insert(7, LayerMode::lut("drum8_6"));
        let c_big = plan_cost(&model, &big);
        let c_small = plan_cost(&model, &small);
        assert!(c_big < c_small, "MAC-heavy layer must dominate: {c_big} vs {c_small}");
        let macs = layer_macs(&model);
        let total: u64 = macs.values().sum();
        let expect = (1.0 * (total - 13824) as f64 + p_small * 13824.0) / total as f64;
        assert!((c_big - expect).abs() < 1e-9, "{c_big} vs {expect}");
    }

    #[test]
    fn layer_outputs_tiny_cnn() {
        let model = crate::trainer::synth::tiny_cnn();
        let outs = layer_outputs(&model);
        // c1: 8x8x8 outputs; c2 after AvgPool2: 4x4x8; head: 4.
        assert_eq!(outs.get(&1), Some(&512));
        assert_eq!(outs.get(&4), Some(&128));
        assert_eq!(outs.get(&7), Some(&4));
        assert_eq!(outs.len(), 3);
    }

    #[test]
    fn plan_cost_comp_charges_adds() {
        let model = crate::trainer::synth::tiny_cnn();
        let macs = layer_macs(&model);
        let outs = layer_outputs(&model);
        let mut plan =
            crate::graph::retransform(&model, &Policy::all(LayerMode::lut("mitchell8")));
        let base = plan_cost_macs(&macs, &plan);
        // No compensation anywhere: the two models agree exactly.
        assert_eq!(plan_cost_comp(&macs, &outs, &plan), base);
        plan.compensation.insert(
            1,
            crate::graph::Compensation {
                constant: 0.1,
                channels: vec![],
            },
        );
        // Modes-only cost ignores compensation (the "identical
        // MAC-weighted power" twin contract) ...
        assert_eq!(plan_cost_macs(&macs, &plan), base);
        // ... while the comp-aware cost pays 512 adds on node 1.
        let total: u64 = macs.values().sum();
        let expect = base + 512.0 * COMP_ADD_POWER / total as f64;
        let got = plan_cost_comp(&macs, &outs, &plan);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    #[test]
    fn search_method_parse_roundtrip() {
        assert_eq!(SearchMethod::parse("mcts").unwrap(), SearchMethod::Mcts);
        assert_eq!(SearchMethod::parse("GREEDY").unwrap(), SearchMethod::Greedy);
        assert!(SearchMethod::parse("anneal").is_err());
        assert_eq!(SearchMethod::Mcts.label(), "mcts");
    }
}
