//! Monte Carlo Tree Search over mixed-ACU execution plans.
//!
//! Tree shape: depth `d` in the tree fixes the ACU for `SearchSpace::layers[d]`
//! (layers ordered most-sensitive-first so the hard decisions are made near
//! the root, where the tree accumulates the most statistics). Children at a
//! depth are the layer's candidate modes in prior order; expansion visits
//! them in that order before UCT takes over. Leaf rollouts fill the
//! remaining layers uniformly at random from the playout's private RNG
//! stream, and the completed plan is scored once on the calibration batches
//! through `SweepCtx::eval_plan` — the same code path greedy and the
//! benches use.
//!
//! Parallelism: playouts are planned sequentially in fixed-size waves with
//! *virtual loss* (each planned-but-unscored playout temporarily counts as
//! a zero-reward visit along its path, pushing sibling playouts in the same
//! wave toward different subtrees), evaluated concurrently via
//! `ThreadPool::run_ordered`, then committed in playout-index order. The
//! wave size is a config constant — never the thread count — so the visit
//! sequence, and therefore the result, is identical at any `ADAPT_THREADS`
//! or worker-pool size for a fixed seed.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::experiments::SweepCtx;
use crate::data::Split;
use crate::graph::{ExecutionPlan, LayerMode, Model};
use crate::util::rng::{Rng, SplitMix64};
use crate::util::threadpool::ThreadPool;

use super::{acu_power, layer_macs, plan_cost_macs};

/// Tuning knobs for [`search`]. `evals` is the hard budget of *fresh* plan
/// evaluations (cache hits are free); `wave` is the fixed parallel-playout
/// wave size that the determinism contract pins independent of thread
/// count.
#[derive(Clone, Debug)]
pub struct MctsConfig {
    pub seed: u64,
    /// Budget of fresh (uncached) plan evaluations.
    pub evals: usize,
    /// Playouts planned per wave; fixed so results never depend on thread
    /// count. Default 8.
    pub wave: usize,
    /// UCT exploration constant.
    pub c_uct: f64,
    /// Hard cap on planned playouts (cache hits re-visit known plans
    /// without consuming budget, so playouts can exceed `evals`).
    /// 0 means `16 * evals`, at least 64.
    pub max_playouts: usize,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig { seed: 0x5EED, evals: 64, wave: 8, c_uct: 0.5, max_playouts: 0 }
    }
}

impl MctsConfig {
    fn playout_cap(&self) -> usize {
        if self.max_playouts > 0 {
            self.max_playouts
        } else {
            (16 * self.evals).max(64)
        }
    }
}

/// One decision in the tree: which mode the given layer runs in.
#[derive(Clone, Debug)]
pub struct LayerChoice {
    pub node: usize,
    pub name: String,
    /// Candidate modes, prior-ordered (index 0 expands first). Always
    /// contains the reference ("keep") mode so every subtree can fall back
    /// to exact.
    pub candidates: Vec<LayerMode>,
}

/// The search problem: decision layers in depth order, the reference plan
/// rollouts start from, the accuracy budget, and the MAC cost model.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Decision order: most sensitive layer first (ascending worst-case
    /// pairwise accuracy, i.e. biggest drop first), ties by node id.
    pub layers: Vec<LayerChoice>,
    pub reference: ExecutionPlan,
    pub base_acc: f64,
    /// Maximum tolerated accuracy drop (absolute, e.g. 0.02).
    pub budget: f64,
    pub macs: BTreeMap<usize, u64>,
    pub ref_cost: f64,
}

/// Reward shaping shared by playout scoring and expansion priors.
/// Feasible (drop ≤ budget) → `0.5 + 0.5·savings` in `[0.5, 1.0]`;
/// infeasible → `< 0.4`, decaying with overshoot so borderline subtrees
/// still rank above hopeless ones.
pub fn shaped_reward(drop: f64, budget: f64, savings: f64) -> f64 {
    if drop <= budget {
        0.5 + 0.5 * savings.clamp(0.0, 1.0)
    } else {
        let over = ((drop - budget) / budget.max(1e-9)).min(1.0);
        0.4 * (1.0 - over).max(0.0)
    }
}

/// UCT score of a child with `visits` committed visits, `total` committed
/// reward, and `vloss` in-flight virtual losses, under a parent with
/// `parent_n` effective visits. Virtual losses count as zero-reward visits,
/// deflating both the exploitation and exploration terms for nodes already
/// claimed by the current wave. Unvisited nodes score `+inf` (expansion
/// order decides among them).
pub fn uct_score(total: f64, visits: u64, vloss: u32, parent_n: u64, c: f64) -> f64 {
    let n = visits + vloss as u64;
    if n == 0 {
        return f64::INFINITY;
    }
    let q = total / n as f64;
    let ln_p = (parent_n.max(1) as f64).ln().max(0.0);
    q + c * (ln_p / n as f64).sqrt()
}

impl SearchSpace {
    /// Build the space from sweep results. `pair_accs` is the
    /// layer-major/ACU-minor accuracy matrix from `sweep_pairs` over
    /// `layers` × `acus`. Candidates keep only ACUs strictly cheaper than
    /// the reference, plus the reference itself; each layer's candidates
    /// are ordered by the shaped single-layer reward of flipping just that
    /// layer (descending, ties by mode label for stability).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        model: &Model,
        reference: ExecutionPlan,
        reference_acu: &str,
        base_acc: f64,
        budget: f64,
        layers: &[(usize, String)],
        pair_accs: &[f64],
        acus: &[String],
    ) -> Result<SearchSpace> {
        ensure!(
            pair_accs.len() == layers.len() * acus.len(),
            "sweep matrix is {} entries, expected {}x{}",
            pair_accs.len(),
            layers.len(),
            acus.len()
        );
        let macs = layer_macs(model);
        let total_macs: u64 = macs.values().sum::<u64>().max(1);
        let ref_cost = plan_cost_macs(&macs, &reference);
        let ref_p = acu_power(reference_acu);

        // Per-layer worst-case drop orders the decision depths.
        let mut order: Vec<(f64, usize)> = Vec::with_capacity(layers.len());
        for (li, _) in layers.iter().enumerate() {
            let worst = (0..acus.len())
                .map(|ai| pair_accs[li * acus.len() + ai])
                .fold(f64::INFINITY, f64::min);
            order.push((worst, li));
        }
        // Most sensitive (lowest worst accuracy) first; ties by node id.
        order.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal)
                .then(layers[a.1].0.cmp(&layers[b.1].0))
        });

        let mut out_layers = Vec::with_capacity(layers.len());
        for &(_, li) in &order {
            let (node, name) = &layers[li];
            let keep = reference.mode_of(*node);
            let lmacs = macs.get(node).copied().unwrap_or(1).max(1) as f64;
            let mut cands: Vec<(f64, String, LayerMode)> = vec![(0.5, keep.label(), keep.clone())];
            for (ai, acu) in acus.iter().enumerate() {
                let p = acu_power(acu);
                if p >= ref_p {
                    continue;
                }
                let acc = pair_accs[li * acus.len() + ai];
                let drop = (base_acc - acc).max(0.0);
                // Savings from flipping only this layer.
                let savings = lmacs * (ref_p - p) / (total_macs as f64 * ref_p.max(1e-9));
                let prior = shaped_reward(drop, budget, savings);
                cands.push((prior, format!("lut:{acu}"), LayerMode::lut(acu)));
            }
            cands.sort_by(|a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
            });
            cands.dedup_by(|a, b| a.1 == b.1);
            out_layers.push(LayerChoice {
                node: *node,
                name: name.clone(),
                candidates: cands.into_iter().map(|(_, _, m)| m).collect(),
            });
        }
        Ok(SearchSpace {
            layers: out_layers,
            reference,
            base_acc,
            budget,
            macs,
            ref_cost: if ref_cost > 0.0 { ref_cost } else { 1.0 },
        })
    }

    /// Fractional MAC-cost savings of `plan` vs the reference plan,
    /// clamped to `[0, 1]`.
    pub fn savings(&self, plan: &ExecutionPlan) -> f64 {
        ((self.ref_cost - plan_cost_macs(&self.macs, plan)) / self.ref_cost).clamp(0.0, 1.0)
    }

    /// Reward of a completed plan given its measured accuracy.
    pub fn reward(&self, acc: f64, plan: &ExecutionPlan) -> f64 {
        shaped_reward((self.base_acc - acc).max(0.0), self.budget, self.savings(plan))
    }

    /// Deterministic cache key for a plan (node→mode labels).
    pub fn plan_key(plan: &ExecutionPlan) -> String {
        let parts: Vec<String> =
            plan.modes.iter().map(|(id, m)| format!("{id}={}", m.label())).collect();
        parts.join(",")
    }
}

/// Per-playout RNG stream: independent of every other playout, derived
/// only from the search seed and the playout's global index.
fn playout_rng(seed: u64, index: u64) -> Rng {
    let mut sm = SplitMix64::new(seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    Rng::new(sm.next_u64())
}

struct NodeStat {
    parent: usize,
    depth: usize,
    /// Candidate index within the layer at `depth - 1` (root: unused).
    choice: usize,
    /// child node-id per candidate index; `usize::MAX` = unexpanded.
    children: Vec<usize>,
    visits: u64,
    total: f64,
    vloss: u32,
}

/// A planned playout: a completed plan, its cache key, its global index
/// (RNG stream id + commit order), and the tree path holding its virtual
/// loss.
pub struct Playout {
    pub plan: ExecutionPlan,
    pub key: String,
    pub index: u64,
    path: Vec<usize>,
}

/// The search tree. Public so tests can drive selection/backprop directly
/// on hand-built spaces.
pub struct Mcts {
    space: SearchSpace,
    cfg: MctsConfig,
    nodes: Vec<NodeStat>,
    next_index: u64,
}

impl Mcts {
    pub fn new(space: SearchSpace, cfg: MctsConfig) -> Mcts {
        let root_children = space.layers.first().map(|l| l.candidates.len()).unwrap_or(0);
        Mcts {
            space,
            cfg,
            nodes: vec![NodeStat {
                parent: usize::MAX,
                depth: 0,
                choice: 0,
                children: vec![usize::MAX; root_children],
                visits: 0,
                total: 0.0,
                vloss: 0,
            }],
            next_index: 0,
        }
    }

    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    pub fn root_visits(&self) -> u64 {
        self.nodes[0].visits
    }

    pub fn playouts_planned(&self) -> u64 {
        self.next_index
    }

    /// Total outstanding virtual loss across the tree (0 when no playout
    /// is in flight).
    pub fn total_vloss(&self) -> u64 {
        self.nodes.iter().map(|n| n.vloss as u64).sum()
    }

    /// Plan one playout: descend by expansion-order-then-UCT, place a
    /// virtual loss along the path, and complete the plan with a rollout
    /// from the playout's own RNG stream.
    pub fn plan_playout(&mut self) -> Playout {
        let index = self.next_index;
        self.next_index += 1;
        let mut rng = playout_rng(self.cfg.seed, index);

        let mut path = vec![0usize];
        let mut cur = 0usize;
        let mut choices: Vec<(usize, usize)> = Vec::new(); // (depth, candidate idx)
        loop {
            let depth = self.nodes[cur].depth;
            if depth >= self.space.layers.len() {
                break;
            }
            // Expand the first unexpanded child, in prior order.
            if let Some(ci) =
                self.nodes[cur].children.iter().position(|&c| c == usize::MAX)
            {
                let child_cands = self
                    .space
                    .layers
                    .get(depth + 1)
                    .map(|l| l.candidates.len())
                    .unwrap_or(0);
                let id = self.nodes.len();
                self.nodes.push(NodeStat {
                    parent: cur,
                    depth: depth + 1,
                    choice: ci,
                    children: vec![usize::MAX; child_cands],
                    visits: 0,
                    total: 0.0,
                    vloss: 0,
                });
                self.nodes[cur].children[ci] = id;
                choices.push((depth, ci));
                path.push(id);
                cur = id;
                break; // rollout from the fresh leaf
            }
            // All children expanded: UCT argmax (strict > keeps first-best
            // on ties, deterministic).
            let parent_n = self.nodes[cur].visits + self.nodes[cur].vloss as u64;
            let mut best_ci = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for (ci, &child) in self.nodes[cur].children.iter().enumerate() {
                let ch = &self.nodes[child];
                let s = uct_score(ch.total, ch.visits, ch.vloss, parent_n, self.cfg.c_uct);
                if s > best_score {
                    best_score = s;
                    best_ci = ci;
                }
            }
            let child = self.nodes[cur].children[best_ci];
            choices.push((depth, best_ci));
            path.push(child);
            cur = child;
        }
        for &n in &path {
            self.nodes[n].vloss += 1;
        }
        // Rollout: random candidates for the remaining depths.
        let decided = choices.len();
        for d in decided..self.space.layers.len() {
            let n = self.space.layers[d].candidates.len();
            let ci = if n > 1 { rng.below(n as u64) as usize } else { 0 };
            choices.push((d, ci));
        }
        let mut plan = self.space.reference.clone();
        for (d, ci) in &choices {
            let layer = &self.space.layers[*d];
            plan.modes.insert(layer.node, layer.candidates[*ci].clone());
        }
        let key = SearchSpace::plan_key(&plan);
        Playout { plan, key, index, path }
    }

    /// Commit a scored playout: replace its virtual loss with a real
    /// visit carrying `reward`.
    pub fn commit(&mut self, p: &Playout, reward: f64) {
        for &n in &p.path {
            let node = &mut self.nodes[n];
            node.vloss = node.vloss.saturating_sub(1);
            node.visits += 1;
            node.total += reward;
        }
    }

    /// Abandon a planned playout (budget exhausted): lift its virtual
    /// loss without recording a visit.
    pub fn revert(&mut self, p: &Playout) {
        for &n in &p.path {
            let node = &mut self.nodes[n];
            node.vloss = node.vloss.saturating_sub(1);
        }
    }
}

/// Optional QAT-in-the-loop re-scoring of the best leaves: the top
/// `leaves` distinct plans by reward get a short `trainer::fit` run and
/// are re-scored with the retrained weights.
pub struct RetrainCtx<'a> {
    pub train: &'a Split,
    pub leaves: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct SearchOutcome {
    pub plan: ExecutionPlan,
    pub accuracy: f64,
    pub cost: f64,
    pub savings: f64,
    pub reward: f64,
    /// Fresh evaluations consumed (incumbent counts as 1; reference is
    /// free — it was already measured to establish `base_acc`).
    pub evals: usize,
    pub cache_hits: usize,
    pub playouts: u64,
    /// Leaves re-scored with QAT.
    pub retrained: usize,
    /// Whether the returned plan meets the accuracy budget.
    pub feasible: bool,
}

/// Run MCTS over `space` with a budget of `cfg.evals` fresh plan
/// evaluations. `incumbent` (typically greedy's plan + accuracy)
/// warm-starts the cache and best-tracking and is charged 1 evaluation,
/// keeping equal-budget comparisons against greedy honest — and
/// guaranteeing the outcome is never worse than the incumbent.
/// Deterministic given `cfg.seed` at any pool size / `ADAPT_THREADS`.
pub fn search(
    ctx: &Arc<SweepCtx>,
    space: SearchSpace,
    cfg: &MctsConfig,
    incumbent: Option<(&ExecutionPlan, f64)>,
    pool: Option<&ThreadPool>,
    retrain: Option<&RetrainCtx>,
) -> Result<SearchOutcome> {
    ensure!(cfg.evals > 0, "mcts: evaluation budget must be > 0");
    ensure!(cfg.wave > 0, "mcts: wave size must be > 0");
    let mut tree = Mcts::new(space, cfg.clone());

    // Ledger of every scored plan: key -> (accuracy, plan).
    let mut cache: BTreeMap<String, (f64, ExecutionPlan)> = BTreeMap::new();
    let ref_plan = tree.space.reference.clone();
    cache.insert(SearchSpace::plan_key(&ref_plan), (tree.space.base_acc, ref_plan.clone()));

    let mut evals = 0usize;
    let mut cache_hits = 0usize;

    // Best = (reward, accuracy, key, plan); replace on strictly greater
    // reward, tie-break higher accuracy, then smaller key.
    let better = |cand: (f64, f64, &str), best: &Option<(f64, f64, String, ExecutionPlan)>| {
        match best {
            None => true,
            Some((br, ba, bk, _)) => {
                cand.0 > *br
                    || (cand.0 == *br && cand.1 > *ba)
                    || (cand.0 == *br && cand.1 == *ba && cand.2 < bk.as_str())
            }
        }
    };
    let mut best: Option<(f64, f64, String, ExecutionPlan)> = None;
    {
        let r = tree.space.reward(tree.space.base_acc, &ref_plan);
        let k = SearchSpace::plan_key(&ref_plan);
        if better((r, tree.space.base_acc, k.as_str()), &best) {
            best = Some((r, tree.space.base_acc, k, ref_plan.clone()));
        }
    }
    if let Some((plan, acc)) = incumbent {
        let k = SearchSpace::plan_key(plan);
        if !cache.contains_key(&k) {
            cache.insert(k.clone(), (acc, plan.clone()));
            evals += 1; // the incumbent's evaluation counts against our budget
        }
        let r = tree.space.reward(acc, plan);
        if better((r, acc, k.as_str()), &best) {
            best = Some((r, acc, k, plan.clone()));
        }
    }

    let cap = cfg.playout_cap();
    'outer: while evals < cfg.evals && tree.playouts_planned() < cap as u64 {
        // Plan a wave sequentially (virtual loss diversifies the wave),
        // dropping playouts whose fresh eval would exceed the budget.
        let mut wave: Vec<Playout> = Vec::with_capacity(cfg.wave);
        let mut fresh_keys: Vec<String> = Vec::new();
        while wave.len() < cfg.wave && tree.playouts_planned() < cap as u64 {
            let p = tree.plan_playout();
            let is_fresh =
                !cache.contains_key(&p.key) && !fresh_keys.iter().any(|k| k == &p.key);
            if is_fresh {
                if evals + fresh_keys.len() >= cfg.evals {
                    tree.revert(&p);
                    break;
                }
                fresh_keys.push(p.key.clone());
            }
            wave.push(p);
        }
        if wave.is_empty() {
            break 'outer;
        }
        // Evaluate fresh plans; ordered fold keeps determinism.
        if !fresh_keys.is_empty() {
            let plans: Vec<ExecutionPlan> = fresh_keys
                .iter()
                .map(|k| {
                    wave.iter().find(|p| &p.key == k).expect("fresh key from wave").plan.clone()
                })
                .collect();
            let accs: Vec<f64> = match pool {
                Some(pool) if pool.threads() > 1 => {
                    let per_job = (ctx.gemm_threads / pool.threads()).max(1);
                    let jobs: Vec<_> = plans
                        .into_iter()
                        .map(|plan| {
                            let ctx = Arc::clone(ctx);
                            move || ctx.eval_plan_threads(plan, per_job)
                        })
                        .collect();
                    pool.run_ordered(jobs).into_iter().collect::<Result<Vec<f64>>>()?
                }
                _ => plans
                    .into_iter()
                    .map(|plan| ctx.eval_plan(plan))
                    .collect::<Result<Vec<f64>>>()?,
            };
            for (k, acc) in fresh_keys.iter().zip(accs) {
                let plan = wave.iter().find(|p| &p.key == k).expect("fresh key").plan.clone();
                cache.insert(k.clone(), (acc, plan));
                evals += 1;
            }
        }
        // Commit in playout-index order (wave is already in that order).
        for p in &wave {
            let (acc, _) = cache.get(&p.key).expect("every wave key is cached").clone();
            if !fresh_keys.iter().any(|k| k == &p.key) {
                cache_hits += 1;
            }
            let r = tree.space.reward(acc, &p.plan);
            tree.commit(p, r);
            if better((r, acc, p.key.as_str()), &best) {
                best = Some((r, acc, p.key.clone(), p.plan.clone()));
            }
        }
    }

    // QAT-in-the-loop: re-score the top-N distinct plans with a short
    // retrain; keeps whichever score is better.
    let mut retrained = 0usize;
    if let Some(rc) = retrain {
        if rc.leaves > 0 && rc.epochs > 0 && !rc.train.is_tokens {
            let mut ranked: Vec<(f64, f64, String, ExecutionPlan)> = cache
                .iter()
                .map(|(k, (acc, plan))| {
                    (tree.space.reward(*acc, plan), *acc, k.clone(), plan.clone())
                })
                .collect();
            ranked.sort_by(|a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.2.cmp(&b.2))
            });
            for (_, _, key, plan) in ranked.into_iter().take(rc.leaves) {
                let tc = crate::trainer::TrainConfig {
                    epochs: rc.epochs,
                    lr: rc.lr,
                    momentum: 0.9,
                    batch: ctx.bs,
                    seed: rc.seed,
                    threads: ctx.gemm_threads,
                    max_batches: None,
                    log_every: 0,
                    approx_backward: None,
                };
                let fit = crate::trainer::fit(
                    &ctx.model,
                    ctx.params.clone(),
                    &plan,
                    &ctx.scales,
                    &ctx.luts,
                    rc.train,
                    &tc,
                )
                .context("mcts: leaf retrain failed")?;
                let acc =
                    ctx.eval_plan_params(plan.clone(), fit.params, ctx.gemm_threads)?;
                retrained += 1;
                let r = tree.space.reward(acc, &plan);
                if better((r, acc, key.as_str()), &best) {
                    best = Some((r, acc, key.clone(), plan.clone()));
                }
            }
        }
    }

    let (reward, accuracy, _, plan) = best.expect("reference always seeds best");
    let cost = plan_cost_macs(&tree.space.macs, &plan);
    let savings = tree.space.savings(&plan);
    let feasible = (tree.space.base_acc - accuracy) <= tree.space.budget;
    Ok(SearchOutcome {
        plan,
        accuracy,
        cost,
        savings,
        reward,
        evals,
        cache_hits,
        playouts: tree.playouts_planned(),
        retrained,
        feasible,
    })
}
