//! Minimal HTTP/1.1 client + load generator for the serving front-end.
//!
//! Two layers: [`http_call`] is a one-shot request/response helper (used
//! for `/v1/plan`, `/v1/stats`, `/v1/healthz` control calls and tests);
//! [`run_load`] is the `adapt client` load generator — N keep-alive
//! connections multiplexed over a *bounded* worker pool (at most
//! [`MAX_WORKERS`] OS threads), pushing deterministic inference requests
//! and checking id echo, so the whole submit → measure → swap plan →
//! measure bench loop runs over the wire. Each worker drives its
//! connections in rounds (write one request per connection, then read
//! every response), keeping one request outstanding per connection —
//! `--concurrency 4096` holds 4096 open sockets from a few dozen
//! threads, which is what the readiness-loop server's connection-scaling
//! bench needs from CI-class hardware. Request payloads and ids are
//! keyed by *connection index*, not worker, so a given [`LoadConfig`]
//! always produces the same traffic no matter the pool size.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::api::InferResponse;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One HTTP request over a fresh connection; returns (status, body).
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    write_request(&mut stream, addr, method, path, body, false)?;
    read_response(&mut stream)
}

/// Write one request on an existing connection.
fn write_request(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    keep_alive: bool,
) -> Result<()> {
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Read one response; returns (status, body). Requires Content-Length
/// framing (which the server always emits).
fn read_response(stream: &mut TcpStream) -> Result<(u16, String)> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            bail!("connection closed mid-response");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("non-UTF-8 response head")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line {status_line:?}"))?;
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok((status, String::from_utf8(body).context("non-UTF-8 body")?))
}

/// Load-generator configuration (`adapt client`).
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Total requests across all client threads.
    pub requests: usize,
    /// Concurrent client connections.
    pub concurrency: usize,
    /// Flat input length (discover via `/v1/healthz` when in doubt).
    pub input_len: usize,
    /// Ask the server for top-k alongside each output.
    pub top_k: Option<usize>,
    /// Per-request queueing deadline forwarded to the server.
    pub deadline_ms: Option<u64>,
    /// Seed for the deterministic request payloads.
    pub seed: u64,
}

/// Outcome of one [`run_load`] phase.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub ok: usize,
    pub errors: usize,
    pub wall: Duration,
    /// Responses per plan generation (hot-swap visibility).
    pub by_generation: BTreeMap<u64, usize>,
    /// Responses per plan version (canary-split visibility).
    pub by_version: BTreeMap<u64, usize>,
    /// Client-observed end-to-end latency, sorted ascending (µs).
    pub latencies_us: Vec<u64>,
}

impl LoadReport {
    pub fn requests_per_sec(&self) -> f64 {
        (self.ok + self.errors) as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Client-side latency percentile in µs (0 when empty).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let rank = ((p * self.latencies_us.len() as f64).ceil() as usize)
            .clamp(1, self.latencies_us.len());
        self.latencies_us[rank - 1]
    }

    /// The canonical JSON shape for one load phase — shared by
    /// `adapt client --bench-out` and `benches/serve_http.rs` so the
    /// tracked `BENCH_*.json` phase records never drift apart.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("ok".to_string(), Json::Num(self.ok as f64));
        m.insert("errors".to_string(), Json::Num(self.errors as f64));
        m.insert("wall_s".to_string(), Json::Num(self.wall.as_secs_f64()));
        m.insert("req_per_s".to_string(), Json::Num(self.requests_per_sec()));
        m.insert("p50_us".to_string(), Json::Num(self.percentile_us(0.50) as f64));
        m.insert("p95_us".to_string(), Json::Num(self.percentile_us(0.95) as f64));
        m.insert("p99_us".to_string(), Json::Num(self.percentile_us(0.99) as f64));
        m.insert(
            "by_generation".to_string(),
            Json::Obj(
                self.by_generation
                    .iter()
                    .map(|(g, n)| (g.to_string(), Json::Num(*n as f64)))
                    .collect(),
            ),
        );
        m.insert(
            "by_version".to_string(),
            Json::Obj(
                self.by_version
                    .iter()
                    .map(|(v, n)| (v.to_string(), Json::Num(*n as f64)))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Scrape `GET /metrics` into a flat `name{labels} -> value` map.
/// Histogram series keep their full `_bucket{...,le="..."}` keys, so
/// two snapshots are directly diffable series-by-series.
pub fn scrape_metrics(addr: &str) -> Result<BTreeMap<String, f64>> {
    let (status, body) = http_call(addr, "GET", "/metrics", None)?;
    if status != 200 {
        bail!("/metrics returned {status}: {body}");
    }
    let mut out = BTreeMap::new();
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // `name{labels} value` or `name value`: split on the *last*
        // space so spaces inside label values can't skew the parse.
        let Some((key, val)) = line.rsplit_once(' ') else {
            continue;
        };
        if let Ok(v) = val.trim().parse::<f64>() {
            out.insert(key.trim().to_string(), v);
        }
    }
    Ok(out)
}

/// Per-series `after - before` between two [`scrape_metrics`] snapshots
/// (a series absent from `before` counts from zero; zero deltas are
/// dropped). This is the object `adapt client --bench-out` embeds per
/// phase so BENCH records carry server-side counters — padding ratio,
/// refusals, batch counts — alongside the client-observed timings.
pub fn metrics_delta(before: &BTreeMap<String, f64>, after: &BTreeMap<String, f64>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in after {
        let d = v - before.get(k).copied().unwrap_or(0.0);
        if d != 0.0 {
            m.insert(k.clone(), Json::Num(d));
        }
    }
    Json::Obj(m)
}

/// Discover the served model's flat input length from `/v1/healthz`.
pub fn discover_input_len(addr: &str) -> Result<usize> {
    let (status, body) = http_call(addr, "GET", "/v1/healthz", None)?;
    if status != 200 {
        bail!("healthz returned {status}: {body}");
    }
    Json::parse(&body)?.get("input_len")?.usize()
}

/// Discover a registry model's flat input length from `GET /v2/models`.
pub fn discover_model_input_len(addr: &str, model: &str) -> Result<usize> {
    let (status, body) = http_call(addr, "GET", "/v2/models", None)?;
    if status != 200 {
        bail!("/v2/models returned {status}: {body}");
    }
    for entry in Json::parse(&body)?.get("models")?.arr()? {
        if entry.get("name")?.str()? == model {
            return entry.get("input_len")?.usize();
        }
    }
    bail!("model {model:?} not in the registry listing");
}

/// Poll a registry model's stats until the shadow collector has folded
/// in (or errored) `expect` mirrored comparisons for `version`, then
/// return the candidate's report object (the comparison runs
/// asynchronously on the server). Errors if `timeout` passes first.
pub fn wait_shadow_report(
    addr: &str,
    model: &str,
    version: u64,
    expect: usize,
    timeout: Duration,
) -> Result<Json> {
    let path = format!("/v2/models/{model}/stats");
    let deadline = Instant::now() + timeout;
    loop {
        let (status, body) = http_call(addr, "GET", &path, None)?;
        if status != 200 {
            bail!("{path} failed ({status}): {body}");
        }
        let j = Json::parse(&body)?;
        if let Some(report) = j.get("shadow_reports")?.opt(&version.to_string()) {
            let done = report.get("mirrored")?.i64()? + report.get("errors")?.i64()?;
            if done >= expect as i64 {
                return Ok(report.clone());
            }
        }
        if Instant::now() >= deadline {
            bail!("shadow collector did not catch up within {timeout:?}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The infer route for a target model (`None` = the `/v1` default).
pub fn infer_path(model: Option<&str>) -> String {
    match model {
        Some(m) => format!("/v2/models/{m}/infer"),
        None => "/v1/infer".to_string(),
    }
}

/// Cap on OS threads the load generator spawns; connections beyond it
/// are multiplexed round-robin across the pool.
pub const MAX_WORKERS: usize = 32;

/// Drive `cfg.requests` inference calls over `cfg.concurrency` keep-alive
/// connections against `POST /v1/infer`. Inputs are deterministic per
/// (connection, sequence) so a given config always sends the same
/// traffic; ids are checked for echo (a swapped response fails loudly).
pub fn run_load(cfg: &LoadConfig) -> Result<LoadReport> {
    run_load_on(cfg, &infer_path(None))
}

/// [`run_load`] against an arbitrary infer route (see [`infer_path`] for
/// the `/v2/models/{name}/infer` form).
pub fn run_load_on(cfg: &LoadConfig, path: &str) -> Result<LoadReport> {
    let conns = cfg.concurrency.max(1);
    let per_conn = cfg.requests.div_ceil(conns);
    let workers = conns.min(MAX_WORKERS);
    // Thousands of client sockets need fd headroom just like the server.
    super::net::sys::ensure_fd_limit(conns * 2 + 64);
    let t0 = Instant::now();
    let results: Vec<Result<LoadReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let cfg = cfg.clone();
                s.spawn(move || client_worker(&cfg, path, w, workers, conns, per_conn))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client worker panicked"))
            .collect()
    });
    let mut report = LoadReport::default();
    for r in results {
        let r = r?;
        report.ok += r.ok;
        report.errors += r.errors;
        for (g, n) in r.by_generation {
            *report.by_generation.entry(g).or_insert(0) += n;
        }
        for (v, n) in r.by_version {
            *report.by_version.entry(v).or_insert(0) += n;
        }
        report.latencies_us.extend(r.latencies_us);
    }
    report.latencies_us.sort_unstable();
    report.wall = t0.elapsed();
    Ok(report)
}

/// One multiplexed connection: its socket, payload stream, and progress.
struct ClientConn {
    stream: TcpStream,
    rng: Rng,
    /// Connection index in `0..concurrency` (keys ids and payloads).
    conn: usize,
    /// Requests sent so far (== the next sequence number).
    sent: usize,
    /// Requests this connection owes in total.
    total: usize,
    /// Id of the one outstanding request, for the echo check.
    inflight_id: u64,
    sent_at: Instant,
}

/// One worker's share of the load: connections `{c : c % workers == w}`,
/// driven in lockstep rounds of write-everything then read-everything —
/// one outstanding request per connection at all times.
fn client_worker(
    cfg: &LoadConfig,
    path: &str,
    w: usize,
    workers: usize,
    conns: usize,
    per_conn: usize,
) -> Result<LoadReport> {
    let mut report = LoadReport::default();
    let mut pool: Vec<ClientConn> = Vec::new();
    for c in (w..conns).step_by(workers.max(1)) {
        let total = per_conn.min(cfg.requests.saturating_sub(c * per_conn));
        if total == 0 {
            continue;
        }
        let stream = TcpStream::connect(&cfg.addr)
            .with_context(|| format!("connecting to {}", cfg.addr))?;
        stream.set_nodelay(true).ok();
        pool.push(ClientConn {
            stream,
            rng: Rng::new(cfg.seed ^ ((c as u64 + 1) * 0x9E37_79B9)),
            conn: c,
            sent: 0,
            total,
            inflight_id: 0,
            sent_at: Instant::now(),
        });
    }
    while !pool.is_empty() {
        for cc in pool.iter_mut() {
            let input: Vec<f32> = (0..cfg.input_len).map(|_| cc.rng.next_gauss()).collect();
            let id = (cc.conn * 1_000_000 + cc.sent) as u64;
            let mut req = super::InferRequest::new(input);
            req.id = Some(id);
            req.top_k = cfg.top_k;
            req.deadline = cfg.deadline_ms.map(Duration::from_millis);
            let body = req.to_json().to_string();
            cc.inflight_id = id;
            cc.sent_at = Instant::now();
            write_request(&mut cc.stream, &cfg.addr, "POST", path, Some(&body), true)?;
            cc.sent += 1;
        }
        for cc in pool.iter_mut() {
            let (status, resp_body) = read_response(&mut cc.stream)?;
            let latency = cc.sent_at.elapsed();
            if status == 200 {
                let resp = InferResponse::from_json(&Json::parse(&resp_body)?)?;
                if resp.id != cc.inflight_id {
                    bail!(
                        "response id {} for request id {}: swapped response",
                        resp.id,
                        cc.inflight_id
                    );
                }
                report.ok += 1;
                *report.by_generation.entry(resp.generation).or_insert(0) += 1;
                *report.by_version.entry(resp.version).or_insert(0) += 1;
                report.latencies_us.push(latency.as_micros() as u64);
            } else {
                report.errors += 1;
            }
        }
        pool.retain(|cc| cc.sent < cc.total);
    }
    Ok(report)
}
