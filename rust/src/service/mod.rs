//! AdaptService: the versioned serving API + network front-end over the
//! engine pool.
//!
//! Three layers, bottom up:
//!
//! * [`api`] — the `/v1` wire types: [`InferRequest`] / [`InferResponse`]
//!   with per-request metadata (id, top-k, deadline) and the structured
//!   [`ServiceError`] enum every layer speaks.
//! * [`AdaptService`] (this module) — the runtime control plane wrapping
//!   [`InferenceEngine`]: typed submit/infer, [`AdaptService::swap_plan`]
//!   (workers adopt a new plan + `Arc`-shared quantized weights at a
//!   batch boundary — no restart), live [`stats`](AdaptService::stats)
//!   without shutdown, and [`health`](AdaptService::health). Body-level
//!   plan swaps live on [`ModelHandle::swap_plan_body`], so every swap on
//!   a registry-managed model is recorded as a [`registry::PlanStore`]
//!   version — there is no store-bypassing text path anymore.
//! * [`registry`] — the multi-model control plane: [`ModelRegistry`]
//!   owns N named models, each a [`ModelHandle`] wrapping its own
//!   engine pool plus a [`registry::PlanStore`] of immutable numbered
//!   plan versions, with canary-fraction routing, shadow mirroring
//!   (live disagreement stats against the active plan) and
//!   activate/rollback lifecycle.
//! * [`net`] — the readiness-loop transport: a fixed pool of event-loop
//!   threads over a dependency-free `Poller` (raw `epoll` syscalls on
//!   Linux, portable `poll(2)` via `ADAPT_NET=poll`), per-connection
//!   state machines with incremental HTTP/1.1 parsing and pipelining,
//!   batched/partial-write-aware output, a timer wheel for idle
//!   deadlines, and a dispatch pool running the blocking engine
//!   submit/wait off the loops.
//! * [`http`] / [`client`] — the HTTP/1.1 route table + response
//!   framing over [`net`], exposing the `/v1` single-model routes
//!   (`POST /v1/infer`, `POST /v1/plan`, `GET /v1/stats`,
//!   `GET /v1/healthz` — a bit-compatible shim over the registry's
//!   default model) and the `/v2/models/...` registry routes (JSON
//!   bodies via [`util::json`](crate::util::json)), plus the matching
//!   minimal client and a worker-pool load generator behind
//!   `adapt client` that multiplexes thousands of keep-alive
//!   connections over a bounded thread count.
//!
//! The old `InferenceEngine::submit`/`infer` surface still works — it is
//! a shim over the same typed path — so in-process consumers (benches,
//! the sweep, tests) did not have to move.
//!
//! ## Observability
//!
//! Every layer reports into [`crate::obs`]: typed submits begin a trace
//! when sampling is on (`ADAPT_TRACE_SAMPLE`; the batching loop records
//! queue/batch/execute spans, tail-retained under `GET /v1/trace/{id}`
//! and `GET /v2/models/{m}/traces`), the engine's counters/histograms
//! plus the registry's rollout state and the net layer's
//! [`crate::obs::NetStats`] render as Prometheus text under
//! `GET /metrics`, and `ADAPT_PROFILE=1` attaches the pool's per-layer
//! kernel profiler (also driven standalone by `adapt profile`).

pub mod api;
pub mod client;
pub mod http;
pub mod net;
pub mod registry;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{BackendSpec, EngineConfig, InferenceEngine, PoolStats};
use crate::graph::ExecutionPlan;
use crate::util::json::Json;

pub use api::{top_k_of, InferRequest, InferResponse, ServiceError};
pub use registry::{ModelHandle, ModelRegistry};

/// The serving control plane: an [`InferenceEngine`] pool plus the typed
/// request/response surface, plan hot-swap, live stats and health.
pub struct AdaptService {
    engine: InferenceEngine,
    model_name: String,
    started: Instant,
    next_id: AtomicU64,
}

/// In-flight typed request: resolves to the full [`InferResponse`].
pub struct InferHandle {
    id: u64,
    top_k: Option<usize>,
    rx: crate::coordinator::engine::RawReceiver,
}

impl InferHandle {
    /// The id the response will carry (client-chosen or auto-assigned).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the engine answers.
    pub fn wait(self) -> Result<InferResponse, ServiceError> {
        let raw = self
            .rx
            .recv()
            .map_err(|_| ServiceError::Internal("engine dropped request".into()))??;
        let top_k = self.top_k.map(|k| top_k_of(&raw.output, k));
        Ok(InferResponse {
            id: self.id,
            output: raw.output,
            top_k,
            queue_wait: raw.queue_wait,
            compute: raw.compute,
            worker: raw.worker,
            generation: raw.generation,
            version: raw.version,
        })
    }
}

/// Live service statistics (a [`PoolStats`] snapshot plus service-level
/// context) — available any time, not only at shutdown.
pub struct ServiceStats {
    pub model: String,
    pub uptime: std::time::Duration,
    pub generation: u64,
    /// Plan version untagged requests route to (0 on PJRT backends).
    pub active_version: u64,
    pub queue_len: usize,
    pub workers: usize,
    pub pool: PoolStats,
}

impl ServiceStats {
    /// The `GET /v1/stats` body.
    pub fn to_json(&self) -> Json {
        let engine_stats = |s: &crate::coordinator::engine::EngineStats| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("requests".into(), Json::Num(s.requests as f64));
            m.insert("batches".into(), Json::Num(s.batches as f64));
            m.insert("padded_slots".into(), Json::Num(s.padded_slots as f64));
            m.insert(
                "queue_wait_us".into(),
                Json::Num(s.queue_wait.as_micros() as f64),
            );
            m.insert("busy_us".into(), Json::Num(s.busy.as_micros() as f64));
            for (label, hist) in [("queue_wait", &s.queue_hist), ("compute", &s.compute_hist)] {
                for (p, tag) in [(0.50, "p50"), (0.95, "p95"), (0.99, "p99")] {
                    m.insert(
                        format!("{label}_{tag}_us"),
                        Json::Num(hist.percentile_us(p) as f64),
                    );
                }
            }
            Json::Obj(m)
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("uptime_s".into(), Json::Num(self.uptime.as_secs_f64()));
        m.insert("generation".into(), Json::Num(self.generation as f64));
        m.insert(
            "active_version".into(),
            Json::Num(self.active_version as f64),
        );
        m.insert("queue_len".into(), Json::Num(self.queue_len as f64));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("total".into(), engine_stats(&self.pool.total));
        m.insert(
            "per_worker".into(),
            Json::Arr(self.pool.per_worker.iter().map(engine_stats).collect()),
        );
        Json::Obj(m)
    }
}

/// Liveness/readiness summary (the `GET /v1/healthz` body).
pub struct Health {
    /// Every configured worker thread is still serving.
    pub ok: bool,
    pub model: String,
    pub input_len: usize,
    pub out_dim: usize,
    pub workers: usize,
    /// Worker threads still running; `< workers` means degraded.
    pub workers_alive: usize,
    pub generation: u64,
    pub queue_len: usize,
    pub uptime: std::time::Duration,
}

impl Health {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "status".into(),
            Json::Str(if self.ok { "ok" } else { "degraded" }.into()),
        );
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("input_len".into(), Json::Num(self.input_len as f64));
        m.insert("out_dim".into(), Json::Num(self.out_dim as f64));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("workers_alive".into(), Json::Num(self.workers_alive as f64));
        m.insert("generation".into(), Json::Num(self.generation as f64));
        m.insert("queue_len".into(), Json::Num(self.queue_len as f64));
        m.insert("uptime_s".into(), Json::Num(self.uptime.as_secs_f64()));
        Json::Obj(m)
    }
}

impl AdaptService {
    /// Start the engine pool and wrap it in the serving control plane.
    pub fn start(cfg: EngineConfig) -> Result<AdaptService> {
        let model_name = match &cfg.backend {
            BackendSpec::Pjrt { model, .. } => model.clone(),
            BackendSpec::Emulator(spec) => spec.model.name.clone(),
        };
        let engine = InferenceEngine::start(cfg)?;
        Ok(AdaptService {
            engine,
            model_name,
            started: Instant::now(),
            next_id: AtomicU64::new(1),
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    pub fn input_len(&self) -> usize {
        self.engine.input_len()
    }

    pub fn out_dim(&self) -> usize {
        self.engine.out_dim()
    }

    /// The wrapped engine (for shim-path consumers and tests).
    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// Typed submit: validates the input length up front (fail fast,
    /// before the request occupies a queue slot), assigns an id when the
    /// client didn't, and returns a handle resolving to the response.
    pub fn submit(&self, req: InferRequest) -> Result<InferHandle, ServiceError> {
        self.submit_to(req, None)
    }

    /// Typed submit pinned to an installed plan version (`None` routes
    /// to the active one) — what the registry's canary and shadow
    /// rollouts ride on.
    pub fn submit_to(
        &self,
        req: InferRequest,
        version: Option<u64>,
    ) -> Result<InferHandle, ServiceError> {
        let id = req
            .id
            .unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let trace = self.engine.tracer().begin(id);
        let expected = self.engine.input_len();
        if req.input.len() != expected {
            let err = ServiceError::WrongInputLength {
                got: req.input.len(),
                expected,
            };
            if let Some(tr) = &trace {
                self.engine
                    .tracer()
                    .finish(tr, crate::obs::TraceOutcome::Error(err.code()));
            }
            return Err(err);
        }
        let rx = self
            .engine
            .submit_raw_traced(req.input, req.deadline, version, trace)?;
        Ok(InferHandle {
            id,
            top_k: req.top_k,
            rx,
        })
    }

    /// Non-blocking [`submit_to`](Self::submit_to): `Ok(None)` when the
    /// engine queue is full instead of backpressure — best-effort
    /// traffic (shadow mirrors) must never stall a serving thread.
    pub fn try_submit_to(
        &self,
        req: InferRequest,
        version: Option<u64>,
    ) -> Result<Option<InferHandle>, ServiceError> {
        let expected = self.engine.input_len();
        if req.input.len() != expected {
            return Err(ServiceError::WrongInputLength {
                got: req.input.len(),
                expected,
            });
        }
        let id = req
            .id
            .unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        let trace = self.engine.tracer().begin(id);
        let rx = self
            .engine
            .try_submit_raw_traced(req.input, req.deadline, version, trace)?;
        Ok(rx.map(|rx| InferHandle {
            id,
            top_k: req.top_k,
            rx,
        }))
    }

    /// Blocking convenience wrapper around [`submit`](Self::submit).
    pub fn infer(&self, req: InferRequest) -> Result<InferResponse, ServiceError> {
        self.submit(req)?.wait()
    }

    /// Hot-swap the execution plan on the live pool. Returns the new
    /// generation number (see [`InferenceEngine::swap_plan`]).
    pub fn swap_plan(&self, plan: ExecutionPlan) -> Result<u64, ServiceError> {
        self.engine.swap_plan(plan)
    }

    /// Live stats snapshot — mid-run, no shutdown required.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            model: self.model_name.clone(),
            uptime: self.started.elapsed(),
            generation: self.engine.generation(),
            active_version: self.engine.active_version(),
            queue_len: self.engine.queue_len(),
            workers: self.engine.workers(),
            pool: self.engine.stats_snapshot(),
        }
    }

    /// Liveness summary. `ok` is derived from worker-thread liveness: a
    /// worker only exits when the queue closes or it panics, so fewer
    /// alive than configured on a serving pool means degraded.
    pub fn health(&self) -> Health {
        let workers = self.engine.workers();
        let workers_alive = self.engine.alive_workers();
        Health {
            ok: workers_alive == workers && workers > 0,
            model: self.model_name.clone(),
            input_len: self.engine.input_len(),
            out_dim: self.engine.out_dim(),
            workers,
            workers_alive,
            generation: self.engine.generation(),
            queue_len: self.engine.queue_len(),
            uptime: self.started.elapsed(),
        }
    }

    /// Stop the pool: drain, join, final stats.
    pub fn shutdown(self) -> Result<PoolStats> {
        self.engine.shutdown()
    }
}
