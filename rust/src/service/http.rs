//! Dependency-free HTTP/1.1 front-end for the model registry.
//!
//! The build is offline, so the framing is hand-rolled over
//! `std::net::TcpListener` (the same spirit as the vendored stand-ins):
//! request-line + headers, `Content-Length` bodies, `keep-alive`
//! connections, JSON in / JSON out.
//!
//! The `/v1` routes are a wire-compatible shim over the registry's
//! **default model**: every pre-registry field and status code is
//! unchanged; responses additionally carry the (additive) `version`
//! field, and non-finite inference inputs are now rejected with 400
//! instead of computing inf/NaN. The `/v2` routes expose the whole
//! [`ModelRegistry`] — models, immutable plan versions, canary rollout
//! and shadow evaluation:
//!
//! ```text
//! POST /v1/infer                      InferRequest -> InferResponse | error
//! POST /v1/plan                       plan JSON or {"spec": "..."} -> {"generation": n}
//! GET  /v1/stats                      live pool stats (totals, per-worker, p50/p95/p99)
//! GET  /v1/healthz                    liveness summary
//!
//! GET  /v2/models                     registry listing (default + per-model summary)
//! POST /v2/models/{m}/infer           as /v1/infer, on model {m} (canary/shadow aware)
//! GET  /v2/models/{m}/stats           pool stats + rollout state + shadow reports
//! GET  /v2/models/{m}/plans           enumerate plan versions (metadata)
//! POST /v2/models/{m}/plans           create an immutable version -> {"version": v, ...}
//! POST /v2/models/{m}/plans/{v}/activate   route traffic to v -> {"version", "generation"}
//! POST /v2/models/{m}/plans/{v}/canary     {"fraction": 0.25} -> route that share to v
//! POST /v2/models/{m}/plans/{v}/shadow     mirror traffic to v, compare online
//! POST /v2/models/{m}/rollback        revert to the previous active version
//! ```
//!
//! Every error is a [`ServiceError`] rendered as
//! `{"error": code, "message": ...}` with that variant's status code.
//! Bodies above [`ServeOptions::max_body`] are refused with 413 before
//! being read; malformed framing gets 400; unknown routes 404; known
//! routes with the wrong method 405.
//!
//! One thread per connection, hardened against stalls: each read loop
//! checks a per-request idle deadline ([`ServeOptions::idle_timeout`]) so
//! a silent keep-alive peer cannot pin its thread forever, and the accept
//! loop refuses connections beyond [`ServeOptions::max_conns`] with a 503
//! `overloaded` body instead of spawning an unbounded thread set. Serving
//! threads only share the `Arc<ModelRegistry>`; all request-level
//! concurrency control (bounded queue, backpressure) stays in the engine
//! pools underneath.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::api::ServiceError;
use super::registry::{ModelHandle, ModelRegistry};
use super::AdaptService;
use crate::util::json::Json;

/// Server tunables.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Max request-body size in bytes; larger gets 413 without a read.
    pub max_body: usize,
    /// Per-read socket timeout: the granularity at which connection
    /// threads notice `stop()` and the idle deadline.
    pub read_timeout: Duration,
    /// Max time a connection may sit without completing a request before
    /// it is closed (counted from the start of each request read, so an
    /// *active* keep-alive connection lives indefinitely).
    pub idle_timeout: Duration,
    /// Max concurrently served connections; beyond it, new connections
    /// get an immediate 503 `overloaded` and are closed.
    pub max_conns: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_body: 8 << 20,
            read_timeout: Duration::from_millis(100),
            idle_timeout: Duration::from_secs(60),
            max_conns: 1024,
        }
    }
}

/// One parsed request.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Connection-level outcome of trying to read a request.
enum ReadOutcome {
    Request(HttpRequest),
    /// Peer closed, idle deadline hit, or server stopping: drop it.
    Closed,
    /// Framing error worth answering before closing.
    Bad(ServiceError),
}

/// Decrements the live-connection count when a connection thread exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The serving front-end: accept loop + per-connection threads.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// serve `service` as a single-model registry until
    /// [`stop`](Self::stop).
    pub fn start(service: Arc<AdaptService>, addr: &str) -> Result<HttpServer> {
        Self::start_with(service, addr, ServeOptions::default())
    }

    /// Single-model variant of [`start_registry`](Self::start_registry):
    /// the service registers under its own model name and becomes the
    /// `/v1` default.
    pub fn start_with(
        service: Arc<AdaptService>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<HttpServer> {
        Self::start_registry(Arc::new(ModelRegistry::single(service)), addr, opts)
    }

    /// Bind `addr` and serve the whole registry (`/v1` shim over its
    /// default model + the `/v2/models/...` routes).
    pub fn start_registry(
        registry: Arc<ModelRegistry>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let live = Arc::new(AtomicUsize::new(0));
            std::thread::Builder::new()
                .name("adapt-http-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let Ok(mut stream) = stream else { continue };
                        // Connection cap: refuse with one short blocking
                        // write instead of spawning a thread.
                        let n = live.fetch_add(1, Ordering::AcqRel) + 1;
                        if n > opts.max_conns {
                            live.fetch_sub(1, Ordering::AcqRel);
                            let e = ServiceError::Overloaded {
                                conns: opts.max_conns,
                            };
                            let _ = stream
                                .set_write_timeout(Some(Duration::from_millis(200)));
                            let _ = write_response(
                                &mut stream,
                                e.http_status(),
                                &e.to_json(),
                                false,
                            );
                            continue;
                        }
                        let guard = ConnGuard(Arc::clone(&live));
                        let registry = Arc::clone(&registry);
                        let stop = Arc::clone(&stop);
                        let handle = std::thread::Builder::new()
                            .name("adapt-http-conn".into())
                            .spawn(move || {
                                let _guard = guard;
                                serve_conn(stream, &registry, &stop, opts);
                            });
                        if let Ok(h) = handle {
                            let mut guard = conns.lock().expect("conn list poisoned");
                            // Reap finished threads so a long-lived server
                            // doesn't accumulate handles.
                            guard.retain(|j: &std::thread::JoinHandle<()>| !j.is_finished());
                            guard.push(h);
                        }
                    }
                })
                .context("spawning accept loop")?
        };
        Ok(HttpServer {
            addr,
            stop,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake the accept loop, and join every connection
    /// thread (each notices the flag within one read timeout).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().expect("conn list poisoned");
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::Release);
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
    }
}

/// Serve one connection: a keep-alive loop of read → route → respond.
fn serve_conn(
    mut stream: TcpStream,
    registry: &ModelRegistry,
    stop: &AtomicBool,
    opts: ServeOptions,
) {
    let _ = stream.set_read_timeout(Some(opts.read_timeout));
    let _ = stream.set_nodelay(true);
    // Bytes read past the previous request's body (HTTP/1.1 pipelining):
    // they are the start of the next request, not garbage.
    let mut carry: Vec<u8> = Vec::new();
    loop {
        // Idle deadline restarts per request: a connection stalls out
        // only by *not completing* a request within the window.
        let idle_deadline = Instant::now() + opts.idle_timeout;
        match read_request(&mut stream, &mut carry, stop, opts.max_body, idle_deadline) {
            ReadOutcome::Closed => return,
            ReadOutcome::Bad(e) => {
                // Drain what the peer already sent (bounded) before the
                // error response + close: closing with unread data makes
                // some TCP stacks RST and discard the response in flight.
                drain(&mut stream, 1 << 20);
                let _ = write_response(&mut stream, e.http_status(), &e.to_json(), false);
                return;
            }
            ReadOutcome::Request(req) => {
                let (status, body) = route(registry, &req);
                if write_response(&mut stream, status, &body, req.keep_alive).is_err()
                    || !req.keep_alive
                {
                    return;
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            return;
        }
    }
}

/// Parse a request body as UTF-8 JSON, mapping failures onto the typed
/// 400s every route shares.
fn parse_body(body: &[u8]) -> std::result::Result<Json, ServiceError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServiceError::BadRequest("body is not UTF-8".into()))?;
    Json::parse(text).map_err(|e| ServiceError::BadRequest(format!("{e:#}")))
}

/// `POST .../infer` on one model (shared by `/v1` and `/v2`).
fn infer_route(handle: &ModelHandle, body: &[u8]) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let parsed = match parse_body(body) {
        Ok(j) => j,
        Err(e) => return err(e),
    };
    let infer_req = match super::InferRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return err(e),
    };
    match handle.infer(infer_req) {
        Ok(resp) => (200, resp.to_json()),
        Err(e) => err(e),
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Dispatch one request. Always returns a JSON body.
fn route(registry: &ModelRegistry, req: &HttpRequest) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let method = req.method.as_str();
    let path = req.path.as_str();

    // ----- /v1: bit-compatible shim over the registry's default model ----
    match (method, path) {
        ("POST", "/v1/infer") => return infer_route(registry.default_model(), &req.body),
        ("POST", "/v1/plan") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(s) => s,
                Err(_) => return err(ServiceError::BadRequest("body is not UTF-8".into())),
            };
            return match registry.default_model().create_and_activate(body) {
                Ok(generation) => (200, obj(vec![("generation", Json::Num(generation as f64))])),
                Err(e) => err(e),
            };
        }
        ("GET", "/v1/stats") => return (200, registry.default_model().service().stats().to_json()),
        ("GET", "/v1/healthz") => {
            return (200, registry.default_model().service().health().to_json())
        }
        (_, "/v1/infer") | (_, "/v1/plan") | (_, "/v1/stats") | (_, "/v1/healthz") => {
            return err(ServiceError::MethodNotAllowed(format!("{method} {path}")))
        }
        _ => {}
    }

    // ----- /v2: the registry surface --------------------------------------
    let Some(rest) = path.strip_prefix("/v2/") else {
        return err(ServiceError::NotFound(path.to_string()));
    };
    let segs: Vec<&str> = rest.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["models"] => match method {
            "GET" => (200, registry.list_json()),
            _ => err(ServiceError::MethodNotAllowed(format!("{method} {path}"))),
        },
        ["models", name, tail @ ..] => {
            let handle = match registry.get(name) {
                Ok(h) => h,
                Err(e) => return err(e),
            };
            route_model(handle, method, path, tail, &req.body)
        }
        _ => err(ServiceError::NotFound(path.to_string())),
    }
}

/// Routes under `/v2/models/{name}/...`.
fn route_model(
    handle: &ModelHandle,
    method: &str,
    path: &str,
    tail: &[&str],
    body: &[u8],
) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let wrong_method = || {
        (
            405,
            ServiceError::MethodNotAllowed(format!("{method} {path}")).to_json(),
        )
    };
    match tail {
        ["infer"] => match method {
            "POST" => infer_route(handle, body),
            _ => wrong_method(),
        },
        ["stats"] => match method {
            "GET" => (200, handle.stats_json()),
            _ => wrong_method(),
        },
        ["plans"] => match method {
            "GET" => (
                200,
                Json::Arr(
                    handle
                        .list_versions()
                        .iter()
                        .map(|p| p.meta_json())
                        .collect(),
                ),
            ),
            "POST" => {
                let text = match std::str::from_utf8(body) {
                    Ok(s) => s,
                    Err(_) => {
                        return err(ServiceError::BadRequest("body is not UTF-8".into()))
                    }
                };
                match handle.create_version(text) {
                    Ok(pv) => (200, pv.meta_json()),
                    Err(e) => err(e),
                }
            }
            _ => wrong_method(),
        },
        ["rollback"] => match method {
            "POST" => match handle.rollback() {
                Ok((version, generation)) => (
                    200,
                    obj(vec![
                        ("version", Json::Num(version as f64)),
                        ("generation", Json::Num(generation as f64)),
                    ]),
                ),
                Err(e) => err(e),
            },
            _ => wrong_method(),
        },
        ["plans", v, action] => {
            let Ok(version) = v.parse::<u64>() else {
                return err(ServiceError::BadRequest(format!(
                    "plan version must be an integer, got {v:?}"
                )));
            };
            match *action {
                // Unknown actions are 404 regardless of method (the
                // resource does not exist); known ones take POST only.
                "activate" | "canary" | "shadow" if method != "POST" => wrong_method(),
                "activate" => match handle.activate(version) {
                    Ok(generation) => (
                        200,
                        obj(vec![
                            ("version", Json::Num(version as f64)),
                            ("generation", Json::Num(generation as f64)),
                        ]),
                    ),
                    Err(e) => err(e),
                },
                "canary" => {
                    let fraction = match parse_body(body).and_then(|j| {
                        j.get("fraction")
                            .and_then(|f| f.f64())
                            .map_err(|e| ServiceError::BadRequest(format!("fraction: {e}")))
                    }) {
                        Ok(f) => f,
                        Err(e) => return err(e),
                    };
                    match handle.start_canary(version, fraction) {
                        Ok(()) => (
                            200,
                            obj(vec![
                                ("version", Json::Num(version as f64)),
                                ("fraction", Json::Num(fraction)),
                            ]),
                        ),
                        Err(e) => err(e),
                    }
                }
                "shadow" => match handle.start_shadow(version) {
                    Ok(()) => (
                        200,
                        obj(vec![
                            ("version", Json::Num(version as f64)),
                            ("shadow", Json::Bool(true)),
                        ]),
                    ),
                    Err(e) => err(e),
                },
                _ => err(ServiceError::NotFound(path.to_string())),
            }
        }
        _ => err(ServiceError::NotFound(path.to_string())),
    }
}

/// Read one request (request line + headers + Content-Length body).
/// `carry` holds bytes already read past the previous request's body
/// (pipelining); on return it holds whatever follows *this* request.
/// `idle_deadline` bounds how long the peer may stall before the
/// connection is dropped.
fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    stop: &AtomicBool,
    max_body: usize,
    idle_deadline: Instant,
) -> ReadOutcome {
    const MAX_HEAD: usize = 16 << 10;
    let mut buf: Vec<u8> = std::mem::take(carry);
    let mut chunk = [0u8; 4096];
    // --- head: read until \r\n\r\n -------------------------------------
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return ReadOutcome::Bad(ServiceError::BadRequest("header block too large".into()));
        }
        // The deadline binds whether the peer is silent *or* trickling
        // bytes (slow-loris): a request that hasn't completed by it is
        // dropped, not a pinned thread.
        if stop.load(Ordering::Acquire) || Instant::now() >= idle_deadline {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    };
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(s) => s.to_string(),
        Err(_) => return ReadOutcome::Bad(ServiceError::BadRequest("non-UTF-8 header".into())),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => {
            return ReadOutcome::Bad(ServiceError::BadRequest(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return ReadOutcome::Bad(ServiceError::BadRequest(format!(
            "unsupported version {version:?}"
        )));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    for line in lines {
        let Some((k, v)) = line.split_once(':') else {
            continue;
        };
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim());
        if k == "content-length" {
            content_length = match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    return ReadOutcome::Bad(ServiceError::BadRequest(format!(
                        "bad content-length {v:?}"
                    )))
                }
            };
        } else if k == "connection" {
            keep_alive = !v.eq_ignore_ascii_case("close");
        }
    }
    if content_length > max_body {
        return ReadOutcome::Bad(ServiceError::BodyTooLarge {
            got: content_length,
            max: max_body,
        });
    }
    // --- body: exactly content_length bytes past the head ----------------
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        if stop.load(Ordering::Acquire) || Instant::now() >= idle_deadline {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
    // Anything past this request's body is the next pipelined request.
    if body.len() > content_length {
        *carry = body.split_off(content_length);
    }
    ReadOutcome::Request(HttpRequest {
        method,
        path,
        body,
        keep_alive,
    })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read and discard up to `cap` already-sent bytes (stops at the first
/// read timeout — the peer has gone quiet — or EOF).
fn drain(stream: &mut TcpStream, cap: usize) {
    let mut chunk = [0u8; 4096];
    let mut total = 0usize;
    while total < cap {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => total += n,
            Err(_) => break,
        }
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Write one JSON response with correct framing.
fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        status_text(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}
