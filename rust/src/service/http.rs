//! Dependency-free HTTP/1.1 front-end for the model registry.
//!
//! The build is offline, so the framing is hand-rolled: request-line +
//! headers, `Content-Length` bodies, `keep-alive` connections, JSON in
//! / JSON out. Since the readiness-loop rewrite the transport lives in
//! [`super::net`]: a small pool of event-loop threads multiplexes every
//! connection over `epoll` (Linux) or `poll(2)` (`ADAPT_NET=poll`),
//! with incremental request parsing, pipelining, batched writes and a
//! timer wheel for idle deadlines — this module keeps the route table,
//! the response framing, and the [`HttpServer`] facade.
//!
//! The `/v1` routes are a wire-compatible shim over the registry's
//! **default model**: every pre-registry field and status code is
//! unchanged; responses additionally carry the (additive) `version`
//! field, and non-finite inference inputs are now rejected with 400
//! instead of computing inf/NaN. The `/v2` routes expose the whole
//! [`ModelRegistry`] — models, immutable plan versions, canary rollout
//! and shadow evaluation:
//!
//! ```text
//! POST /v1/infer                      InferRequest -> InferResponse | error
//! POST /v1/plan                       plan JSON or {"spec": "..."} -> {"generation": n}
//! GET  /v1/stats                      live pool stats (totals, per-worker, p50/p95/p99)
//! GET  /v1/healthz                    liveness summary
//!
//! GET  /v2/models                     registry listing (default + per-model summary)
//! POST /v2/models/{m}/infer           as /v1/infer, on model {m} (canary/shadow aware)
//! GET  /v2/models/{m}/stats           pool stats + rollout state + shadow reports
//! GET  /v2/models/{m}/plans           enumerate plan versions (metadata)
//! POST /v2/models/{m}/plans           create an immutable version -> {"version": v, ...}
//! POST /v2/models/{m}/plans/{v}/activate   route traffic to v -> {"version", "generation"}
//! POST /v2/models/{m}/plans/{v}/canary     {"fraction": 0.25} -> route that share to v
//! POST /v2/models/{m}/plans/{v}/shadow     mirror traffic to v, compare online
//! POST /v2/models/{m}/rollback        revert to the previous active version
//!
//! GET  /metrics                       Prometheus text exposition (engine, net, rollout)
//! GET  /v1/trace/{id}                 span tree of one sampled request (404 if unsampled)
//! GET  /v2/models/{m}/traces          recently retained traces for model {m}
//! ```
//!
//! `/metrics` is the only non-JSON response
//! (`text/plain; version=0.0.4`); the body is rendered by
//! [`ModelRegistry::metrics_text`] from live engine counters, the
//! net-layer [`crate::obs::NetStats`], and rollout state. The trace
//! routes read the per-engine [`crate::obs::TraceRecorder`] ring;
//! sampling is off by default (`ADAPT_TRACE_SAMPLE=0..=1` to enable),
//! so an unsampled or evicted id is a plain 404.
//!
//! Every error is a [`ServiceError`] rendered as
//! `{"error": code, "message": ...}` with that variant's status code.
//! Bodies above [`ServeOptions::max_body`] are refused with 413 before
//! being read; malformed framing gets 400; unknown routes 404; known
//! routes with the wrong method 405.
//!
//! Hardening semantics are unchanged from the thread-per-connection
//! server: a connection that does not *complete* a request within
//! [`ServeOptions::idle_timeout`] is dropped (trickling header bytes
//! does not extend the window), and connections beyond
//! [`ServeOptions::max_conns`] get an immediate 503 `overloaded`. The
//! blocking engine submit/wait runs on a dispatch thread pool, so all
//! request-level concurrency control (bounded queue, backpressure)
//! stays in the engine pools underneath.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::api::ServiceError;
use super::net::conn::HttpRequest;
use super::net::server::NetServer;
use super::net::Backend;
use super::registry::{ModelHandle, ModelRegistry};
use super::AdaptService;
use crate::util::json::Json;

/// Server tunables.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Max request-body size in bytes; larger gets 413 without a read.
    pub max_body: usize,
    /// Event-loop timer granularity: poll timeout and timer-wheel tick
    /// (bounds how late an idle deadline or stop flag is noticed).
    pub tick: Duration,
    /// Max time a connection may sit without completing a request before
    /// it is closed (counted per request, so an *active* keep-alive
    /// connection lives indefinitely).
    pub idle_timeout: Duration,
    /// Max concurrently served connections; beyond it, new connections
    /// get an immediate 503 `overloaded` and are closed.
    pub max_conns: usize,
    /// Event-loop threads (0 = `ADAPT_THREADS` / available cores).
    pub event_loops: usize,
    /// Dispatch (engine submit/wait) threads
    /// (0 = `2 × ADAPT_THREADS`, at least 8).
    pub dispatch_threads: usize,
    /// Readiness backend override (`None` = `ADAPT_NET` env, else the
    /// platform default: epoll on Linux, poll elsewhere).
    pub net: Option<Backend>,
    /// `SO_SNDBUF` for accepted sockets (tests shrink it to force the
    /// partial-write path); `None` leaves the kernel default.
    pub sndbuf: Option<usize>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_body: 8 << 20,
            tick: Duration::from_millis(10),
            idle_timeout: Duration::from_secs(60),
            max_conns: 1024,
            event_loops: 0,
            dispatch_threads: 0,
            net: None,
            sndbuf: None,
        }
    }
}

/// The serving front-end: a facade over the readiness-loop
/// [`NetServer`] keeping the pre-rewrite construction API.
pub struct HttpServer {
    inner: Option<NetServer>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// serve `service` as a single-model registry until
    /// [`stop`](Self::stop).
    pub fn start(service: Arc<AdaptService>, addr: &str) -> Result<HttpServer> {
        Self::start_with(service, addr, ServeOptions::default())
    }

    /// Single-model variant of [`start_registry`](Self::start_registry):
    /// the service registers under its own model name and becomes the
    /// `/v1` default.
    pub fn start_with(
        service: Arc<AdaptService>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<HttpServer> {
        Self::start_registry(Arc::new(ModelRegistry::single(service)), addr, opts)
    }

    /// Bind `addr` and serve the whole registry (`/v1` shim over its
    /// default model + the `/v2/models/...` routes).
    pub fn start_registry(
        registry: Arc<ModelRegistry>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<HttpServer> {
        Ok(HttpServer {
            inner: Some(NetServer::start(registry, addr, opts)?),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.inner.as_ref().expect("server running").addr()
    }

    /// Which readiness backend the server is running on.
    pub fn backend(&self) -> Backend {
        self.inner.as_ref().expect("server running").backend()
    }

    /// Stop the event loops (dropping open connections) and drain the
    /// dispatch pool.
    pub fn stop(mut self) {
        if let Some(inner) = self.inner.take() {
            inner.stop();
        }
    }
}

/// Parse a request body as UTF-8 JSON, mapping failures onto the typed
/// 400s every route shares.
fn parse_body(body: &[u8]) -> std::result::Result<Json, ServiceError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServiceError::BadRequest("body is not UTF-8".into()))?;
    Json::parse(text).map_err(|e| ServiceError::BadRequest(format!("{e:#}")))
}

/// `POST .../infer` on one model (shared by `/v1` and `/v2`).
fn infer_route(handle: &ModelHandle, body: &[u8]) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let parsed = match parse_body(body) {
        Ok(j) => j,
        Err(e) => return err(e),
    };
    let infer_req = match super::InferRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return err(e),
    };
    match handle.infer(infer_req) {
        Ok(resp) => (200, resp.to_json()),
        Err(e) => err(e),
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A response body: JSON for the API routes, Prometheus plain text for
/// `GET /metrics`.
pub(crate) enum Payload {
    Json(Json),
    Text(String),
}

/// Where the net layer should deliver its accept-to-flush span
/// annotations: the request id (= trace id) plus the owning engine's
/// recorder. Returned by [`route`] for successfully routed infer
/// requests while that model's tracer is sampling.
pub(crate) struct NetTrace {
    pub id: u64,
    pub tracer: Arc<crate::obs::TraceRecorder>,
}

/// Dispatch one request. Runs on a dispatch-pool thread (may block on
/// the engine queue), never on an event loop. The third element tells
/// the net layer which trace (if any) to annotate with its own
/// dispatch-wait / flush timestamps.
pub(crate) fn route(
    registry: &ModelRegistry,
    req: &HttpRequest,
) -> (u16, Payload, Option<NetTrace>) {
    if req.path == "/metrics" {
        if req.method == "GET" {
            return (200, Payload::Text(registry.metrics_text()), None);
        }
        let e = ServiceError::MethodNotAllowed(format!("{} /metrics", req.method));
        return (e.http_status(), Payload::Json(e.to_json()), None);
    }
    let (status, body) = route_json(registry, req);
    let trace = net_trace_for(registry, req, status, &body);
    (status, Payload::Json(body), trace)
}

/// The net layer learns a request's trace id only from the routed
/// response (the id is allocated inside the service), so the annotation
/// target is resolved after the fact: a 200 infer response on a model
/// whose tracer is sampling.
fn net_trace_for(
    registry: &ModelRegistry,
    req: &HttpRequest,
    status: u16,
    body: &Json,
) -> Option<NetTrace> {
    if status != 200 || req.method != "POST" {
        return None;
    }
    let handle = if req.path == "/v1/infer" {
        registry.default_model()
    } else {
        let rest = req.path.strip_prefix("/v2/models/")?;
        let (name, tail) = rest.split_once('/')?;
        if tail != "infer" {
            return None;
        }
        registry.get(name).ok()?
    };
    let tracer = handle.service().engine().tracer();
    if !tracer.enabled() {
        return None;
    }
    let id = body.get("id").ok()?.i64().ok()? as u64;
    Some(NetTrace {
        id,
        tracer: Arc::clone(tracer),
    })
}

/// All the JSON routes (everything except `/metrics`).
fn route_json(registry: &ModelRegistry, req: &HttpRequest) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let method = req.method.as_str();
    let path = req.path.as_str();

    if let Some(id) = path.strip_prefix("/v1/trace/") {
        return trace_route(registry.default_model(), method, path, id);
    }

    // ----- /v1: bit-compatible shim over the registry's default model ----
    match (method, path) {
        ("POST", "/v1/infer") => return infer_route(registry.default_model(), &req.body),
        ("POST", "/v1/plan") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(s) => s,
                Err(_) => return err(ServiceError::BadRequest("body is not UTF-8".into())),
            };
            return match registry.default_model().create_and_activate(body) {
                Ok(generation) => (200, obj(vec![("generation", Json::Num(generation as f64))])),
                Err(e) => err(e),
            };
        }
        ("GET", "/v1/stats") => return (200, registry.default_model().service().stats().to_json()),
        ("GET", "/v1/healthz") => {
            return (200, registry.default_model().service().health().to_json())
        }
        (_, "/v1/infer") | (_, "/v1/plan") | (_, "/v1/stats") | (_, "/v1/healthz") => {
            return err(ServiceError::MethodNotAllowed(format!("{method} {path}")))
        }
        _ => {}
    }

    // ----- /v2: the registry surface --------------------------------------
    let Some(rest) = path.strip_prefix("/v2/") else {
        return err(ServiceError::NotFound(path.to_string()));
    };
    let segs: Vec<&str> = rest.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["models"] => match method {
            "GET" => (200, registry.list_json()),
            _ => err(ServiceError::MethodNotAllowed(format!("{method} {path}"))),
        },
        ["models", name, tail @ ..] => {
            let handle = match registry.get(name) {
                Ok(h) => h,
                Err(e) => return err(e),
            };
            route_model(handle, method, path, tail, &req.body)
        }
        _ => err(ServiceError::NotFound(path.to_string())),
    }
}

/// `GET /v1/trace/{id}`: the span tree of one sampled request on the
/// default model, or 404 if the id was never sampled (or fell out of
/// the bounded ring).
fn trace_route(handle: &ModelHandle, method: &str, path: &str, id: &str) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    if method != "GET" {
        return err(ServiceError::MethodNotAllowed(format!("{method} {path}")));
    }
    let Ok(id) = id.parse::<u64>() else {
        return err(ServiceError::BadRequest(format!(
            "trace id must be an integer, got {id:?}"
        )));
    };
    match handle.service().engine().tracer().get(id) {
        Some(trace) => (200, trace),
        None => err(ServiceError::NotFound(format!("trace {id}"))),
    }
}

/// Routes under `/v2/models/{name}/...`.
fn route_model(
    handle: &ModelHandle,
    method: &str,
    path: &str,
    tail: &[&str],
    body: &[u8],
) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    let wrong_method = || {
        (
            405,
            ServiceError::MethodNotAllowed(format!("{method} {path}")).to_json(),
        )
    };
    match tail {
        ["infer"] => match method {
            "POST" => infer_route(handle, body),
            _ => wrong_method(),
        },
        ["stats"] => match method {
            "GET" => (200, handle.stats_json()),
            _ => wrong_method(),
        },
        ["traces"] => match method {
            "GET" => (200, handle.service().engine().tracer().recent(50)),
            _ => wrong_method(),
        },
        ["plans"] => match method {
            "GET" => (
                200,
                Json::Arr(
                    handle
                        .list_versions()
                        .iter()
                        .map(|p| p.meta_json())
                        .collect(),
                ),
            ),
            "POST" => {
                let text = match std::str::from_utf8(body) {
                    Ok(s) => s,
                    Err(_) => {
                        return err(ServiceError::BadRequest("body is not UTF-8".into()))
                    }
                };
                match handle.create_version(text) {
                    Ok(pv) => (200, pv.meta_json()),
                    Err(e) => err(e),
                }
            }
            _ => wrong_method(),
        },
        ["rollback"] => match method {
            "POST" => match handle.rollback() {
                Ok((version, generation)) => (
                    200,
                    obj(vec![
                        ("version", Json::Num(version as f64)),
                        ("generation", Json::Num(generation as f64)),
                    ]),
                ),
                Err(e) => err(e),
            },
            _ => wrong_method(),
        },
        ["plans", v, action] => {
            let Ok(version) = v.parse::<u64>() else {
                return err(ServiceError::BadRequest(format!(
                    "plan version must be an integer, got {v:?}"
                )));
            };
            match *action {
                // Unknown actions are 404 regardless of method (the
                // resource does not exist); known ones take POST only.
                "activate" | "canary" | "shadow" if method != "POST" => wrong_method(),
                "activate" => match handle.activate(version) {
                    Ok(generation) => (
                        200,
                        obj(vec![
                            ("version", Json::Num(version as f64)),
                            ("generation", Json::Num(generation as f64)),
                        ]),
                    ),
                    Err(e) => err(e),
                },
                "canary" => {
                    let fraction = match parse_body(body).and_then(|j| {
                        j.get("fraction")
                            .and_then(|f| f.f64())
                            .map_err(|e| ServiceError::BadRequest(format!("fraction: {e}")))
                    }) {
                        Ok(f) => f,
                        Err(e) => return err(e),
                    };
                    match handle.start_canary(version, fraction) {
                        Ok(()) => (
                            200,
                            obj(vec![
                                ("version", Json::Num(version as f64)),
                                ("fraction", Json::Num(fraction)),
                            ]),
                        ),
                        Err(e) => err(e),
                    }
                }
                "shadow" => match handle.start_shadow(version) {
                    Ok(()) => (
                        200,
                        obj(vec![
                            ("version", Json::Num(version as f64)),
                            ("shadow", Json::Bool(true)),
                        ]),
                    ),
                    Err(e) => err(e),
                },
                _ => err(ServiceError::NotFound(path.to_string())),
            }
        }
        _ => err(ServiceError::NotFound(path.to_string())),
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Serialize one response with correct framing — for JSON bodies the
/// head format is byte-identical to the pre-readiness-loop server;
/// text bodies (only `/metrics`) carry the Prometheus content type.
pub(crate) fn response_bytes(status: u16, body: &Payload, keep_alive: bool) -> Vec<u8> {
    let (ctype, body) = match body {
        Payload::Json(j) => ("application/json", j.to_string()),
        Payload::Text(t) => ("text/plain; version=0.0.4", t.clone()),
    };
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {ctype}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        status_text(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}
