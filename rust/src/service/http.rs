//! Dependency-free HTTP/1.1 front-end for [`AdaptService`].
//!
//! The build is offline, so the framing is hand-rolled over
//! `std::net::TcpListener` (the same spirit as the vendored stand-ins):
//! request-line + headers, `Content-Length` bodies, `keep-alive`
//! connections, JSON in / JSON out. Exactly four routes:
//!
//! ```text
//! POST /v1/infer    InferRequest body  -> InferResponse | error
//! POST /v1/plan     plan JSON or {"spec": "..."} -> {"generation": n}
//! GET  /v1/stats    live pool stats (totals, per-worker, p50/p95/p99)
//! GET  /v1/healthz  liveness summary
//! ```
//!
//! Every error is a [`ServiceError`] rendered as
//! `{"error": code, "message": ...}` with that variant's status code.
//! Bodies above [`ServeOptions::max_body`] are refused with 413 before
//! being read; malformed framing gets 400; unknown routes 404; known
//! routes with the wrong method 405.
//!
//! One thread per connection, each with a short read timeout so `stop()`
//! can join everything promptly. Serving threads only share the
//! `Arc<AdaptService>`; all request-level concurrency control (bounded
//! queue, backpressure) stays in the engine pool underneath.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::api::ServiceError;
use super::AdaptService;
use crate::util::json::Json;

/// Server tunables.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Max request-body size in bytes; larger gets 413 without a read.
    pub max_body: usize,
    /// Per-read socket timeout: the granularity at which connection
    /// threads notice `stop()`.
    pub read_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_body: 8 << 20,
            read_timeout: Duration::from_millis(100),
        }
    }
}

/// One parsed request.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Connection-level outcome of trying to read a request.
enum ReadOutcome {
    Request(HttpRequest),
    /// Peer closed (or idle + server stopping): drop the connection.
    Closed,
    /// Framing error worth answering before closing.
    Bad(ServiceError),
}

/// The serving front-end: accept loop + per-connection threads.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// serve `service` until [`stop`](Self::stop).
    pub fn start(service: Arc<AdaptService>, addr: &str) -> Result<HttpServer> {
        Self::start_with(service, addr, ServeOptions::default())
    }

    pub fn start_with(
        service: Arc<AdaptService>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("adapt-http-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let service = Arc::clone(&service);
                        let stop = Arc::clone(&stop);
                        let handle = std::thread::Builder::new()
                            .name("adapt-http-conn".into())
                            .spawn(move || serve_conn(stream, &service, &stop, opts));
                        if let Ok(h) = handle {
                            let mut guard = conns.lock().expect("conn list poisoned");
                            // Reap finished threads so a long-lived server
                            // doesn't accumulate handles.
                            guard.retain(|j: &std::thread::JoinHandle<()>| !j.is_finished());
                            guard.push(h);
                        }
                    }
                })
                .context("spawning accept loop")?
        };
        Ok(HttpServer {
            addr,
            stop,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake the accept loop, and join every connection
    /// thread (each notices the flag within one read timeout).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().expect("conn list poisoned");
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::Release);
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
    }
}

/// Serve one connection: a keep-alive loop of read → route → respond.
fn serve_conn(
    mut stream: TcpStream,
    service: &AdaptService,
    stop: &AtomicBool,
    opts: ServeOptions,
) {
    let _ = stream.set_read_timeout(Some(opts.read_timeout));
    let _ = stream.set_nodelay(true);
    // Bytes read past the previous request's body (HTTP/1.1 pipelining):
    // they are the start of the next request, not garbage.
    let mut carry: Vec<u8> = Vec::new();
    loop {
        match read_request(&mut stream, &mut carry, stop, opts.max_body) {
            ReadOutcome::Closed => return,
            ReadOutcome::Bad(e) => {
                // Drain what the peer already sent (bounded) before the
                // error response + close: closing with unread data makes
                // some TCP stacks RST and discard the response in flight.
                drain(&mut stream, 1 << 20);
                let _ = write_response(&mut stream, e.http_status(), &e.to_json(), false);
                return;
            }
            ReadOutcome::Request(req) => {
                let (status, body) = route(service, &req);
                if write_response(&mut stream, status, &body, req.keep_alive).is_err()
                    || !req.keep_alive
                {
                    return;
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            return;
        }
    }
}

/// Dispatch one request to the service. Always returns a JSON body.
fn route(service: &AdaptService, req: &HttpRequest) -> (u16, Json) {
    let err = |e: ServiceError| (e.http_status(), e.to_json());
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/infer") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(s) => s,
                Err(_) => return err(ServiceError::BadRequest("body is not UTF-8".into())),
            };
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return err(ServiceError::BadRequest(format!("{e:#}"))),
            };
            let infer_req = match super::InferRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return err(e),
            };
            match service.infer(infer_req) {
                Ok(resp) => (200, resp.to_json()),
                Err(e) => err(e),
            }
        }
        ("POST", "/v1/plan") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(s) => s,
                Err(_) => return err(ServiceError::BadRequest("body is not UTF-8".into())),
            };
            match service.swap_plan_body(body) {
                Ok(generation) => {
                    let mut m = std::collections::BTreeMap::new();
                    m.insert("generation".into(), Json::Num(generation as f64));
                    (200, Json::Obj(m))
                }
                Err(e) => err(e),
            }
        }
        ("GET", "/v1/stats") => (200, service.stats().to_json()),
        ("GET", "/v1/healthz") => (200, service.health().to_json()),
        (_, "/v1/infer") | (_, "/v1/plan") | (_, "/v1/stats") | (_, "/v1/healthz") => err(
            ServiceError::MethodNotAllowed(format!("{} {}", req.method, req.path)),
        ),
        _ => err(ServiceError::NotFound(req.path.clone())),
    }
}

/// Read one request (request line + headers + Content-Length body).
/// `carry` holds bytes already read past the previous request's body
/// (pipelining); on return it holds whatever follows *this* request.
fn read_request(
    stream: &mut TcpStream,
    carry: &mut Vec<u8>,
    stop: &AtomicBool,
    max_body: usize,
) -> ReadOutcome {
    const MAX_HEAD: usize = 16 << 10;
    let mut buf: Vec<u8> = std::mem::take(carry);
    let mut chunk = [0u8; 4096];
    // --- head: read until \r\n\r\n -------------------------------------
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return ReadOutcome::Bad(ServiceError::BadRequest("header block too large".into()));
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle timeout: keep waiting unless the server is
                // stopping (a half-received request is dropped then —
                // its sender gets a reset, not a hang).
                if stop.load(Ordering::Acquire) {
                    return ReadOutcome::Closed;
                }
                continue;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    };
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(s) => s.to_string(),
        Err(_) => return ReadOutcome::Bad(ServiceError::BadRequest("non-UTF-8 header".into())),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => {
            return ReadOutcome::Bad(ServiceError::BadRequest(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return ReadOutcome::Bad(ServiceError::BadRequest(format!(
            "unsupported version {version:?}"
        )));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    for line in lines {
        let Some((k, v)) = line.split_once(':') else {
            continue;
        };
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim());
        if k == "content-length" {
            content_length = match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    return ReadOutcome::Bad(ServiceError::BadRequest(format!(
                        "bad content-length {v:?}"
                    )))
                }
            };
        } else if k == "connection" {
            keep_alive = !v.eq_ignore_ascii_case("close");
        }
    }
    if content_length > max_body {
        return ReadOutcome::Bad(ServiceError::BodyTooLarge {
            got: content_length,
            max: max_body,
        });
    }
    // --- body: exactly content_length bytes past the head ----------------
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Acquire) {
                    return ReadOutcome::Closed;
                }
                continue;
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
    // Anything past this request's body is the next pipelined request.
    if body.len() > content_length {
        *carry = body.split_off(content_length);
    }
    ReadOutcome::Request(HttpRequest {
        method,
        path,
        body,
        keep_alive,
    })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read and discard up to `cap` already-sent bytes (stops at the first
/// read timeout — the peer has gone quiet — or EOF).
fn drain(stream: &mut TcpStream, cap: usize) {
    let mut chunk = [0u8; 4096];
    let mut total = 0usize;
    while total < cap {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => total += n,
            Err(_) => break,
        }
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Write one JSON response with correct framing.
fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        status_text(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}
