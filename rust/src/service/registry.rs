//! Multi-model registry: named models, immutable plan versions, canary
//! rollout and live shadow evaluation.
//!
//! [`ModelRegistry`] owns N named models. Each [`ModelHandle`] wraps its
//! own [`AdaptService`] engine pool plus a [`PlanStore`] of immutable,
//! numbered plan versions (created from a plan JSON document or a
//! `{"spec": ...}` policy, never mutated, with `created`/`source`
//! metadata). On top of the store sits the rollout lifecycle:
//!
//! * **activate** — install a version on the pool (weights re-quantized
//!   once, `Arc`-shared) and flip untagged traffic to it at the next
//!   batch boundary; the previous active version is remembered for
//!   **rollback**. No executed batch ever mixes versions.
//! * **canary** — route a configurable fraction of requests to the
//!   candidate version's workers (deterministic counter-based split:
//!   exactly `⌊n·fraction⌋` of the first `n` requests).
//! * **shadow** — mirror every request to the candidate and compare its
//!   output against the active plan's *online*: per-version disagreement
//!   rate, top-1 flip rate and max `|Δ|` accumulate in [`ShadowStats`],
//!   turning the paper's offline accuracy evaluation into a live,
//!   promote-or-rollback decision.
//!
//! The `/v1` single-model routes are a thin shim over the registry's
//! default model ([`ModelHandle::create_and_activate`] reproduces the
//! `POST /v1/plan` create-and-flip semantics bit-for-bit).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::SystemTime;

use anyhow::Result;

use super::api::ServiceError;
use super::{AdaptService, InferHandle, InferRequest, InferResponse};
use crate::coordinator::engine::{EmulatorSpec, LatencyHist, LAT_BUCKETS};
use crate::graph::{retransform, ExecutionPlan, Policy};
use crate::obs::prom::PromWriter;
use crate::obs::NetStats;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Plan versions
// ---------------------------------------------------------------------------

/// One immutable, numbered plan version. Once created it never changes;
/// a "changed" plan is a *new* version.
pub struct PlanVersion {
    pub version: u64,
    /// Where the plan came from: `"initial"`, `"spec:<text>"` or `"json"`.
    pub source: String,
    /// Unix seconds at creation.
    pub created_unix_s: f64,
    pub plan: ExecutionPlan,
}

impl PlanVersion {
    pub fn meta_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".into(), Json::Num(self.version as f64));
        m.insert("source".into(), Json::Str(self.source.clone()));
        m.insert("created_unix_s".into(), Json::Num(self.created_unix_s));
        Json::Obj(m)
    }
}

/// Append-only store of a model's plan versions, numbered from 1.
pub struct PlanStore {
    versions: BTreeMap<u64, Arc<PlanVersion>>,
    next: u64,
}

impl PlanStore {
    fn new() -> PlanStore {
        PlanStore {
            versions: BTreeMap::new(),
            next: 1,
        }
    }

    fn add(&mut self, source: String, plan: ExecutionPlan) -> Arc<PlanVersion> {
        let version = self.next;
        self.next += 1;
        let created_unix_s = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let pv = Arc::new(PlanVersion {
            version,
            source,
            created_unix_s,
            plan,
        });
        self.versions.insert(version, Arc::clone(&pv));
        pv
    }

    pub fn get(&self, version: u64) -> Option<Arc<PlanVersion>> {
        self.versions.get(&version).cloned()
    }

    /// Every version, ascending.
    pub fn list(&self) -> Vec<Arc<PlanVersion>> {
        self.versions.values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.versions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.versions.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Shadow evaluation
// ---------------------------------------------------------------------------

/// Live shadow-comparison counters for one candidate version. Workers
/// publish through atomics; [`ShadowStats::report`] snapshots any time.
pub struct ShadowStats {
    /// Comparisons completed (primary + mirror both answered).
    mirrored: AtomicU64,
    /// Mirror or primary failures — nothing to compare.
    errors: AtomicU64,
    /// Outputs differed in at least one f32 bit.
    disagree: AtomicU64,
    /// Argmax (top-1 class) changed.
    top1_flips: AtomicU64,
    /// Max `|candidate - active|` seen, as f32 bits (both non-negative,
    /// so the bit order is the numeric order and a CAS-max works).
    max_abs_delta_bits: AtomicU32,
}

impl ShadowStats {
    fn new() -> ShadowStats {
        ShadowStats {
            mirrored: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            disagree: AtomicU64::new(0),
            top1_flips: AtomicU64::new(0),
            max_abs_delta_bits: AtomicU32::new(0),
        }
    }

    fn record(&self, primary: &[f32], mirror: &[f32]) {
        let disagree = primary.len() != mirror.len()
            || primary
                .iter()
                .zip(mirror)
                .any(|(a, b)| a.to_bits() != b.to_bits());
        if disagree {
            self.disagree.fetch_add(1, Ordering::Relaxed);
        }
        if argmax(primary) != argmax(mirror) {
            self.top1_flips.fetch_add(1, Ordering::Relaxed);
        }
        let mut max_d = 0f32;
        for (a, b) in primary.iter().zip(mirror) {
            max_d = max_d.max((a - b).abs());
        }
        let bits = max_d.to_bits();
        let mut cur = self.max_abs_delta_bits.load(Ordering::Relaxed);
        while bits > cur {
            match self.max_abs_delta_bits.compare_exchange_weak(
                cur,
                bits,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        // Last, so a poller that sees `mirrored == n` sees n complete
        // comparisons in the other counters.
        self.mirrored.fetch_add(1, Ordering::Release);
    }

    pub fn report(&self, version: u64) -> ShadowReport {
        ShadowReport {
            version,
            mirrored: self.mirrored.load(Ordering::Acquire),
            errors: self.errors.load(Ordering::Relaxed),
            disagree: self.disagree.load(Ordering::Relaxed),
            top1_flips: self.top1_flips.load(Ordering::Relaxed),
            max_abs_delta: f32::from_bits(self.max_abs_delta_bits.load(Ordering::Relaxed)),
        }
    }
}

/// POD snapshot of one candidate's [`ShadowStats`].
#[derive(Clone, Debug, PartialEq)]
pub struct ShadowReport {
    pub version: u64,
    pub mirrored: u64,
    pub errors: u64,
    pub disagree: u64,
    pub top1_flips: u64,
    pub max_abs_delta: f32,
}

impl ShadowReport {
    /// Fraction of compared requests whose outputs differed anywhere.
    pub fn disagreement_rate(&self) -> f64 {
        self.disagree as f64 / (self.mirrored as f64).max(1.0)
    }

    /// Fraction of compared requests whose top-1 class flipped.
    pub fn top1_flip_rate(&self) -> f64 {
        self.top1_flips as f64 / (self.mirrored as f64).max(1.0)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".into(), Json::Num(self.version as f64));
        m.insert("mirrored".into(), Json::Num(self.mirrored as f64));
        m.insert("errors".into(), Json::Num(self.errors as f64));
        m.insert("disagree".into(), Json::Num(self.disagree as f64));
        m.insert(
            "disagreement_rate".into(),
            Json::Num(self.disagreement_rate()),
        );
        m.insert("top1_flips".into(), Json::Num(self.top1_flips as f64));
        m.insert("top1_flip_rate".into(), Json::Num(self.top1_flip_rate()));
        m.insert("max_abs_delta".into(), Json::Num(self.max_abs_delta as f64));
        Json::Obj(m)
    }
}

/// First index of the largest element (ties break to the lower index —
/// same convention as [`top_k_of`](super::top_k_of)).
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate().skip(1) {
        if v.total_cmp(&xs[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

/// One completed primary response waiting for its mirror: the collector
/// thread blocks on `rx` and folds the comparison into `stats`.
struct ShadowJob {
    stats: Arc<ShadowStats>,
    primary: Vec<f32>,
    rx: crate::coordinator::engine::RawReceiver,
}

// ---------------------------------------------------------------------------
// Rollout state
// ---------------------------------------------------------------------------

/// Traffic-splitting state for one model.
struct Rollout {
    /// Version untagged requests route to (kept for `previous`
    /// bookkeeping; reporting reads the engine's table, the single
    /// source of truth).
    active: u64,
    /// The version `active` replaced (the rollback target).
    previous: Option<u64>,
    canary: Option<Arc<CanaryState>>,
    /// The live shadow experiment, if any.
    shadow: Option<ShadowState>,
}

/// One shadow experiment: the candidate version plus the comparison
/// sinks — carried in the rollout state so the serving path gets
/// everything from the single rollout-lock read it already takes.
#[derive(Clone)]
struct ShadowState {
    version: u64,
    stats: Arc<ShadowStats>,
    tx: mpsc::Sender<ShadowJob>,
}

/// One canary experiment: the split counters live *inside* the state,
/// so a retune (a fresh `CanaryState`) can never have its counters
/// corrupted by an in-flight request that read the previous experiment
/// under the rollout lock — stragglers increment the discarded state.
struct CanaryState {
    version: u64,
    fraction: f64,
    /// Requests seen by this experiment (the split counter).
    seq: AtomicU64,
    /// Requests routed to the candidate.
    routed: AtomicU64,
}

/// Deterministic canary split: request `t` (0-based) goes to the
/// candidate iff the running target `⌊(t+1)·f⌋` advanced — exactly
/// `⌊n·f⌋` of the first `n` requests, at any concurrency.
fn canary_pick(t: u64, fraction: f64) -> bool {
    ((t + 1) as f64 * fraction).floor() > (t as f64 * fraction).floor()
}

// ---------------------------------------------------------------------------
// Per-model handle
// ---------------------------------------------------------------------------

/// One named model in the registry: its engine pool, plan-version store
/// and rollout state.
pub struct ModelHandle {
    name: String,
    service: Arc<AdaptService>,
    store: Mutex<PlanStore>,
    rollout: Mutex<Rollout>,
    /// Cumulative shadow stats per candidate version.
    shadow_stats: Mutex<BTreeMap<u64, Arc<ShadowStats>>>,
    shadow_tx: Mutex<Option<mpsc::Sender<ShadowJob>>>,
    shadow_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// In-flight request on a registry model: the primary handle plus an
/// optional shadow mirror handed to the collector on completion.
pub struct ModelInferHandle {
    primary: InferHandle,
    mirror: Option<(Arc<ShadowStats>, InferHandle, mpsc::Sender<ShadowJob>)>,
}

impl ModelInferHandle {
    pub fn id(&self) -> u64 {
        self.primary.id()
    }

    /// Block until the primary answers; a completed mirror comparison is
    /// handed off to the model's collector thread (never blocks on the
    /// mirror itself).
    pub fn wait(self) -> Result<InferResponse, ServiceError> {
        let resp = self.primary.wait();
        if let Some((stats, mirror, tx)) = self.mirror {
            match &resp {
                Ok(ok) => {
                    let job = ShadowJob {
                        stats: Arc::clone(&stats),
                        primary: ok.output.clone(),
                        rx: mirror.rx,
                    };
                    if tx.send(job).is_err() {
                        // Collector gone (model shutting down).
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Err(_) => {
                    // Nothing to compare; the mirror's answer is dropped.
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        resp
    }
}

impl ModelHandle {
    fn new(name: String, service: Arc<AdaptService>) -> Arc<ModelHandle> {
        let mut store = PlanStore::new();
        let mut active = 0;
        // Seed version 1 with the engine's starting plan (emulator pools;
        // PJRT pools serve unversioned and keep an empty store).
        if let Some(spec) = service.engine().emulator_spec() {
            let pv = store.add("initial".into(), spec.plan.clone());
            active = pv.version;
        }
        Arc::new(ModelHandle {
            name,
            service,
            store: Mutex::new(store),
            rollout: Mutex::new(Rollout {
                active,
                previous: None,
                canary: None,
                shadow: None,
            }),
            shadow_stats: Mutex::new(BTreeMap::new()),
            shadow_tx: Mutex::new(None),
            shadow_thread: Mutex::new(None),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The wrapped control plane (stats, health, direct typed calls).
    pub fn service(&self) -> &Arc<AdaptService> {
        &self.service
    }

    fn emulator_spec(&self) -> Result<Arc<EmulatorSpec>, ServiceError> {
        self.service
            .engine()
            .emulator_spec()
            .cloned()
            .ok_or_else(|| {
                ServiceError::PlanRejected(
                    "plan versioning requires the emulator backend (PJRT executables bake their plan in)"
                        .into(),
                )
            })
    }

    fn plan_of(&self, version: u64) -> Result<Arc<PlanVersion>, ServiceError> {
        self.store
            .lock()
            .expect("plan store poisoned")
            .get(version)
            .ok_or(ServiceError::NoSuchVersion { version })
    }

    // ----- inference (canary + shadow routing) ---------------------------

    /// Submit one request through the model's rollout state: a running
    /// canary claims its fraction, a running shadow mirrors the request
    /// to the candidate. The mirror is best-effort and enqueued *after*
    /// the primary, non-blocking — it never delays or fails the primary
    /// (a full queue drops the mirror and counts a shadow error).
    pub fn submit(&self, req: InferRequest) -> Result<ModelInferHandle, ServiceError> {
        let (canary, shadow) = {
            let r = self.rollout.lock().expect("rollout state poisoned");
            (r.canary.clone(), r.shadow.clone())
        };
        let version = match &canary {
            Some(c) => {
                let t = c.seq.fetch_add(1, Ordering::Relaxed);
                if canary_pick(t, c.fraction) {
                    c.routed.fetch_add(1, Ordering::Relaxed);
                    Some(c.version)
                } else {
                    None
                }
            }
            None => None,
        };
        // Only mirror requests whose primary runs the *active* plan:
        // canary-routed requests would otherwise feed the comparison a
        // candidate-vs-canary baseline and corrupt the stats. The input
        // copy happens before the primary consumes `req`.
        let mirror_input = match (&shadow, version) {
            (Some(_), None) => Some(req.input.clone()),
            _ => None,
        };
        // A candidate retired between the rollout read and here
        // (promote/rollback race) routes to the active plan instead of
        // failing the request; the residual worker-side race is answered
        // with a typed `no_such_version` (see `retire_version`).
        let version = version.filter(|&v| self.service.engine().has_version(v));
        let primary = self.service.submit_to(req, version)?;
        let mirror = match (shadow, mirror_input) {
            (Some(s), Some(input)) => {
                let mirror_req = InferRequest {
                    id: None,
                    input,
                    top_k: None,
                    deadline: None,
                };
                match self.service.try_submit_to(mirror_req, Some(s.version)) {
                    Ok(Some(handle)) => Some((s.stats, handle, s.tx)),
                    // Queue full or candidate gone: drop the mirror.
                    Ok(None) | Err(_) => {
                        s.stats.errors.fetch_add(1, Ordering::Relaxed);
                        None
                    }
                }
            }
            _ => None,
        };
        Ok(ModelInferHandle { primary, mirror })
    }

    /// Blocking convenience wrapper around [`submit`](Self::submit).
    pub fn infer(&self, req: InferRequest) -> Result<InferResponse, ServiceError> {
        self.submit(req)?.wait()
    }

    // ----- plan-version lifecycle ----------------------------------------

    /// Create an immutable plan version from a request body — a plan
    /// JSON document (what `adapt plan --out` writes) or a policy spec
    /// `{"spec": "default=mul8s_1l2h_like,c1=exact8"}` — validated
    /// against the served model. Routes no traffic.
    pub fn create_version(&self, body: &str) -> Result<Arc<PlanVersion>, ServiceError> {
        let spec = self.emulator_spec()?;
        let (source, plan) = parse_plan_body(body, &spec)?;
        // Every named ACU must resolve before the version enters the
        // store — broken plans never become versions.
        spec.luts
            .preload(&plan.acus())
            .map_err(|e| ServiceError::PlanRejected(format!("{e:#}")))?;
        Ok(self
            .store
            .lock()
            .expect("plan store poisoned")
            .add(source, plan))
    }

    /// Version metadata for `GET /v2/models/{name}/plans`.
    pub fn list_versions(&self) -> Vec<Arc<PlanVersion>> {
        self.store.lock().expect("plan store poisoned").list()
    }

    /// Route untagged traffic to `version` (installing it on the pool if
    /// needed), remember the replaced version for rollback, and end any
    /// running canary/shadow experiment. Engine versions no longer
    /// reachable (not active, not the rollback target) are retired to
    /// free their prepared weights. Returns the new generation.
    pub fn activate(&self, version: u64) -> Result<u64, ServiceError> {
        let pv = self.plan_of(version)?;
        let engine = self.service.engine();
        engine.install_version(version, pv.plan.clone())?;
        let generation = engine.activate_version(version)?;
        {
            let mut r = self.rollout.lock().expect("rollout state poisoned");
            if r.active != version {
                r.previous = Some(r.active);
                r.active = version;
            }
            r.canary = None;
            r.shadow = None;
        }
        self.retire_unreachable();
        Ok(generation)
    }

    /// Retire engine versions no longer reachable from the rollout state
    /// (not active, not the rollback target, not a live experiment) so
    /// abandoned candidates release their prepared weights and every
    /// worker's cached executor for them.
    fn retire_unreachable(&self) {
        let (active, previous, canary, shadow) = {
            let r = self.rollout.lock().expect("rollout state poisoned");
            (
                r.active,
                r.previous,
                r.canary.as_ref().map(|c| c.version),
                r.shadow.as_ref().map(|s| s.version),
            )
        };
        let engine = self.service.engine();
        for v in engine.installed_versions() {
            if v != active && Some(v) != previous && Some(v) != canary && Some(v) != shadow {
                let _ = engine.retire_version(v);
            }
        }
    }

    /// The `POST /v1/plan` semantics on this model: create a version
    /// from the body and activate it in one call. Returns the new
    /// generation (the v1 hot-swap counter).
    pub fn create_and_activate(&self, body: &str) -> Result<u64, ServiceError> {
        let pv = self.create_version(body)?;
        self.activate(pv.version)
    }

    /// Parse and hot-swap a plan from a request body — a plan JSON
    /// document or a `{"spec": "..."}` policy — on this model. The former
    /// `AdaptService::swap_plan_body`, folded in here so a direct
    /// in-process swap goes through the [`PlanStore`] like the HTTP path:
    /// the body becomes an immutable numbered version *and* activates.
    /// Returns the new generation.
    pub fn swap_plan_body(&self, body: &str) -> Result<u64, ServiceError> {
        self.create_and_activate(body)
    }

    /// Revert untagged traffic to the previously active version. The
    /// rolled-back-from version becomes the new rollback target, so two
    /// rollbacks ping-pong. Ends any canary/shadow experiment.
    pub fn rollback(&self) -> Result<(u64, u64), ServiceError> {
        let previous = self
            .rollout
            .lock()
            .expect("rollout state poisoned")
            .previous
            .ok_or_else(|| {
                ServiceError::PlanRejected("no previous version to roll back to".into())
            })?;
        let generation = self.activate(previous)?;
        Ok((previous, generation))
    }

    /// Start (or retune) a canary: route `fraction` of subsequent
    /// requests to `version`. The split counters restart.
    pub fn start_canary(&self, version: u64, fraction: f64) -> Result<(), ServiceError> {
        if !(0.0..=1.0).contains(&fraction) {
            return Err(ServiceError::BadRequest(format!(
                "canary fraction must be in [0, 1], got {fraction}"
            )));
        }
        let pv = self.plan_of(version)?;
        let engine = self.service.engine();
        engine.install_version(version, pv.plan.clone())?;
        {
            let mut r = self.rollout.lock().expect("rollout state poisoned");
            if r.active == version {
                return Err(ServiceError::PlanRejected(format!(
                    "version {version} is already active"
                )));
            }
            // A fresh CanaryState carries its own zeroed counters, so
            // the exact ⌊n·f⌋ split holds from the first request that
            // observes this experiment — an in-flight request that read
            // a previous canary increments that discarded state instead.
            r.canary = Some(Arc::new(CanaryState {
                version,
                fraction,
                seq: AtomicU64::new(0),
                routed: AtomicU64::new(0),
            }));
        }
        // A replaced (retuned-away) candidate releases its engine
        // resources instead of lingering installed.
        self.retire_unreachable();
        Ok(())
    }

    /// Start mirroring every request to `version` and comparing its
    /// outputs against the active plan's online.
    pub fn start_shadow(&self, version: u64) -> Result<(), ServiceError> {
        let pv = self.plan_of(version)?;
        let engine = self.service.engine();
        engine.install_version(version, pv.plan.clone())?;
        let stats = self.shadow_stats_for(version);
        let tx = self.collector_tx();
        {
            let mut r = self.rollout.lock().expect("rollout state poisoned");
            if r.active == version {
                return Err(ServiceError::PlanRejected(format!(
                    "version {version} is already active"
                )));
            }
            r.shadow = Some(ShadowState { version, stats, tx });
        }
        self.retire_unreachable();
        Ok(())
    }

    /// The running canary's (version, fraction); `None` when no canary
    /// experiment is live (the `/metrics` gauge source).
    pub fn canary_fraction(&self) -> Option<(u64, f64)> {
        self.rollout
            .lock()
            .expect("rollout state poisoned")
            .canary
            .as_ref()
            .map(|c| (c.version, c.fraction))
    }

    /// The running shadow experiment's candidate version, if any.
    pub fn shadow_version(&self) -> Option<u64> {
        self.rollout
            .lock()
            .expect("rollout state poisoned")
            .shadow
            .as_ref()
            .map(|s| s.version)
    }

    /// (requests routed to the canary, requests seen) since the current
    /// canary experiment started; `(0, 0)` when none is running.
    pub fn canary_counters(&self) -> (u64, u64) {
        self.rollout
            .lock()
            .expect("rollout state poisoned")
            .canary
            .as_ref()
            .map(|c| {
                (
                    c.routed.load(Ordering::Relaxed),
                    c.seq.load(Ordering::Relaxed),
                )
            })
            .unwrap_or((0, 0))
    }

    /// Live shadow report for a candidate version, if it ever shadowed.
    pub fn shadow_report(&self, version: u64) -> Option<ShadowReport> {
        self.shadow_stats
            .lock()
            .expect("shadow stats poisoned")
            .get(&version)
            .map(|s| s.report(version))
    }

    fn shadow_stats_for(&self, version: u64) -> Arc<ShadowStats> {
        Arc::clone(
            self.shadow_stats
                .lock()
                .expect("shadow stats poisoned")
                .entry(version)
                .or_insert_with(|| Arc::new(ShadowStats::new())),
        )
    }

    /// The mirror-comparison collector's channel, spawning the collector
    /// on first use. One thread per model: it blocks on each mirror's
    /// receiver in submission order, so shadow comparison never sits on
    /// a serving thread.
    fn collector_tx(&self) -> mpsc::Sender<ShadowJob> {
        let mut guard = self.shadow_tx.lock().expect("shadow channel poisoned");
        if let Some(tx) = guard.as_ref() {
            return tx.clone();
        }
        let (sender, receiver) = mpsc::channel::<ShadowJob>();
        *guard = Some(sender.clone());
        let handle = std::thread::Builder::new()
            .name(format!("adapt-shadow-{}", self.name))
            .spawn(move || {
                for job in receiver {
                    match job.rx.recv() {
                        Ok(Ok(raw)) => job.stats.record(&job.primary, &raw.output),
                        _ => {
                            job.stats.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        if let Ok(h) = handle {
            *self.shadow_thread.lock().expect("shadow thread poisoned") = Some(h);
        }
        sender
    }

    // ----- observability --------------------------------------------------

    /// The `GET /v2/models/{name}/stats` body: the service stats plus
    /// rollout state, canary counters and per-version shadow reports.
    pub fn stats_json(&self) -> Json {
        let Json::Obj(mut m) = self.service.stats().to_json() else {
            unreachable!("ServiceStats::to_json always returns an object");
        };
        m.insert("name".into(), Json::Str(self.name.clone()));
        // "active_version" stays the engine-table value ServiceStats
        // already reported — the single source of truth.
        let (previous, canary, shadow) = {
            let r = self.rollout.lock().expect("rollout state poisoned");
            (
                r.previous,
                r.canary.clone(),
                r.shadow.as_ref().map(|s| s.version),
            )
        };
        m.insert(
            "previous_version".into(),
            match previous {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        );
        m.insert(
            "canary".into(),
            match canary {
                Some(c) => {
                    let mut cm = BTreeMap::new();
                    cm.insert("version".into(), Json::Num(c.version as f64));
                    cm.insert("fraction".into(), Json::Num(c.fraction));
                    cm.insert(
                        "routed".into(),
                        Json::Num(c.routed.load(Ordering::Relaxed) as f64),
                    );
                    cm.insert(
                        "seen".into(),
                        Json::Num(c.seq.load(Ordering::Relaxed) as f64),
                    );
                    Json::Obj(cm)
                }
                None => Json::Null,
            },
        );
        m.insert(
            "shadow".into(),
            match shadow {
                Some(v) => Json::Num(v as f64),
                None => Json::Null,
            },
        );
        let reports: BTreeMap<String, Json> = {
            let stats = self.shadow_stats.lock().expect("shadow stats poisoned");
            stats
                .iter()
                .map(|(v, s)| (v.to_string(), s.report(*v).to_json()))
                .collect()
        };
        m.insert("shadow_reports".into(), Json::Obj(reports));
        m.insert(
            "versions".into(),
            Json::Num(self.store.lock().expect("plan store poisoned").len() as f64),
        );
        Json::Obj(m)
    }

    /// One row of the `GET /v2/models` listing.
    pub fn summary_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert(
            "model".into(),
            Json::Str(self.service.model_name().to_string()),
        );
        let (canary, shadow) = {
            let r = self.rollout.lock().expect("rollout state poisoned");
            (
                r.canary.as_ref().map(|c| c.version),
                r.shadow.as_ref().map(|s| s.version),
            )
        };
        m.insert(
            "active_version".into(),
            Json::Num(self.service.engine().active_version() as f64),
        );
        m.insert(
            "versions".into(),
            Json::Num(self.store.lock().expect("plan store poisoned").len() as f64),
        );
        m.insert(
            "generation".into(),
            Json::Num(self.service.engine().generation() as f64),
        );
        m.insert(
            "workers".into(),
            Json::Num(self.service.engine().workers() as f64),
        );
        m.insert(
            "input_len".into(),
            Json::Num(self.service.input_len() as f64),
        );
        m.insert("out_dim".into(), Json::Num(self.service.out_dim() as f64));
        m.insert(
            "canary_version".into(),
            canary.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
        );
        m.insert(
            "shadow_version".into(),
            shadow.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }
}

impl Drop for ModelHandle {
    fn drop(&mut self) {
        // Close the collector channel, then join the thread so pending
        // comparisons finish before the engine pool is torn down.
        *self.shadow_tx.lock().expect("shadow channel poisoned") = None;
        if let Some(h) = self
            .shadow_thread
            .lock()
            .expect("shadow thread poisoned")
            .take()
        {
            let _ = h.join();
        }
    }
}

/// Parse a plan body: `{"spec": "..."}` resolves a policy against the
/// served model; anything else must be a plan JSON document. Returns the
/// version `source` tag alongside the plan — plan documents carrying a
/// `provenance` field (e.g. `"mcts:<seed>/<budget>"` from `adapt search`)
/// keep it as the source tag so the store records where a searched plan
/// came from. (Shared by the `/v1` swap shim and `/v2` version creation,
/// so their error surfaces match.)
pub(crate) fn parse_plan_body(
    body: &str,
    spec: &EmulatorSpec,
) -> Result<(String, ExecutionPlan), ServiceError> {
    let j = Json::parse(body).map_err(|e| ServiceError::BadRequest(format!("{e:#}")))?;
    match j.opt("spec") {
        Some(s) => {
            let text = s
                .str()
                .map_err(|e| ServiceError::BadRequest(format!("spec: {e}")))?;
            let policy = Policy::parse_spec(text)
                .map_err(|e| ServiceError::BadRequest(format!("{e:#}")))?;
            let unmatched = policy.unmatched_overrides(&spec.model);
            if !unmatched.is_empty() {
                return Err(ServiceError::PlanRejected(format!(
                    "spec overrides match no layer of {}: {unmatched:?}",
                    spec.model.name
                )));
            }
            Ok((format!("spec:{text}"), retransform(&spec.model, &policy)))
        }
        None => Ok((
            ExecutionPlan::provenance_of(body).unwrap_or_else(|| "json".into()),
            ExecutionPlan::from_json(body, &spec.model)
                .map_err(|e| ServiceError::PlanRejected(format!("{e:#}")))?,
        )),
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// N named models, each with its own engine pool and plan lifecycle.
/// The first entry is the **default model** the `/v1` shim serves.
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<ModelHandle>>,
    /// Names in registration order (BTreeMap sorts; listings shouldn't).
    order: Vec<String>,
    default: String,
    /// Process-wide net-layer lifecycle counters, shared with every
    /// event loop ([`crate::service::net::NetServer`]) and rendered
    /// under `GET /metrics`.
    net: Arc<NetStats>,
}

impl ModelRegistry {
    /// Build a registry over named services. Fails on an empty list or a
    /// duplicate name.
    pub fn new(entries: Vec<(String, Arc<AdaptService>)>) -> Result<ModelRegistry> {
        anyhow::ensure!(!entries.is_empty(), "registry needs at least one model");
        let default = entries[0].0.clone();
        let mut models = BTreeMap::new();
        let mut order = Vec::with_capacity(entries.len());
        for (name, service) in entries {
            anyhow::ensure!(
                !models.contains_key(&name),
                "duplicate model name {name:?} in registry"
            );
            order.push(name.clone());
            models.insert(name.clone(), ModelHandle::new(name, service));
        }
        Ok(ModelRegistry {
            models,
            order,
            default,
            net: Arc::new(NetStats::new()),
        })
    }

    /// The shared net-layer counters (event loops write, `/metrics`
    /// reads).
    pub fn net_stats(&self) -> &Arc<NetStats> {
        &self.net
    }

    /// Single-model registry (what wrapping a bare [`AdaptService`] in
    /// the HTTP front-end builds): the model registers under its own
    /// name and becomes the default.
    pub fn single(service: Arc<AdaptService>) -> ModelRegistry {
        let name = service.model_name().to_string();
        ModelRegistry::new(vec![(name, service)]).expect("one named model is always valid")
    }

    pub fn get(&self, name: &str) -> Result<&Arc<ModelHandle>, ServiceError> {
        self.models
            .get(name)
            .ok_or_else(|| ServiceError::ModelNotFound(name.to_string()))
    }

    /// The model the `/v1` shim serves.
    pub fn default_model(&self) -> &Arc<ModelHandle> {
        self.models.get(&self.default).expect("default model exists")
    }

    /// Every model, in registration order.
    pub fn models(&self) -> Vec<&Arc<ModelHandle>> {
        self.order
            .iter()
            .map(|n| self.models.get(n).expect("ordered name exists"))
            .collect()
    }

    /// The `GET /v2/models` body.
    pub fn list_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("default".into(), Json::Str(self.default.clone()));
        m.insert(
            "models".into(),
            Json::Arr(self.models().iter().map(|h| h.summary_json()).collect()),
        );
        Json::Obj(m)
    }

    /// The `GET /metrics` body: Prometheus text exposition (0.0.4) over
    /// every model's engine counters + latency histograms, rollout
    /// state, and the shared net-layer counters. Every metric name is
    /// `adapt_`-prefixed snake_case (CI lints the scrape).
    pub fn metrics_text(&self) -> String {
        struct Snap {
            name: String,
            stats: super::ServiceStats,
            canary_fraction: f64,
            shadow_rate: f64,
            traces_retained: usize,
        }
        let snaps: Vec<Snap> = self
            .models()
            .iter()
            .map(|h| {
                let shadow_rate = h
                    .shadow_version()
                    .and_then(|v| h.shadow_report(v))
                    .map(|r| r.disagreement_rate())
                    .unwrap_or(0.0);
                Snap {
                    name: h.name().to_string(),
                    stats: h.service().stats(),
                    canary_fraction: h.canary_fraction().map(|(_, f)| f).unwrap_or(0.0),
                    shadow_rate,
                    traces_retained: h.service().engine().tracer().retained(),
                }
            })
            .collect();

        let mut w = PromWriter::new();
        let counters: [(&str, &str, fn(&Snap) -> f64); 3] = [
            (
                "adapt_requests_total",
                "Requests admitted by the engine pool.",
                |s| s.stats.pool.total.requests as f64,
            ),
            (
                "adapt_batches_total",
                "Batches executed by the engine pool.",
                |s| s.stats.pool.total.batches as f64,
            ),
            (
                "adapt_padded_slots_total",
                "Batch slots filled with padding rather than real requests.",
                |s| s.stats.pool.total.padded_slots as f64,
            ),
        ];
        for (name, help, get) in counters {
            w.header(name, help, "counter");
            for s in &snaps {
                w.sample(name, &[("model", &s.name)], get(s));
            }
        }
        let gauges: [(&str, &str, fn(&Snap) -> f64); 8] = [
            (
                "adapt_padding_ratio",
                "Fraction of executed batch slots that were padding.",
                |s| {
                    let real = s.stats.pool.total.requests as f64;
                    let padded = s.stats.pool.total.padded_slots as f64;
                    padded / (real + padded).max(1.0)
                },
            ),
            ("adapt_queue_depth", "Requests waiting in the engine queue.", |s| {
                s.stats.queue_len as f64
            }),
            ("adapt_workers", "Configured engine pool workers.", |s| {
                s.stats.workers as f64
            }),
            (
                "adapt_active_version",
                "Plan version untagged requests route to.",
                |s| s.stats.active_version as f64,
            ),
            (
                "adapt_generation",
                "Plan generation (install counter of the active version).",
                |s| s.stats.generation as f64,
            ),
            (
                "adapt_canary_fraction",
                "Fraction of requests routed to a canary candidate (0 = none).",
                |s| s.canary_fraction,
            ),
            (
                "adapt_shadow_disagreement_rate",
                "Shadow-mirror disagreement rate for the running candidate (0 = none).",
                |s| s.shadow_rate,
            ),
            (
                "adapt_traces_retained",
                "Request traces currently retained in the ring.",
                |s| s.traces_retained as f64,
            ),
        ];
        for (name, help, get) in gauges {
            w.header(name, help, "gauge");
            for s in &snaps {
                w.sample(name, &[("model", &s.name)], get(s));
            }
        }

        let uppers: Vec<u64> = (0..LAT_BUCKETS).map(LatencyHist::upper_edge_us).collect();
        w.header(
            "adapt_queue_wait_us",
            "Per-request queue wait, microseconds.",
            "histogram",
        );
        for s in &snaps {
            w.histogram(
                "adapt_queue_wait_us",
                &[("model", &s.name)],
                &uppers,
                &s.stats.pool.total.queue_hist.buckets,
                s.stats.pool.total.queue_wait.as_micros() as f64,
            );
        }
        w.header(
            "adapt_compute_us",
            "Per-request share of batch compute time, microseconds.",
            "histogram",
        );
        for s in &snaps {
            w.histogram(
                "adapt_compute_us",
                &[("model", &s.name)],
                &uppers,
                &s.stats.pool.total.compute_hist.buckets,
                s.stats.pool.total.busy.as_micros() as f64,
            );
        }

        let net: [(&str, &str, &str, f64); 6] = [
            (
                "adapt_net_accepted_total",
                "Connections accepted and registered on an event loop.",
                "counter",
                self.net.accepted.load(Ordering::Relaxed) as f64,
            ),
            (
                "adapt_net_refused_total",
                "Connections refused with 503 at the connection cap.",
                "counter",
                self.net.refused.load(Ordering::Relaxed) as f64,
            ),
            (
                "adapt_net_idle_closed_total",
                "Connections reaped by the idle-timeout wheel.",
                "counter",
                self.net.idle_closed.load(Ordering::Relaxed) as f64,
            ),
            (
                "adapt_net_pipelined_total",
                "Requests parsed beyond pipeline depth 1.",
                "counter",
                self.net.pipelined.load(Ordering::Relaxed) as f64,
            ),
            (
                "adapt_net_flush_resumes_total",
                "Partial flushes resumed via write interest.",
                "counter",
                self.net.flush_resumes.load(Ordering::Relaxed) as f64,
            ),
            (
                "adapt_net_live_conns",
                "Currently open connections.",
                "gauge",
                self.net.live.load(Ordering::Relaxed) as f64,
            ),
        ];
        for (name, help, kind, value) in net {
            w.header(name, help, kind);
            w.sample(name, &[], value);
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canary_split_is_exact() {
        for (n, f) in [(100u64, 0.25f64), (40, 0.5), (7, 0.33), (64, 0.0), (64, 1.0)] {
            let picked = (0..n).filter(|&t| canary_pick(t, f)).count() as u64;
            assert_eq!(picked, (n as f64 * f).floor() as u64, "n={n} f={f}");
        }
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.5, 2.0, 2.0, -1.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-1.0, -1.0]), 0);
    }

    #[test]
    fn shadow_stats_accumulate() {
        let s = ShadowStats::new();
        s.record(&[1.0, 2.0], &[1.0, 2.0]);
        s.record(&[1.0, 2.0], &[2.5, 2.0]); // disagree + flip, |Δ| = 1.5
        s.record(&[1.0, 2.0], &[1.0, 2.25]); // disagree, no flip
        let r = s.report(7);
        assert_eq!(r.version, 7);
        assert_eq!(r.mirrored, 3);
        assert_eq!(r.disagree, 2);
        assert_eq!(r.top1_flips, 1);
        assert_eq!(r.max_abs_delta, 1.5);
        assert!((r.disagreement_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
