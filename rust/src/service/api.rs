//! The versioned request/response types of the serving API (`/v1` + `/v2`).
//!
//! [`InferRequest`] / [`InferResponse`] replace the engine's original bare
//! `Vec<f32>`-in / `Result<Vec<f32>>`-out surface: requests carry an id,
//! an optional top-k ask and an optional queueing deadline; responses
//! carry the output row plus per-request observability (queue wait,
//! compute time, serving worker, plan generation). [`ServiceError`] is
//! the structured error enum every layer speaks — the engine rejects
//! malformed or expired requests with it, the control plane rejects bad
//! plans with it, and the HTTP front-end maps each variant onto a status
//! code and a stable machine-readable `code` string.
//!
//! Everything (de)serializes through [`util::json`](crate::util::json);
//! f32 payloads survive the trip bit-for-bit (f32 → f64 is exact and the
//! writer emits a shortest round-tripping decimal).

use std::time::Duration;

use crate::util::json::Json;

/// One typed inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    /// Client-chosen id echoed in the response; auto-assigned when `None`.
    pub id: Option<u64>,
    /// Flat per-sample input (the model's `input_shape` product). Integer
    /// input models (token sequences) take the ids as f32 values.
    pub input: Vec<f32>,
    /// Return the k largest (index, score) pairs alongside the output.
    pub top_k: Option<usize>,
    /// Max time the request may wait in the engine queue before it is
    /// rejected with [`ServiceError::DeadlineExceeded`].
    pub deadline: Option<Duration>,
}

impl InferRequest {
    pub fn new(input: Vec<f32>) -> InferRequest {
        InferRequest {
            id: None,
            input,
            top_k: None,
            deadline: None,
        }
    }

    /// Parse the `POST /v1/infer` body:
    /// `{"input": [..], "id": 7, "top_k": 3, "deadline_ms": 50}`.
    pub fn from_json(j: &Json) -> Result<InferRequest, ServiceError> {
        let bad = ServiceError::BadRequest;
        let input = j
            .get("input")
            .map_err(|e| bad(format!("{e}")))?
            .arr()
            .map_err(|e| bad(format!("input: {e}")))?
            .iter()
            .map(|v| {
                let x = v.f64()? as f32;
                // Non-finite inputs (incl. f64 values that overflow f32)
                // would propagate inf/NaN into the output row; reject at
                // the door instead.
                anyhow::ensure!(x.is_finite(), "input values must be finite f32 ({x})");
                Ok(x)
            })
            .collect::<anyhow::Result<Vec<f32>>>()
            .map_err(|e| bad(format!("input: {e}")))?;
        let id = match j.opt("id") {
            Some(v) => Some(
                v.i64()
                    .ok()
                    .and_then(|n| u64::try_from(n).ok())
                    // Ids transit JSON as f64: above 2^53 the echo would
                    // come back mangled, so reject instead of corrupting.
                    .filter(|&n| n <= (1u64 << 53))
                    .ok_or_else(|| {
                        bad("id must be an integer in [0, 2^53] (it is echoed through JSON)"
                            .into())
                    })?,
            ),
            None => None,
        };
        let top_k = match j.opt("top_k") {
            Some(v) => Some(v.usize().map_err(|e| bad(format!("top_k: {e}")))?),
            None => None,
        };
        let deadline = match j.opt("deadline_ms") {
            Some(v) => Some(Duration::from_millis(
                v.i64()
                    .ok()
                    .and_then(|n| u64::try_from(n).ok())
                    .ok_or_else(|| bad("deadline_ms must be a non-negative integer".into()))?,
            )),
            None => None,
        };
        Ok(InferRequest {
            id,
            input,
            top_k,
            deadline,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("input".into(), Json::from_f32s(&self.input));
        if let Some(id) = self.id {
            m.insert("id".into(), Json::Num(id as f64));
        }
        if let Some(k) = self.top_k {
            m.insert("top_k".into(), Json::Num(k as f64));
        }
        if let Some(d) = self.deadline {
            m.insert("deadline_ms".into(), Json::Num(d.as_millis() as f64));
        }
        Json::Obj(m)
    }
}

/// One typed inference response.
#[derive(Clone, Debug, PartialEq)]
pub struct InferResponse {
    /// Echo of the request id (client-chosen or auto-assigned).
    pub id: u64,
    /// Flat output row.
    pub output: Vec<f32>,
    /// The k largest (index, score) pairs, when the request asked.
    pub top_k: Option<Vec<(usize, f32)>>,
    /// Time the request spent queued before a worker picked it up.
    pub queue_wait: Duration,
    /// Wall-clock of the batch that computed this response.
    pub compute: Duration,
    /// Pool worker that served the request.
    pub worker: usize,
    /// Plan generation the response was computed under (bumped by every
    /// successful plan hot-swap).
    pub generation: u64,
    /// Plan version the response was computed under (a [`PlanStore`]
    /// version number on registry-served models; 1 for the initial plan).
    ///
    /// [`PlanStore`]: crate::service::registry::PlanStore
    pub version: u64,
}

impl InferResponse {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert("output".into(), Json::from_f32s(&self.output));
        if let Some(tk) = &self.top_k {
            m.insert(
                "top_k".into(),
                Json::Arr(
                    tk.iter()
                        .map(|(i, s)| {
                            Json::Arr(vec![Json::Num(*i as f64), Json::Num(*s as f64)])
                        })
                        .collect(),
                ),
            );
        }
        m.insert(
            "queue_wait_us".into(),
            Json::Num(self.queue_wait.as_micros() as f64),
        );
        m.insert(
            "compute_us".into(),
            Json::Num(self.compute.as_micros() as f64),
        );
        m.insert("worker".into(), Json::Num(self.worker as f64));
        m.insert("generation".into(), Json::Num(self.generation as f64));
        m.insert("version".into(), Json::Num(self.version as f64));
        Json::Obj(m)
    }

    /// Parse a `/v1/infer` response body (the client side of the wire).
    pub fn from_json(j: &Json) -> anyhow::Result<InferResponse> {
        let top_k = match j.opt("top_k") {
            Some(v) => Some(
                v.arr()?
                    .iter()
                    .map(|pair| {
                        let p = pair.arr()?;
                        anyhow::ensure!(p.len() == 2, "top_k pair must be [index, score]");
                        Ok((p[0].usize()?, p[1].f64()? as f32))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
            None => None,
        };
        Ok(InferResponse {
            id: j.get("id")?.i64()? as u64,
            output: j.get("output")?.f32_vec()?,
            top_k,
            queue_wait: Duration::from_micros(j.get("queue_wait_us")?.i64()? as u64),
            compute: Duration::from_micros(j.get("compute_us")?.i64()? as u64),
            worker: j.get("worker")?.usize()?,
            generation: j.get("generation")?.i64()? as u64,
            // Absent on pre-registry peers: treat as the initial version.
            version: match j.opt("version") {
                Some(v) => v.i64()? as u64,
                None => 1,
            },
        })
    }
}

/// Structured service error: every failure mode of the serving path, each
/// with a stable machine-readable code and an HTTP status.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceError {
    /// Malformed request (bad JSON, missing/mistyped fields).
    BadRequest(String),
    /// Input length does not match the model's flat input size.
    WrongInputLength { got: usize, expected: usize },
    /// The model's input dtype is not servable by this backend.
    UnsupportedDtype(String),
    /// The request out-waited its queueing deadline.
    DeadlineExceeded { waited_ms: u64 },
    /// Request body exceeded the server's size cap.
    BodyTooLarge { got: usize, max: usize },
    /// No such route.
    NotFound(String),
    /// No model by that name in the registry.
    ModelNotFound(String),
    /// No plan version by that number in the model's store.
    NoSuchVersion { version: u64 },
    /// The server is at its connection cap.
    Overloaded { conns: usize },
    /// Known route, wrong HTTP method.
    MethodNotAllowed(String),
    /// Plan hot-swap rejected (validation failed or backend can't swap).
    PlanRejected(String),
    /// The engine is shutting down; no new requests.
    ShuttingDown,
    /// Backend execution failure.
    Backend(String),
    /// Anything else (a bug).
    Internal(String),
}

impl ServiceError {
    /// Stable machine-readable code (the `error` field on the wire).
    pub fn code(&self) -> &'static str {
        match self {
            ServiceError::BadRequest(_) => "bad_request",
            ServiceError::WrongInputLength { .. } => "wrong_input_length",
            ServiceError::UnsupportedDtype(_) => "unsupported_dtype",
            ServiceError::DeadlineExceeded { .. } => "deadline_exceeded",
            ServiceError::BodyTooLarge { .. } => "body_too_large",
            ServiceError::NotFound(_) => "not_found",
            ServiceError::ModelNotFound(_) => "model_not_found",
            ServiceError::NoSuchVersion { .. } => "no_such_version",
            ServiceError::Overloaded { .. } => "overloaded",
            ServiceError::MethodNotAllowed(_) => "method_not_allowed",
            ServiceError::PlanRejected(_) => "plan_rejected",
            ServiceError::ShuttingDown => "shutting_down",
            ServiceError::Backend(_) => "backend",
            ServiceError::Internal(_) => "internal",
        }
    }

    /// HTTP status the front-end answers with.
    pub fn http_status(&self) -> u16 {
        match self {
            ServiceError::BadRequest(_) | ServiceError::WrongInputLength { .. } => 400,
            ServiceError::NotFound(_)
            | ServiceError::ModelNotFound(_)
            | ServiceError::NoSuchVersion { .. } => 404,
            ServiceError::MethodNotAllowed(_) => 405,
            ServiceError::BodyTooLarge { .. } => 413,
            ServiceError::UnsupportedDtype(_) | ServiceError::PlanRejected(_) => 422,
            ServiceError::ShuttingDown | ServiceError::Overloaded { .. } => 503,
            ServiceError::DeadlineExceeded { .. } => 504,
            ServiceError::Backend(_) | ServiceError::Internal(_) => 500,
        }
    }

    /// Wire form: `{"error": "<code>", "message": "<detail>"}`.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("error".into(), Json::Str(self.code().into()));
        m.insert("message".into(), Json::Str(self.to_string()));
        Json::Obj(m)
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServiceError::WrongInputLength { got, expected } => {
                write!(f, "input length {got} != expected {expected}")
            }
            ServiceError::UnsupportedDtype(d) => {
                write!(f, "model input dtype {d:?} is not servable on this backend")
            }
            ServiceError::DeadlineExceeded { waited_ms } => {
                write!(f, "request out-waited its deadline ({waited_ms} ms in queue)")
            }
            ServiceError::BodyTooLarge { got, max } => {
                write!(f, "request body {got} bytes exceeds cap {max}")
            }
            ServiceError::NotFound(p) => write!(f, "no such route: {p}"),
            ServiceError::ModelNotFound(m) => write!(f, "no such model: {m}"),
            ServiceError::NoSuchVersion { version } => {
                write!(f, "no such plan version: {version}")
            }
            ServiceError::Overloaded { conns } => {
                write!(f, "server at its connection cap ({conns} open)")
            }
            ServiceError::MethodNotAllowed(m) => write!(f, "method not allowed: {m}"),
            ServiceError::PlanRejected(m) => write!(f, "plan rejected: {m}"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
            ServiceError::Backend(m) => write!(f, "backend failure: {m}"),
            ServiceError::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// The k largest (index, score) pairs of an output row, scores descending
/// (ties broken by lower index — deterministic).
pub fn top_k_of(output: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..output.len()).collect();
    idx.sort_by(|&a, &b| output[b].total_cmp(&output[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx.into_iter().map(|i| (i, output[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = InferRequest {
            id: Some(9),
            input: vec![0.125, -3.5, 1.0e-7],
            top_k: Some(2),
            deadline: Some(Duration::from_millis(50)),
        };
        let j = Json::parse(&req.to_json().to_string()).unwrap();
        assert_eq!(InferRequest::from_json(&j).unwrap(), req);
    }

    #[test]
    fn response_roundtrip_is_bit_exact() {
        let resp = InferResponse {
            id: 3,
            output: vec![1.0f32 / 3.0, f32::MIN_POSITIVE, -0.0, 7.25],
            top_k: Some(vec![(3, 7.25), (0, 1.0 / 3.0)]),
            queue_wait: Duration::from_micros(15),
            compute: Duration::from_micros(420),
            worker: 1,
            generation: 2,
            version: 3,
        };
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        let back = InferResponse::from_json(&j).unwrap();
        for (a, b) in back.output.iter().zip(&resp.output) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 must survive the wire");
        }
        assert_eq!(back.id, resp.id);
        assert_eq!(back.generation, resp.generation);
        assert_eq!(back.version, resp.version);
    }

    #[test]
    fn malformed_requests_are_typed() {
        let j = Json::parse(r#"{"id": 1}"#).unwrap();
        let e = InferRequest::from_json(&j).unwrap_err();
        assert_eq!(e.code(), "bad_request");
        let j = Json::parse(r#"{"input": "nope"}"#).unwrap();
        assert!(InferRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"input": [1], "id": -4}"#).unwrap();
        assert!(InferRequest::from_json(&j).is_err());
        // Non-finite inputs (incl. f64 overflow of f32) are rejected —
        // they would otherwise propagate inf/NaN into the output row.
        let j = Json::parse(r#"{"input": [1e400]}"#).unwrap();
        assert!(InferRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"input": [1e39]}"#).unwrap();
        assert!(InferRequest::from_json(&j).is_err(), "f32 overflow");
    }

    #[test]
    fn error_codes_and_statuses() {
        let e = ServiceError::WrongInputLength { got: 3, expected: 16 };
        assert_eq!(e.http_status(), 400);
        let j = e.to_json();
        assert_eq!(j.get("error").unwrap().str().unwrap(), "wrong_input_length");
        assert_eq!(ServiceError::NotFound("/x".into()).http_status(), 404);
        assert_eq!(ServiceError::ModelNotFound("m".into()).http_status(), 404);
        assert_eq!(
            ServiceError::NoSuchVersion { version: 9 }.http_status(),
            404
        );
        assert_eq!(ServiceError::Overloaded { conns: 4 }.http_status(), 503);
        assert_eq!(ServiceError::BodyTooLarge { got: 9, max: 1 }.http_status(), 413);
        assert_eq!(
            ServiceError::DeadlineExceeded { waited_ms: 1 }.http_status(),
            504
        );
    }

    #[test]
    fn top_k_deterministic_on_ties() {
        let out = vec![0.5, 2.0, 2.0, -1.0];
        assert_eq!(top_k_of(&out, 3), vec![(1, 2.0), (2, 2.0), (0, 0.5)]);
    }
}
