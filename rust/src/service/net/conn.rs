//! Per-connection state for the readiness loop: an incremental
//! HTTP/1.1 parser plus buffered output.
//!
//! The parser consumes whatever bytes have arrived so far and either
//! produces a complete request, asks for more, or reports a framing
//! error — byte-for-byte the same accept/reject decisions as the old
//! blocking reader (`MAX_HEAD`, malformed request lines, bad
//! `Content-Length`, `413` before the body is read, `connection:
//! close`). Pipelined requests simply stay in the buffer: the loop
//! calls [`Conn::try_parse`] again after answering the previous one.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use super::Interest;
use crate::obs::trace::unix_us;
use crate::obs::TraceRecorder;
use crate::service::api::ServiceError;

/// Request head cap, matching the old blocking server.
const MAX_HEAD: usize = 16 << 10;

/// How many parsed-but-unanswered requests one connection may queue
/// (pipelining); beyond this the loop stops reading from the socket,
/// which backpressures the peer through TCP.
pub const PIPELINE_MAX: usize = 8;

/// One parsed request (shared with the router in `service::http`).
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    pub keep_alive: bool,
    /// Wall-clock instant (unix µs) the request finished parsing; the
    /// net layer stamps its dispatch-wait trace span from here.
    pub parsed_unix_us: u64,
}

/// Outcome of one [`Conn::try_parse`] pass.
pub enum ParseStep {
    /// Head or body incomplete; read more bytes first.
    NeedMore,
    /// A full request was consumed from the buffer.
    Request(HttpRequest),
    /// Unrecoverable framing error: answer it, then close.
    Error(ServiceError),
}

/// State for one live connection on an event loop.
pub struct Conn {
    pub stream: TcpStream,
    /// Unconsumed inbound bytes (head-in-progress + pipelined data).
    pub read_buf: Vec<u8>,
    /// Serialized responses not yet accepted by the kernel.
    pub out: Vec<u8>,
    /// How far into `out` the kernel has taken (partial writes).
    pub out_start: usize,
    /// Parsed requests waiting for a dispatch slot, answered in order.
    pub parsed: VecDeque<HttpRequest>,
    /// A request from this connection is in the dispatch pool/engine.
    pub inflight: bool,
    /// Idle deadline: when the *current request* must be complete by.
    /// Re-armed when a response finishes, not when bytes trickle in,
    /// so slow-loris peers still expire.
    pub deadline: Instant,
    /// Close once `out` drains (error responses, `connection: close`).
    pub close_after_write: bool,
    /// Framing failed: keep reading and discarding so the peer's
    /// unread data cannot trigger an RST that eats our error response.
    pub discard_input: bool,
    /// Peer sent EOF (half-close): no more requests will arrive, but
    /// responses already earned still get written before the close.
    pub peer_eof: bool,
    /// Serialized framing-error response, held back until every
    /// previously pipelined request has been answered (responses stay
    /// in request order, exactly like the sequential blocking server).
    pub pending_error: Option<Vec<u8>>,
    /// Interest currently registered with the poller.
    pub interest: Interest,
    /// Pending `net_flush` trace annotation for the response currently
    /// draining: (recorder, trace id, queued-at unix µs). Set when a
    /// traced completion queues its bytes, consumed when `out` drains.
    pub flush_trace: Option<(Arc<TraceRecorder>, u64, u64)>,
}

impl Conn {
    pub fn new(stream: TcpStream, deadline: Instant) -> Conn {
        Conn {
            stream,
            read_buf: Vec::new(),
            out: Vec::new(),
            out_start: 0,
            parsed: VecDeque::new(),
            inflight: false,
            deadline,
            close_after_write: false,
            discard_input: false,
            peer_eof: false,
            pending_error: None,
            interest: Interest::READ,
            flush_trace: None,
        }
    }

    /// Bytes still queued for the peer.
    pub fn pending_out(&self) -> usize {
        self.out.len() - self.out_start
    }

    /// Append a serialized response; compacts the flushed prefix first
    /// so the buffer never grows unboundedly across keep-alive reuse.
    pub fn queue_output(&mut self, bytes: &[u8]) {
        if self.out_start > 0 {
            self.out.drain(..self.out_start);
            self.out_start = 0;
        }
        self.out.extend_from_slice(bytes);
    }

    /// True when the connection has nothing in flight, nothing queued,
    /// and nothing buffered — safe to reap on idle timeout.
    pub fn is_quiescent(&self) -> bool {
        !self.inflight
            && self.parsed.is_empty()
            && self.pending_out() == 0
            && self.pending_error.is_none()
    }

    /// Try to consume one complete request from `read_buf`.
    pub fn try_parse(&mut self, max_body: usize) -> ParseStep {
        let buf = &self.read_buf;
        let Some(head_end) = find_head_end(buf) else {
            if buf.len() > MAX_HEAD {
                return ParseStep::Error(ServiceError::BadRequest(
                    "header block too large".into(),
                ));
            }
            return ParseStep::NeedMore;
        };
        let head = match std::str::from_utf8(&buf[..head_end]) {
            Ok(s) => s,
            Err(_) => {
                return ParseStep::Error(ServiceError::BadRequest("non-UTF-8 header".into()))
            }
        };
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
            _ => {
                return ParseStep::Error(ServiceError::BadRequest(format!(
                    "malformed request line {request_line:?}"
                )))
            }
        };
        if !version.starts_with("HTTP/1.") {
            return ParseStep::Error(ServiceError::BadRequest(format!(
                "unsupported version {version:?}"
            )));
        }
        let mut content_length = 0usize;
        let mut keep_alive = true; // HTTP/1.1 default
        for line in lines {
            let Some((k, v)) = line.split_once(':') else {
                continue;
            };
            let (k, v) = (k.trim().to_ascii_lowercase(), v.trim());
            if k == "content-length" {
                content_length = match v.parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return ParseStep::Error(ServiceError::BadRequest(format!(
                            "bad content-length {v:?}"
                        )))
                    }
                };
            } else if k == "connection" {
                keep_alive = !v.eq_ignore_ascii_case("close");
            }
        }
        if content_length > max_body {
            // Refused before the body is read, like the old server.
            return ParseStep::Error(ServiceError::BodyTooLarge {
                got: content_length,
                max: max_body,
            });
        }
        let body_start = head_end + 4;
        if buf.len() < body_start + content_length {
            return ParseStep::NeedMore;
        }
        let body = buf[body_start..body_start + content_length].to_vec();
        // Whatever follows is the next pipelined request.
        self.read_buf.drain(..body_start + content_length);
        ParseStep::Request(HttpRequest {
            method,
            path,
            body,
            keep_alive,
            parsed_unix_us: unix_us(),
        })
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::time::{Duration, Instant};

    fn test_conn() -> Conn {
        // try_parse never touches the socket, but Conn owns one; use a
        // real loopback pair so the test stays dependency-free.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        Conn::new(stream, Instant::now() + Duration::from_secs(60))
    }

    fn feed(c: &mut Conn, bytes: &[u8]) {
        c.read_buf.extend_from_slice(bytes);
    }

    #[test]
    fn parses_incrementally_across_fragments() {
        let mut c = test_conn();
        feed(&mut c, b"POST /v1/infer HTTP/1.1\r\ncontent-le");
        assert!(matches!(c.try_parse(1024), ParseStep::NeedMore));
        feed(&mut c, b"ngth: 5\r\n\r\nhel");
        assert!(matches!(c.try_parse(1024), ParseStep::NeedMore));
        feed(&mut c, b"lo");
        match c.try_parse(1024) {
            ParseStep::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/v1/infer");
                assert_eq!(r.body, b"hello");
                assert!(r.keep_alive);
            }
            _ => panic!("expected a request"),
        }
        assert!(c.read_buf.is_empty());
    }

    #[test]
    fn pipelined_requests_stay_buffered() {
        let mut c = test_conn();
        feed(
            &mut c,
            b"GET /v1/healthz HTTP/1.1\r\n\r\nGET /v1/stats HTTP/1.1\r\n\r\n",
        );
        match c.try_parse(1024) {
            ParseStep::Request(r) => assert_eq!(r.path, "/v1/healthz"),
            _ => panic!("expected first request"),
        }
        match c.try_parse(1024) {
            ParseStep::Request(r) => assert_eq!(r.path, "/v1/stats"),
            _ => panic!("expected second request"),
        }
        assert!(matches!(c.try_parse(1024), ParseStep::NeedMore));
    }

    #[test]
    fn connection_close_clears_keep_alive() {
        let mut c = test_conn();
        feed(
            &mut c,
            b"GET /v1/healthz HTTP/1.1\r\nConnection: Close\r\n\r\n",
        );
        match c.try_parse(1024) {
            ParseStep::Request(r) => assert!(!r.keep_alive),
            _ => panic!("expected a request"),
        }
    }

    #[test]
    fn oversized_head_is_bad_request() {
        let mut c = test_conn();
        feed(&mut c, b"GET / HTTP/1.1\r\nx-pad: ");
        let pad = vec![b'a'; MAX_HEAD + 1];
        feed(&mut c, &pad);
        match c.try_parse(1024) {
            ParseStep::Error(ServiceError::BadRequest(m)) => {
                assert_eq!(m, "header block too large")
            }
            _ => panic!("expected header-too-large"),
        }
    }

    #[test]
    fn body_over_limit_is_413_before_body_arrives() {
        let mut c = test_conn();
        // Only the head is present; the verdict must not wait for the body.
        feed(&mut c, b"POST /v1/infer HTTP/1.1\r\ncontent-length: 999\r\n\r\n");
        match c.try_parse(100) {
            ParseStep::Error(ServiceError::BodyTooLarge { got, max }) => {
                assert_eq!((got, max), (999, 100));
            }
            _ => panic!("expected body-too-large"),
        }
    }

    #[test]
    fn malformed_line_and_version_rejected() {
        let mut c = test_conn();
        feed(&mut c, b"NONSENSE\r\n\r\n");
        assert!(matches!(
            c.try_parse(1024),
            ParseStep::Error(ServiceError::BadRequest(_))
        ));

        let mut c = test_conn();
        feed(&mut c, b"GET / SPDY/3\r\n\r\n");
        match c.try_parse(1024) {
            ParseStep::Error(ServiceError::BadRequest(m)) => {
                assert!(m.contains("unsupported version"), "{m}")
            }
            _ => panic!("expected version rejection"),
        }

        let mut c = test_conn();
        feed(&mut c, b"POST / HTTP/1.1\r\ncontent-length: nope\r\n\r\n");
        match c.try_parse(1024) {
            ParseStep::Error(ServiceError::BadRequest(m)) => {
                assert!(m.contains("bad content-length"), "{m}")
            }
            _ => panic!("expected content-length rejection"),
        }
    }

    #[test]
    fn output_buffer_compacts_flushed_prefix() {
        let mut c = test_conn();
        c.queue_output(b"0123456789");
        c.out_start = 6;
        assert_eq!(c.pending_out(), 4);
        c.queue_output(b"ab");
        assert_eq!(c.out_start, 0);
        assert_eq!(c.out, b"6789ab");
        assert_eq!(c.pending_out(), 6);
    }
}
