//! Readiness-loop networking for the HTTP front-end.
//!
//! # Architecture
//!
//! The serving tier used to run one OS thread per connection over
//! blocking sockets; it fell over at a few hundred keep-alive
//! connections. This module replaces that accept path with a classic
//! reactor: a small fixed pool of **event-loop threads** (default
//! `ADAPT_THREADS`), each owning a [`Poller`] — an abstraction over raw
//! `epoll` syscalls on Linux with a portable `poll(2)` tier — plus a
//! slab of per-connection state machines and a hashed timer wheel for
//! idle deadlines. Everything is level-triggered and non-blocking:
//!
//! - every loop registers its own `try_clone` of the listener, so the
//!   kernel distributes accepts across loops;
//! - reads feed an **incremental HTTP/1.1 parser** ([`conn`]) that
//!   supports pipelining — multiple requests parsed from one read are
//!   queued and answered strictly in order;
//! - writes are buffered and batched; a partial write registers
//!   write-interest and the loop finishes the flush when the socket
//!   drains, so a slow reader never blocks a thread;
//! - parsed requests are handed to a small **dispatch pool** which runs
//!   the (blocking) engine submit/wait off the event loops and posts
//!   the serialized response back through a completion queue + pipe
//!   waker.
//!
//! # Backend selection
//!
//! [`Backend::from_env`] picks `epoll` on Linux and `poll` elsewhere;
//! `ADAPT_NET=poll` forces the portable tier (CI runs the full suite
//! both ways), `ADAPT_NET=epoll` forces epoll. The two backends are
//! behaviorally identical — same level-triggered semantics, same
//! readable/writable/hangup event model — so every test passes
//! bit-for-bit under either.
//!
//! # Determinism contract
//!
//! The loop changes *scheduling*, never *semantics*: requests still
//! flow into the same bounded engine queue, batches still never mix
//! plan versions, and response bytes for a given request are identical
//! to the thread-per-connection server. Idle-timeout and `max_conns`
//! behavior are preserved: the idle window covers an entire request
//! (trickling bytes does not extend it), connections busy in the engine
//! are never reaped, and the live-connection cap still answers 503
//! with `Retry-After` semantics via the standard error JSON.

pub mod conn;
pub mod server;
pub mod sys;

use std::io;
use std::net::TcpStream;
use std::os::fd::{AsRawFd, RawFd};
use std::time::{Duration, Instant};

/// Which readiness backend a server runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Raw `epoll` syscalls (Linux only; the default there).
    Epoll,
    /// Portable `poll(2)` tier (default off Linux; `ADAPT_NET=poll`).
    Poll,
}

impl Backend {
    /// Resolve the backend from `ADAPT_NET` (`"epoll"` / `"poll"`;
    /// unset or empty picks the platform default).
    pub fn from_env() -> Backend {
        match std::env::var("ADAPT_NET").as_deref() {
            Ok("poll") => Backend::Poll,
            Ok("epoll") => Backend::Epoll,
            _ => Backend::default(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Epoll => "epoll",
            Backend::Poll => "poll",
        }
    }
}

impl Default for Backend {
    fn default() -> Backend {
        if cfg!(target_os = "linux") {
            Backend::Epoll
        } else {
            Backend::Poll
        }
    }
}

/// What a registration wants to hear about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
    pub const BOTH: Interest = Interest {
        readable: true,
        writable: true,
    };
}

/// One readiness event out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer closed or the socket errored; the connection is done.
    pub hangup: bool,
}

/// Level-triggered readiness poller over epoll (Linux) or `poll(2)`.
pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Poll(PollPoller),
}

impl Poller {
    pub fn new(backend: Backend) -> io::Result<Poller> {
        match backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll => Ok(Poller::Epoll(EpollPoller::new()?)),
            #[cfg(not(target_os = "linux"))]
            Backend::Epoll => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll backend requires Linux (set ADAPT_NET=poll)",
            )),
            Backend::Poll => Ok(Poller::Poll(PollPoller::default())),
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => Backend::Epoll,
            Poller::Poll(_) => Backend::Poll,
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.register(fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.reregister(fd, token, interest),
            Poller::Poll(p) => p.reregister(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.deregister(fd),
            Poller::Poll(p) => p.deregister(fd),
        }
    }

    /// Block up to `timeout` for readiness; append events to `out`.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, ms),
            Poller::Poll(p) => p.wait(out, ms),
        }
    }
}

#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: RawFd,
    buf: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> io::Result<EpollPoller> {
        Ok(EpollPoller {
            epfd: sys::epoll_create()?,
            buf: vec![sys::EpollEvent { events: 0, data: 0 }; 1024],
        })
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = sys::EPOLLRDHUP;
        if interest.readable {
            m |= sys::EPOLLIN;
        }
        if interest.writable {
            m |= sys::EPOLLOUT;
        }
        m
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        sys::epoll_add(self.epfd, fd, Self::mask(interest), token)
    }

    fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        sys::epoll_mod(self.epfd, fd, Self::mask(interest), token)
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        sys::epoll_del(self.epfd, fd)
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        let n = sys::epoll_wait_ms(self.epfd, &mut self.buf, timeout_ms)?;
        for ev in &self.buf[..n] {
            // Copy out of the (possibly packed) struct before use.
            let events = ev.events;
            let data = ev.data;
            let hangup = events & (sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP) != 0;
            out.push(Event {
                token: data,
                readable: events & sys::EPOLLIN != 0 || hangup,
                writable: events & sys::EPOLLOUT != 0,
                hangup,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollPoller {
    fn drop(&mut self) {
        sys::close_fd(self.epfd);
    }
}

/// `poll(2)` backend: a dense `pollfd` array plus a parallel token
/// array; removal is `swap_remove` with an fd→index map kept in sync.
#[derive(Default)]
pub struct PollPoller {
    fds: Vec<sys::PollFd>,
    tokens: Vec<u64>,
    index: std::collections::HashMap<RawFd, usize>,
}

impl PollPoller {
    fn events(interest: Interest) -> std::ffi::c_short {
        let mut e = 0;
        if interest.readable {
            e |= sys::POLLIN;
        }
        if interest.writable {
            e |= sys::POLLOUT;
        }
        e
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        if self.index.contains_key(&fd) {
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                "fd already registered",
            ));
        }
        self.index.insert(fd, self.fds.len());
        self.fds.push(sys::PollFd {
            fd,
            events: Self::events(interest),
            revents: 0,
        });
        self.tokens.push(token);
        Ok(())
    }

    fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        let &i = self
            .index
            .get(&fd)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
        self.fds[i].events = Self::events(interest);
        self.tokens[i] = token;
        Ok(())
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        let i = self
            .index
            .remove(&fd)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
        self.fds.swap_remove(i);
        self.tokens.swap_remove(i);
        if i < self.fds.len() {
            self.index.insert(self.fds[i].fd, i);
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        for f in &mut self.fds {
            f.revents = 0;
        }
        let n = sys::poll_ms(&mut self.fds, timeout_ms)?;
        if n == 0 {
            return Ok(());
        }
        for (f, &token) in self.fds.iter().zip(&self.tokens) {
            let r = f.revents;
            if r == 0 {
                continue;
            }
            let hangup = r & (sys::POLLHUP | sys::POLLERR | sys::POLLNVAL) != 0;
            out.push(Event {
                token,
                readable: r & sys::POLLIN != 0 || hangup,
                writable: r & sys::POLLOUT != 0,
                hangup,
            });
        }
        Ok(())
    }
}

/// Cross-thread wakeup for a loop parked in [`Poller::wait`]: a
/// non-blocking pipe whose read end is registered like any socket.
pub struct Waker {
    write_fd: RawFd,
}

impl Waker {
    /// One byte, best-effort: a full pipe means a wake is already
    /// pending, a broken pipe means the loop already exited.
    pub fn wake(&self) {
        sys::write_byte(self.write_fd);
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        sys::close_fd(self.write_fd);
    }
}

/// The loop-owned read end of a [`Waker`] pipe.
pub struct WakeReader {
    read_fd: RawFd,
}

impl WakeReader {
    pub fn fd(&self) -> RawFd {
        self.read_fd
    }

    /// Swallow all pending wake bytes.
    pub fn drain(&self) {
        sys::drain_fd(self.read_fd);
    }
}

impl Drop for WakeReader {
    fn drop(&mut self) {
        sys::close_fd(self.read_fd);
    }
}

/// Build a connected waker pair.
pub fn waker_pair() -> io::Result<(Waker, WakeReader)> {
    let (r, w) = sys::make_pipe()?;
    Ok((Waker { write_fd: w }, WakeReader { read_fd: r }))
}

/// Hashed timer wheel for idle deadlines: `slots × tick` of horizon,
/// one live entry per connection. Entries are `(deadline, token)`;
/// [`TimerWheel::take_due`] hands back every token whose slot has
/// rotated past, re-queueing entries whose deadline is still in the
/// future (including ones originally beyond the horizon). The caller
/// re-checks the connection's *actual* deadline — deadlines move every
/// time a request completes, and rather than chase each move with a
/// removal, stale entries are simply dropped or re-inserted on fire.
pub struct TimerWheel {
    slots: Vec<Vec<(Instant, u64)>>,
    tick: Duration,
    cursor: usize,
    /// Wheel time: everything strictly before `base` has been scanned.
    base: Instant,
}

impl TimerWheel {
    pub fn new(slots: usize, tick: Duration) -> TimerWheel {
        assert!(slots >= 2, "timer wheel needs at least two slots");
        TimerWheel {
            slots: (0..slots).map(|_| Vec::new()).collect(),
            tick,
            cursor: 0,
            base: Instant::now(),
        }
    }

    pub fn tick(&self) -> Duration {
        self.tick
    }

    /// Queue `token` to fire at (or shortly after) `deadline`.
    pub fn insert(&mut self, deadline: Instant, token: u64) {
        let ticks = if deadline <= self.base {
            1
        } else {
            let dt = deadline.duration_since(self.base);
            // Round up so an entry never fires a slot early, and clamp
            // to one lap; beyond-horizon entries re-insert on scan.
            let t = (dt.as_nanos() / self.tick.as_nanos().max(1)) as usize + 1;
            t.clamp(1, self.slots.len() - 1)
        };
        let slot = (self.cursor + ticks) % self.slots.len();
        self.slots[slot].push((deadline, token));
    }

    /// Advance the wheel to `now`, returning tokens whose recorded
    /// deadline has passed. Bounded to one full lap per call.
    pub fn take_due(&mut self, now: Instant) -> Vec<u64> {
        let mut due = Vec::new();
        let mut laps = 0;
        while now.duration_since(self.base) >= self.tick && laps < self.slots.len() {
            self.cursor = (self.cursor + 1) % self.slots.len();
            self.base += self.tick;
            laps += 1;
            let entries = std::mem::take(&mut self.slots[self.cursor]);
            for (deadline, token) in entries {
                if deadline <= now {
                    due.push(token);
                } else {
                    self.insert(deadline, token);
                }
            }
        }
        due
    }
}

/// Shrink a client socket's kernel receive buffer (tests use this to
/// force the server down its partial-write path).
pub fn set_recv_buffer(stream: &TcpStream, bytes: usize) -> io::Result<()> {
    sys::set_rcvbuf(stream.as_raw_fd(), bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn wheel_fires_after_deadline_not_before() {
        let mut w = TimerWheel::new(8, Duration::from_millis(10));
        let start = Instant::now();
        w.insert(start + Duration::from_millis(25), 7);
        assert!(w.take_due(start + Duration::from_millis(10)).is_empty());
        let due = w.take_due(start + Duration::from_millis(60));
        assert_eq!(due, vec![7]);
        // Fired entries are gone.
        assert!(w.take_due(start + Duration::from_millis(200)).is_empty());
    }

    #[test]
    fn wheel_requeues_beyond_horizon() {
        // Horizon is 8 * 5ms = 40ms; a 100ms deadline must survive the
        // first lap and fire on a later one.
        let mut w = TimerWheel::new(8, Duration::from_millis(5));
        let start = Instant::now();
        w.insert(start + Duration::from_millis(100), 42);
        assert!(w.take_due(start + Duration::from_millis(50)).is_empty());
        assert_eq!(w.take_due(start + Duration::from_millis(120)), vec![42]);
    }

    #[test]
    fn wheel_handles_many_tokens_one_slot() {
        let mut w = TimerWheel::new(4, Duration::from_millis(10));
        let start = Instant::now();
        for t in 0..16 {
            w.insert(start + Duration::from_millis(15), t);
        }
        let mut due = w.take_due(start + Duration::from_millis(40));
        due.sort_unstable();
        assert_eq!(due, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn backend_from_env_strings() {
        // from_env reads the process env, so only exercise the parse
        // paths that do not depend on ambient ADAPT_NET.
        assert_eq!(Backend::Epoll.name(), "epoll");
        assert_eq!(Backend::Poll.name(), "poll");
        let default = Backend::default();
        if cfg!(target_os = "linux") {
            assert_eq!(default, Backend::Epoll);
        } else {
            assert_eq!(default, Backend::Poll);
        }
    }

    #[test]
    fn poll_poller_register_cycle() {
        // The PollPoller bookkeeping (swap_remove + index map) is pure
        // data structure work; exercise it without real sockets.
        let mut p = PollPoller::default();
        p.register(10, 100, Interest::READ).unwrap();
        p.register(11, 101, Interest::BOTH).unwrap();
        p.register(12, 102, Interest::WRITE).unwrap();
        assert!(p.register(11, 999, Interest::READ).is_err());
        p.deregister(10).unwrap();
        // 12 swapped into slot 0; reregister must still find it.
        p.reregister(12, 202, Interest::READ).unwrap();
        assert_eq!(p.tokens[p.index[&12]], 202);
        p.deregister(12).unwrap();
        p.deregister(11).unwrap();
        assert!(p.fds.is_empty());
        assert!(p.deregister(11).is_err());
    }
}
