//! Raw syscall surface for the readiness loop.
//!
//! The build is offline and dependency-free, so instead of the `libc`
//! crate this file declares the handful of C symbols the poller needs —
//! they are all in the libc `std` already links — and wraps each in a
//! thin safe function returning `io::Result`. Everything Linux-specific
//! (`epoll_*`, `pipe2`, `RLIMIT_NOFILE = 7`) is gated on
//! `target_os = "linux"`; the portable tier (`poll(2)`, `pipe` +
//! `fcntl`) covers other Unixes.

use std::ffi::{c_int, c_short, c_void};
use std::io;
use std::os::fd::RawFd;

// ---------------------------------------------------------------------------
// epoll (Linux only)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
pub const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
pub const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
pub const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
pub const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
pub const EPOLLRDHUP: u32 = 0x2000;

#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: c_int = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: c_int = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: c_int = 3;
#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: c_int = 0o2000000;

/// The kernel's `struct epoll_event`. Packed on x86_64 (kernel ABI);
/// naturally aligned elsewhere — the same split the `libc` crate makes.
#[cfg(target_os = "linux")]
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, evs: *mut EpollEvent, max: c_int, timeout_ms: c_int) -> c_int;
}

#[cfg(target_os = "linux")]
pub fn epoll_create() -> io::Result<RawFd> {
    let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(fd)
}

#[cfg(target_os = "linux")]
fn epoll_op(epfd: RawFd, op: c_int, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    let mut ev = EpollEvent { events, data };
    let rc = unsafe { epoll_ctl(epfd, op, fd, &mut ev) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

#[cfg(target_os = "linux")]
pub fn epoll_add(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    epoll_op(epfd, EPOLL_CTL_ADD, fd, events, data)
}

#[cfg(target_os = "linux")]
pub fn epoll_mod(epfd: RawFd, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
    epoll_op(epfd, EPOLL_CTL_MOD, fd, events, data)
}

#[cfg(target_os = "linux")]
pub fn epoll_del(epfd: RawFd, fd: RawFd) -> io::Result<()> {
    epoll_op(epfd, EPOLL_CTL_DEL, fd, 0, 0)
}

/// Wait for events; `EINTR` is reported as zero events, not an error.
#[cfg(target_os = "linux")]
pub fn epoll_wait_ms(epfd: RawFd, buf: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
    let n = unsafe { epoll_wait(epfd, buf.as_mut_ptr(), buf.len() as c_int, timeout_ms) };
    if n < 0 {
        let e = io::Error::last_os_error();
        if e.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(e);
    }
    Ok(n as usize)
}

// ---------------------------------------------------------------------------
// poll (portable tier)
// ---------------------------------------------------------------------------

pub const POLLIN: c_short = 0x001;
pub const POLLOUT: c_short = 0x004;
pub const POLLERR: c_short = 0x008;
pub const POLLHUP: c_short = 0x010;
pub const POLLNVAL: c_short = 0x020;

/// `struct pollfd` — identical layout on every Unix.
#[derive(Clone, Copy)]
#[repr(C)]
pub struct PollFd {
    pub fd: c_int,
    pub events: c_short,
    pub revents: c_short,
}

#[cfg(target_os = "linux")]
type NfdsT = std::ffi::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = u32;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: u32,
    ) -> c_int;
}

/// Poll `fds`; `EINTR` is reported as zero ready fds, not an error.
pub fn poll_ms(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
    if n < 0 {
        let e = io::Error::last_os_error();
        if e.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(e);
    }
    Ok(n as usize)
}

pub fn close_fd(fd: RawFd) {
    unsafe {
        close(fd);
    }
}

/// Best-effort single-byte write (the waker). `EAGAIN` (pipe already
/// full, a wake is pending) and `EPIPE` (loop gone) are both fine.
pub fn write_byte(fd: RawFd) {
    let b = [1u8];
    unsafe {
        write(fd, b.as_ptr() as *const c_void, 1);
    }
}

/// Drain a non-blocking pipe read end completely.
pub fn drain_fd(fd: RawFd) {
    let mut buf = [0u8; 64];
    loop {
        let n = unsafe { read(fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
        if n <= 0 {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// pipe (the loop waker)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
pub fn make_pipe() -> io::Result<(RawFd, RawFd)> {
    const O_NONBLOCK: c_int = 0o4000;
    const O_CLOEXEC: c_int = 0o2000000;
    extern "C" {
        fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
    }
    let mut fds: [c_int; 2] = [0; 2];
    let rc = unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok((fds[0], fds[1]))
}

#[cfg(not(target_os = "linux"))]
pub fn make_pipe() -> io::Result<(RawFd, RawFd)> {
    const F_SETFL: c_int = 4;
    const F_SETFD: c_int = 2;
    const FD_CLOEXEC: c_int = 1;
    const O_NONBLOCK: c_int = 0x0004; // BSD/macOS value
    extern "C" {
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    }
    let mut fds: [c_int; 2] = [0; 2];
    let rc = unsafe { pipe(fds.as_mut_ptr()) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    for fd in fds {
        unsafe {
            fcntl(fd, F_SETFL, O_NONBLOCK);
            fcntl(fd, F_SETFD, FD_CLOEXEC);
        }
    }
    Ok((fds[0], fds[1]))
}

// ---------------------------------------------------------------------------
// Socket buffer knobs (tests force partial writes with tiny buffers)
// ---------------------------------------------------------------------------

const SOL_SOCKET: c_int = 1;
const SO_RCVBUF: c_int = 8;
const SO_SNDBUF: c_int = 7;

fn set_buf(fd: RawFd, opt: c_int, bytes: usize) -> io::Result<()> {
    let v = bytes as c_int;
    let rc = unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            opt,
            &v as *const c_int as *const c_void,
            std::mem::size_of::<c_int>() as u32,
        )
    };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Set `SO_SNDBUF` (the kernel typically doubles the value).
pub fn set_sndbuf(fd: RawFd, bytes: usize) -> io::Result<()> {
    set_buf(fd, SO_SNDBUF, bytes)
}

/// Set `SO_RCVBUF` (the kernel typically doubles the value).
pub fn set_rcvbuf(fd: RawFd, bytes: usize) -> io::Result<()> {
    set_buf(fd, SO_RCVBUF, bytes)
}

// ---------------------------------------------------------------------------
// File-descriptor limit (thousands of sockets need headroom)
// ---------------------------------------------------------------------------

/// Raise the soft `RLIMIT_NOFILE` toward `want` (capped by the hard
/// limit). Best-effort: serving or load-generating thousands of
/// connections otherwise dies on `EMFILE` under the common 1024 default.
#[cfg(target_os = "linux")]
pub fn ensure_fd_limit(want: usize) {
    const RLIMIT_NOFILE: c_int = 7;
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    }
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return;
    }
    let want = want as u64;
    if lim.cur >= want {
        return;
    }
    let raised = RLimit {
        cur: want.min(lim.max),
        max: lim.max,
    };
    unsafe {
        setrlimit(RLIMIT_NOFILE, &raised);
    }
}

#[cfg(not(target_os = "linux"))]
pub fn ensure_fd_limit(_want: usize) {}
